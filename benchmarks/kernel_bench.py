"""Kernel micro-benchmarks: wall time of the force kernels' reference paths
on CPU (the Pallas kernels target TPU; interpret mode is not a perf path)
and of one smoke-model train step per architecture."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def force_kernels(small: bool = False):
    from repro.kernels.nbody.ref import nbody_repulsion_ref
    from repro.kernels.neighbor_force.ref import neighbor_repulsion_ref
    rows = []
    rng = np.random.default_rng(0)
    for n in ((1024, 4096) if small else (1024, 4096, 16384)):
        pos = jnp.asarray(rng.random((n, 2)), jnp.float32)
        mass = jnp.ones((n,), jnp.float32)
        vmask = jnp.ones((n,), bool)
        f = jax.jit(lambda p, m, v: nbody_repulsion_ref(p, m, v, 1.0, 1.0, 1e-3))
        if n <= 4096:
            dt = _time(f, pos, mass, vmask)
            rows.append((f"nbody_ref_n{n}", dt * 1e6, f"pairs={n*n}"))
        K = 64
        nbr = jnp.asarray(rng.integers(0, n, (n, K)), jnp.int32)
        nmask = jnp.ones((n, K), bool)
        g = jax.jit(lambda p, m, i, k, v:
                    neighbor_repulsion_ref(p, m, i, k, v, 1.0, 1.0, 1e-3))
        dt = _time(g, pos, mass, nbr, nmask, vmask)
        rows.append((f"neighbor_ref_n{n}_k{K}", dt * 1e6, f"gathers={n*K}"))
    for name, us, d in rows:
        print(f"  kernel {name:24s} {us:10.1f} us  {d}", flush=True)
    return rows


def grid_vs_exact(small: bool = False):
    """Tentpole acceptance numbers: wall-clock and max force error of the
    three repulsion modes at scale. Target: grid ≥ 3× faster than exact
    all-pairs at 50k vertices with error within 10% of the force scale."""
    from repro.kernels.nbody.ref import nbody_repulsion_ref_chunked
    from repro.kernels.grid_force.ops import grid_repulsion, choose_grid
    from repro.kernels.neighbor_force.ref import neighbor_repulsion_ref
    rows = []
    rng = np.random.default_rng(0)
    for n in ((8_192,) if small else (8_192, 50_000)):
        pos = jnp.asarray(rng.random((n, 2)) * np.sqrt(n), jnp.float32)
        mass = jnp.ones((n,), jnp.float32)
        vmask = jnp.ones((n,), bool)
        G, cap = choose_grid(n)

        exact = jax.jit(lambda p, m, v: nbody_repulsion_ref_chunked(
            p, m, v, 1.0, 1.0, 1e-2))
        grid = jax.jit(lambda p, m, v: grid_repulsion(
            p, m, v, 1.0, 1.0, 1e-2, grid_dim=G, cell_cap=cap))
        t_exact = _time(exact, pos, mass, vmask, iters=3)
        t_grid = _time(grid, pos, mass, vmask, iters=3)

        K = 64
        nbr = jnp.asarray(rng.integers(0, n, (n, K)), jnp.int32)
        nmask = jnp.ones((n, K), bool)
        neigh = jax.jit(lambda p, m, i, k, v: neighbor_repulsion_ref(
            p, m, i, k, v, 1.0, 1.0, 1e-2))
        t_nbr = _time(neigh, pos, mass, nbr, nmask, vmask, iters=3)

        f_e = np.asarray(exact(pos, mass, vmask))
        f_g = np.asarray(grid(pos, mass, vmask))
        en = np.linalg.norm(f_e, axis=1)
        err = np.linalg.norm(f_g - f_e, axis=1) / (en + en.mean())
        speedup = t_exact / t_grid
        rows.append((f"repulsion_exact_n{n}", t_exact * 1e6, f"G={G}"))
        rows.append((f"repulsion_grid_n{n}", t_grid * 1e6,
                     f"speedup={speedup:.1f}x;maxerr={err.max():.4f}"))
        rows.append((f"repulsion_neighbor_n{n}_k{K}", t_nbr * 1e6,
                     "capped-khop"))
        print(f"  repulsion n={n:6d}: exact {t_exact*1e3:9.1f} ms | "
              f"grid {t_grid*1e3:9.1f} ms ({speedup:4.1f}x, max err "
              f"{err.max()*100:.2f}%) | neighbor(k={K}) {t_nbr*1e3:9.1f} ms",
              flush=True)
    return rows


def arch_steps(small: bool = True):
    from repro.configs import list_archs, get_smoke_config
    from repro.models import loss_fn, init_params
    rows = []
    rng = np.random.default_rng(0)
    for arch in list_archs():
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)),
                                       jnp.int32)}
        if cfg.enc_layers:
            batch["frames"] = jnp.zeros((2, 64, cfg.d_model), jnp.bfloat16)
        if cfg.modality == "vlm":
            batch["patches"] = jnp.zeros((2, 16, cfg.d_model), jnp.bfloat16)
        step = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))
        dt = _time(lambda p, b: jax.tree.leaves(step(p, b))[0], params, batch)
        rows.append((f"grad_step_{arch}", dt * 1e6, "smoke-config"))
        print(f"  arch {arch:24s} grad step {dt*1e6:10.0f} us", flush=True)
    return rows


def run(small: bool = False):
    return force_kernels(small) + grid_vs_exact(small) + arch_steps(small)


def csv_rows(rows):
    return rows
