"""Paper Table 1: drawing quality (CRE, NELD) — Multi-GiLA vs the
centralized multilevel baseline (FM³ stand-in) on RegularGraphs families."""
from __future__ import annotations

import time

import numpy as np

from repro.graphs import generators as G
from repro.graphs.metrics import cre, neld, sampled_stress
from repro.core import multigila_layout, LayoutConfig


def instances(small: bool):
    if small:
        return [(n, e, v) for n, e, v in G.regulargraphs_suite(small=True)]
    specs = [
        ("karate_like", *G.gnp(34, 4.6, 2)),
        ("grid_20_20", *G.grid(20, 20)),
        ("cylinder_010", *G.cylinder(10, 10)),
        ("tree_06_03", *G.tree(6, 3)),
        ("sierpinski_04", *G.sierpinski(4)),
        ("snowflake_A", *G.snowflake(3, 4, 2)),
        ("spider_A", *G.spider(8, 11, 2)),
        ("grid_40_40", *G.grid(40, 40)),
        ("sierpinski_06", *G.sierpinski(6)),
        ("grid_rnd_032", *G.random_regular(985, 4, 5)),
        ("flower_001", *G.flower(14, 14)),
        ("tree_06_04", *G.tree(6, 4)),
    ]
    return specs


def run(small: bool = False):
    rows = []
    for name, edges, n in instances(small):
        row = {"name": name, "n": n, "m": len(edges)}
        for engine, tag in (("multigila", "mg"), ("centralized", "fm3")):
            # paper-faithful Multi-GiLA refines with the k-hop GiLA
            # approximation at EVERY level (exact_threshold=0); the FM³
            # stand-in uses exact forces everywhere.
            cfg = LayoutConfig(engine=engine, seed=3,
                               exact_threshold=0 if engine == "multigila"
                               else 10 ** 9)
            t0 = time.perf_counter()
            pos, stats = multigila_layout(edges, n, cfg)
            dt = time.perf_counter() - t0
            row[f"{tag}_cre"] = cre(pos, edges)
            row[f"{tag}_neld"] = neld(pos, edges)
            row[f"{tag}_stress"] = sampled_stress(pos, edges, n)
            row[f"{tag}_t"] = dt
            row[f"{tag}_levels"] = stats.levels
        rows.append(row)
        print(f"  table1 {name:14s} n={n:5d} m={len(edges):6d} "
              f"CRE mg={row['mg_cre']:7.2f} fm3={row['fm3_cre']:7.2f}  "
              f"NELD mg={row['mg_neld']:.2f} fm3={row['fm3_neld']:.2f}",
              flush=True)
    return rows


def csv_rows(rows):
    out = []
    for r in rows:
        out.append(("table1_" + r["name"], r["mg_t"] * 1e6,
                    f"cre={r['mg_cre']:.2f};neld={r['mg_neld']:.2f};"
                    f"fm3_cre={r['fm3_cre']:.2f};fm3_neld={r['fm3_neld']:.2f}"))
    return out
