"""Continuous-batching layout service under Poisson load.

Compares the two layout front doors on one mixed-size multi-tenant
workload (small delaunay "minnows" with a periodic 420-vertex "whale" —
the shape mix that makes window batching hurt):

- fixed-window baseline: ``serve.layout_service.LayoutService`` — the
  deadline-window collector. Every request in a window resolves when the
  WHOLE batch finishes (convoy), and while a batch runs nothing else
  does, so a minnow stuck behind a whale inherits the whale's latency.
- continuous: ``serve.engine.ContinuousLayoutService`` — requests join
  the wave scheduler mid-flight between level waves and complete the
  moment their own lanes finish.

Headline metric — matched-p99 rate doubling. For rate pairs ``(r, 2r)``
the continuous engine is offered TWICE the arrival rate and must still
deliver a p99 latency no worse than the fixed window's at ``r``: that is
"≥2x the graphs/sec at equal p99 latency", checked per pair and recorded
in BENCH_service.json.

Two modes:

- ``--smoke`` (the CI gate): deterministic virtual-clock simulation.
  Both systems are replayed on the SAME scripted Poisson traces under
  the same per-group wave cost model (serve/engine.py:default_wave_cost)
  — the continuous engine through ``run_sim`` on an ``EngineCore`` with
  ``null_dispatch``, the baseline through ``simulate_fixed_window``
  below, which reproduces the ``_BatcherCore`` window semantics
  event-by-event. No wall clock anywhere: the run is bit-stable (the
  continuous engine's scheduling log is asserted identical across two
  replays) and the 2x property is checked on model time.
- full (default): real threaded measurement against the live services —
  warm-up covering every (shape, lane-bucket) the trace can reach, then
  open-loop Poisson load at each rate, p50/p99 stamped by Future
  callbacks, and a zero-warm-compile assertion over the whole measured
  region (core/bucketing.py:cache_stats).

    PYTHONPATH=src python benchmarks/service_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import LayoutConfig, bucketing, multigila_layout_many
from repro.core.multilevel import WaveScheduler
from repro.graphs import generators as G
from repro.serve.engine import (EngineCore, VirtualClock, default_wave_cost,
                                null_dispatch, poisson_trace, run_sim,
                                WAVE_COST_BASE_S, WAVE_COST_PER_LANE_S)

WHALE_EVERY = 6                     # every 6th request is a 420-vertex graph
MINNOW_SIZES = (90, 120)
WHALE_SIZE = 420
RATE_PAIRS = ((3, 6), (6, 12))      # (fixed rate, continuous rate) in graphs/s
CONT_MAX_LANES = 16                 # admission cap: bounds wave weight so
                                    # whales can't make every wave heavy


def make_workload(count: int, seed0: int = 2000) -> list:
    """The mixed-size request stream: graph i of every trace."""
    out = []
    for i in range(count):
        size = (WHALE_SIZE if i % WHALE_EVERY == 3
                else MINNOW_SIZES[i % len(MINNOW_SIZES)])
        out.append(G.delaunay(size, seed0 + i))
    return out


def warm(cfg: LayoutConfig, graphs: list) -> None:
    """Compile every (shape, lane-bucket) combination the services can
    reach: one pass over the full workload at each reachable lane bucket
    (pow2, floor 8 — graphs/packing.py:lane_bucket)."""
    for b in (8, 16, 32):
        for i in range(0, len(graphs), b):
            multigila_layout_many(graphs[i:i + b], cfg)


# -- deterministic simulation (smoke mode) --------------------------------------

def simulate_fixed_window(events: list, cfg: LayoutConfig, *,
                          max_batch: int = 16, window_s: float = 0.010,
                          wave_cost=None) -> tuple:
    """Replay the fixed-window ``LayoutService`` on virtual time.

    Mirrors ``serve.batcher._BatcherCore``: the serial worker picks up
    the oldest queued request, anchors a ``window_s`` collection window
    there, dispatches early when ``max_batch`` fills, then runs the batch
    TO COMPLETION — every member resolves when the last lane finishes,
    and requests arriving meanwhile wait for the next pickup. Batch
    durations come from draining a real ``WaveScheduler`` (real
    coarsening, real level waves, ``null_dispatch``) under ``wave_cost``,
    so both simulated systems are costed by the same model.

    Returns ``(latencies, schedule)`` with latencies in trace order.
    """
    cost = wave_cost or default_wave_cost
    subs = sorted((e for e in events if e.kind == "submit"),
                  key=lambda e: e.t)
    lats, t_free, i = [], 0.0, 0
    waves = groups = batches = 0
    while i < len(subs):
        t_pick = max(t_free, subs[i].t)
        t_close = t_pick + window_s
        j, t_start = i, t_close
        while (j < len(subs) and j - i < max_batch
               and subs[j].t <= t_close + 1e-12):
            j += 1
            if j - i == max_batch:     # early dispatch: window cut short
                t_start = max(t_pick, subs[j - 1].t)
        batch = subs[i:j]
        sched = WaveScheduler(cfg, dispatch=null_dispatch)
        for ev in batch:
            sched.admit(ev.edges, ev.n, seed=ev.seed)
        dur = 0.0
        while True:
            s = sched.step()
            if not s["lanes"]:
                break
            dur += cost(s)
            waves += 1
            groups += len(s["groups"])
        t_done = t_start + dur
        lats.extend(t_done - ev.t for ev in batch)
        t_free, i = t_done, j
        batches += 1
    return lats, dict(batches=batches, waves=waves, groups=groups)


def simulate_continuous(events: list, cfg: LayoutConfig, *,
                        max_lanes: int = CONT_MAX_LANES,
                        wave_cost=None) -> tuple:
    """Replay the continuous engine on virtual time; returns
    ``(latencies, core)`` — latencies for completed requests in trace
    order, the core for its log/counters."""
    core = EngineCore(cfg, clock=VirtualClock(), max_queue=4 * max_lanes,
                      max_lanes=max_lanes, dispatch=null_dispatch)
    handles = run_sim(core, events, wave_cost=wave_cost)
    lats = [h.latency for h in handles
            if h is not None and h.status == "done"]
    return lats, core


def _pcts(lats: list) -> dict:
    a = np.asarray(lats, dtype=float)
    return dict(count=int(a.size),
                p50_ms=round(float(np.percentile(a, 50)) * 1e3, 1),
                p99_ms=round(float(np.percentile(a, 99)) * 1e3, 1))


def run_sim_mode(count: int = 60) -> dict:
    """Virtual-clock comparison: deterministic, wall-clock-free."""
    cfg = LayoutConfig(seed=0)
    graphs = make_workload(count)
    mk = lambda i, rng: graphs[i % len(graphs)]
    pairs = []
    for r_fixed, r_cont in RATE_PAIRS:
        # same trace seed: the two traces are the same unit-exponential
        # draws scaled by 1/rate, so the comparison is paired, not noisy
        tr_f = poisson_trace(r_fixed, count, mk, seed=17)
        tr_c = poisson_trace(r_cont, count, mk, seed=17)
        lat_f, sched_f = simulate_fixed_window(tr_f, cfg)
        lat_c, core = simulate_continuous(tr_c, cfg)
        lat_c2, core2 = simulate_continuous(tr_c, cfg)
        assert core.log == core2.log, \
            "continuous sim replay produced a different scheduling log"
        assert len(lat_c) == count, \
            f"sim dropped requests: {len(lat_c)}/{count} completed"
        f, c = _pcts(lat_f), _pcts(lat_c)
        pairs.append(dict(
            rate_fixed=r_fixed, rate_cont=r_cont, fixed=f, cont=c,
            fixed_schedule=sched_f,
            cont_waves=core.counters["waves"],
            pass_2x=bool(c["p99_ms"] <= f["p99_ms"])))
        print(f"[service/sim] fixed@{r_fixed}: p99={f['p99_ms']}ms  "
              f"cont@{r_cont}: p99={c['p99_ms']}ms  "
              f"2x_at_equal_p99={'PASS' if pairs[-1]['pass_2x'] else 'FAIL'}",
              flush=True)
    assert all(p["pass_2x"] for p in pairs), \
        "continuous batching failed the matched-p99 rate doubling in sim"
    return dict(deterministic=True, pairs=pairs,
                model=dict(base_s=WAVE_COST_BASE_S,
                           per_lane_s=WAVE_COST_PER_LANE_S))


# -- real threaded measurement (full mode) --------------------------------------

def drive(submit, graphs: list, rate_hz: float, seed: int,
          timeout: float = 600.0) -> list:
    """Open-loop Poisson load against a live service: submit each graph at
    its scripted arrival time, stamp completion latency from a Future
    done-callback (NOT after-the-fact — early completions must be stamped
    when they happen), return per-request latencies."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=len(graphs))
    lats: list = [None] * len(graphs)
    futs = []
    t_next = time.perf_counter()
    for i, (e, n) in enumerate(graphs):
        t_next += gaps[i]
        dt = t_next - time.perf_counter()
        if dt > 0:
            time.sleep(dt)
        t0 = time.perf_counter()
        f = submit(e, n)
        f.add_done_callback(
            lambda _f, i=i, t0=t0:
                lats.__setitem__(i, time.perf_counter() - t0))
        futs.append(f)
    for f in futs:
        f.result(timeout)
    return lats


def run_real_mode(count: int = 120, seeds=(17, 41)) -> dict:
    """Measure the live services; asserts zero warm compiles and the
    matched-p99 doubling on at least one rate pair (wall-clock runs are
    noisy; the deterministic gate is the sim)."""
    from repro.serve import LayoutService
    from repro.serve.engine import ContinuousLayoutService

    cfg = LayoutConfig(seed=0)
    graphs = make_workload(count)
    print(f"[service] warming {len(graphs)} graphs x lane buckets 8/16/32 "
          "...", flush=True)
    warm(cfg, graphs)
    st0 = bucketing.cache_stats()

    pairs = []
    for r_fixed, r_cont in RATE_PAIRS:
        lat_f, lat_c = [], []
        for seed in seeds:
            svc = LayoutService(cfg)
            lat_f += drive(svc.submit, graphs, r_fixed, seed)
            svc.close()
            svc2 = ContinuousLayoutService(cfg, max_lanes=CONT_MAX_LANES)
            lat_c += drive(lambda e, n: svc2.submit(e, n).future,
                           graphs, r_cont, seed)
            svc2.close()
        f, c = _pcts(lat_f), _pcts(lat_c)
        pairs.append(dict(rate_fixed=r_fixed, rate_cont=r_cont,
                          fixed=f, cont=c,
                          pass_2x=bool(c["p99_ms"] <= f["p99_ms"])))
        print(f"[service] fixed@{r_fixed}: p50={f['p50_ms']}ms "
              f"p99={f['p99_ms']}ms   cont@{r_cont}: p50={c['p50_ms']}ms "
              f"p99={c['p99_ms']}ms   "
              f"2x_at_equal_p99={'PASS' if pairs[-1]['pass_2x'] else 'FAIL'}",
              flush=True)
    st1 = bucketing.cache_stats()
    compiles = st1["misses"] - st0["misses"]
    assert compiles == 0, f"measured region compiled {compiles} steps"
    assert any(p["pass_2x"] for p in pairs), \
        "no rate pair sustained 2x graphs/sec at equal p99"
    return dict(pairs=pairs, warm_compiles=compiles,
                seeds=list(seeds), cont_max_lanes=CONT_MAX_LANES)


def run(mode: str = "full") -> dict:
    res = dict(
        workload=dict(whale_every=WHALE_EVERY, whale_size=WHALE_SIZE,
                      minnow_sizes=list(MINNOW_SIZES)),
        rate_pairs=[list(p) for p in RATE_PAIRS],
        sim=run_sim_mode())
    if mode == "full":
        res["real"] = run_real_mode()
    from repro.obs import metrics as obs_metrics
    res["metrics"] = obs_metrics.REGISTRY.snapshot()
    return res


def csv_rows(res: dict):
    rows = []
    for scope in ("sim", "real"):
        for p in res.get(scope, {}).get("pairs", ()):
            rows.append((
                f"service_{scope}_fixed_r{p['rate_fixed']}",
                p["fixed"]["p99_ms"] * 1e3, "p99"))
            rows.append((
                f"service_{scope}_cont_r{p['rate_cont']}",
                p["cont"]["p99_ms"] * 1e3,
                f"p99_2x_pass={p['pass_2x']}"))
    return rows


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="deterministic virtual-clock simulation only "
                         "(wall-clock-stable; the CI gate)")
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args(argv)
    res = run("smoke" if args.smoke else "full")
    res["date"] = time.strftime("%Y-%m-%d")
    res["mode"] = "smoke" if args.smoke else "full"
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"[service] wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
