"""End-to-end large-graph run: the paper's headline scenario, measured.

The paper's Table 2 reports wall clock for complete multilevel layouts of
real-world graphs up to ~10M edges in about an hour on inexpensive cloud
hardware (Amazon EC2, Giraph). This bench reproduces the *shape* of that
experiment at whatever size the host can hold: generate the largest graph
the tier allows, round-trip it through the chunked edge-list loader
(``graphs/io.py`` — the ingest path a real dataset takes, exercising the
streaming parser), then run the full bucketed multilevel pipeline and
record per-phase wall clock (coarsen / place / refine / compile) from
``core.bucketing.PHASES`` plus the device-merger round counters.

    PYTHONPATH=src python -m benchmarks.bigrun_bench [--smoke|--small]
        [--out BENCH_bigrun.json]

``--smoke`` is the CI size (a few seconds); ``--small`` (grid_400x400,
~320k edges) is the tier recorded in EXPERIMENTS.md §Bigrun; the default
("full") is a ~2M-edge grid for hosts with a longer time budget.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

# the paper's reference point for this scenario (Table 2, com-Youtube /
# soc-Pokec class runs): ~10M edges in ~60 minutes end-to-end on a small
# Giraph cluster of commodity cloud machines
PAPER_REFERENCE = {
    "source": "arXiv:1608.08522 Table 2",
    "edges": 10_000_000,
    "minutes_end_to_end": 60.0,
}


def make_graph(kind: str):
    """(name, edges, n): regular grids — deterministic, any size, and the
    worst case for coarsening depth (diameter O(sqrt n))."""
    from repro.graphs import generators as G
    side = {"smoke": 80, "small": 400}.get(kind, 1000)
    return f"grid_{side}x{side}", *G.grid(side, side)


def run(kind: str = "full") -> dict:
    import jax

    from repro.core import LayoutConfig, bucketing, multigila_layout
    from repro.graphs import io as gio
    from repro.obs import metrics as obs_metrics

    name, edges, n = make_graph(kind)
    res = dict(bench="bigrun", suite=kind, graph=name,
               backend=jax.default_backend(),
               n=int(n), m=int(len(edges)),
               paper_reference=PAPER_REFERENCE)

    # ingest through the chunked streaming loader, as a real dataset would
    fd, path = tempfile.mkstemp(suffix=".txt")
    os.close(fd)
    try:
        gio.save_edgelist(path, edges)
        t0 = time.perf_counter()
        edges, n_loaded = gio.load_edgelist(path)
        res["load_seconds"] = round(time.perf_counter() - t0, 4)
        res["load_bytes"] = os.path.getsize(path)
    finally:
        os.unlink(path)
    assert n_loaded == n, (n_loaded, n)
    print(f"[bigrun] {name}: n={n:,} m={len(edges):,} "
          f"(loaded {res['load_bytes'] / 1e6:.1f} MB in "
          f"{res['load_seconds']:.2f}s)", flush=True)

    bucketing.PHASES.reset()
    def _rounds():
        snap = obs_metrics.REGISTRY.snapshot()
        vals = snap.get("gila_merger_rounds_total", {}).get("values", {})
        return sum(vals.values())

    rounds0 = _rounds()
    t0 = time.perf_counter()
    pos, stats = multigila_layout(edges, n, LayoutConfig(seed=0,
                                                         bucketing=True))
    total = time.perf_counter() - t0
    assert pos.shape == (n, 2) and np.isfinite(pos).all()

    phases = {k: round(v, 4) for k, v in bucketing.PHASES.snapshot().items()}
    # one-time XLA compiles (cold cache) vs the repeatable compute; a warm
    # serving process — or any second run of the same shape buckets — pays
    # only the latter, so both rates are recorded
    compute = max(total - phases.get("compile", 0.0), 1e-9)
    res.update(
        seconds=round(total, 4),
        phases=phases,
        compute_seconds=round(compute, 4),
        levels=int(stats.levels),
        level_sizes=[[int(x) for x in s] if np.ndim(s) else int(s)
                     for s in stats.level_sizes],
        merger_rounds=int(_rounds() - rounds0),
        edges_per_second=round(len(edges) / total, 1),
        edges_per_second_warm=round(len(edges) / compute, 1),
        # scale ratio vs the paper's run: wall-clock per edge, ours / theirs
        paper_minutes_at_this_rate=round(
            PAPER_REFERENCE["edges"] / max(len(edges) / total, 1e-9) / 60, 1),
        paper_minutes_at_warm_rate=round(
            PAPER_REFERENCE["edges"] / (len(edges) / compute) / 60, 1),
    )
    print(f"[bigrun] layout {total:.1f}s over {stats.levels} levels "
          f"({res['merger_rounds']} merger rounds) — phases {res['phases']}",
          flush=True)
    print(f"[bigrun] {res['edges_per_second']:,.0f} edges/s cold "
          f"({res['edges_per_second_warm']:,.0f} warm, compiles excluded) → "
          f"a 10M-edge run ≈ {res['paper_minutes_at_this_rate']} min cold / "
          f"{res['paper_minutes_at_warm_rate']} min warm "
          f"(paper: ~60 min on a Giraph cluster)", flush=True)
    return res


def csv_rows(res: dict):
    return [(f"bigrun_{res['graph']}_total", res["seconds"] * 1e6,
             f"levels={res['levels']}")]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized graph, still writes the JSON")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--out", default="BENCH_bigrun.json")
    args = ap.parse_args(argv)
    kind = "smoke" if args.smoke else ("small" if args.small else "full")
    res = run(kind)
    res["date"] = time.strftime("%Y-%m-%d")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"[bigrun] wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
