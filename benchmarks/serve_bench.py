"""Serving-path micro-benchmark: batched viewport query throughput.

Builds a layout + tile pyramid in-process, then measures the jitted
batched resolver closed-loop at B ∈ {1, 16, 64} — the BatchLayout-style
claim that batching independent requests into one device program is
where query throughput comes from. The ≥100k-vertex acceptance run goes
through ``repro.launch.serve --build/--bench`` (EXPERIMENTS.md §Serving);
this module keeps a CI-sized version in the benchmark harness.
"""
from __future__ import annotations

import time

import numpy as np


def run(small: bool = False):
    from repro.graphs import generators as G
    from repro.core import multigila_layout, LayoutConfig
    from repro.serve import build_pyramid, QueryEngine
    from repro.serve.query import random_viewports

    n_target = 2_000 if small else 20_000
    edges, n = G.gnp(n_target, 4.0, seed=0)
    cfg = LayoutConfig(seed=0, coarsest_iters=60, finest_iters=10)
    pos, stats, exp = multigila_layout(edges, n, cfg, export=True)
    pyr = build_pyramid(exp)
    eng = QueryEngine(pyr)
    zoom_max = max(b.zoom for b in pyr.bands)

    rows = []
    reqs = 128 if small else 512
    base_qps = None
    for B in (1, 16, 64):
        boxes, zs = random_viewports(pyr.lo, pyr.hi, zoom_max,
                                     max(reqs, B), seed=1)
        eng.query(boxes[:B], zs[:B])                      # compile
        n_batches = len(boxes) // B
        t0 = time.perf_counter()
        for i in range(n_batches):
            eng.query(boxes[i * B:(i + 1) * B], zs[i * B:(i + 1) * B])
        dt = time.perf_counter() - t0
        qps = n_batches * B / dt
        base_qps = base_qps or qps
        us_per_req = dt / (n_batches * B) * 1e6
        rows.append((f"serve_query_B{B}_n{n}", us_per_req,
                     f"qps={qps:.0f} speedup_vs_B1={qps / base_qps:.1f}x"))
        print(f"  serve B={B:3d}: {qps:9.1f} qps "
              f"({us_per_req:8.1f} us/request)", flush=True)
    return rows


def csv_rows(rows):
    return rows
