"""Paper Table 3 / Fig. 3: strong scalability of the layout engine.

Two views (this container has ONE physical core, so wall-clock cannot show
multi-worker speedup directly):

  1. *BSP cost model* — per-worker work/communication of one GiLA superstep
     for worker counts p ∈ {4, 8, 16, 32} from the SPMD-lowered program
     (the quantity the paper's Fig. 3 tracks: max per-worker load/superstep).
     Derived in a subprocess with p virtual devices via the roofline parser.
     Emitted for both repulsion regimes of a big hierarchy: mode="neighbor"
     (the paper's k-hop supersteps) and mode="grid" (the grid-bucketed
     approximation the schedule selects above 32768 vertices — the finest
     levels, where the mesh matters most).

  2. *Wall-clock vs graph size* — layout time on RealGraphs-class stand-ins
     of growing m on the single device (the paper's Table 3 row direction:
     time grows ~linearly in m thanks to the k(m) schedule). Sizes above
     the 32768-vertex grid threshold exercise mode="grid" on their finest
     levels.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.graphs import generators as G
from repro.core import multigila_layout, LayoutConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bsp_cost_model(ps=(4, 8, 16, 32), modes=("neighbor", "grid")):
    rows = []
    for p in ps:
        for mode in modes:
            code = f"""
            import os
            os.environ["XLA_FLAGS"] = \\
                "--xla_force_host_platform_device_count={p}"
            import json, jax
            from repro.core.distributed import (layout_train_step,
                                                layout_step_specs)
            from repro.kernels.grid_force.ops import choose_grid
            from repro.launch.roofline import analyze_text
            from repro.launch.mesh import make_compat_mesh
            mesh = make_compat_mesh(({p // 2}, 2), ("data", "model"))
            n_pad, m_pad, cap = 1 << 18, 1 << 20, 32
            G, cc = choose_grid(n_pad) if "{mode}" == "grid" else (0, 0)
            step, sh = layout_train_step(mesh, n_pad, m_pad, cap,
                                         mode="{mode}", grid_dim=G,
                                         cell_cap=cc)
            specs = layout_step_specs(n_pad, m_pad, cap, mode="{mode}")
            lowered = jax.jit(step, in_shardings=(
                sh["pos"], sh["w"], sh["nbr_idx"], sh["edge"], sh["edge"],
                sh["edge"], sh["edge"], sh["scalar"], sh["scalar"])).lower(
                specs["pos"], specs["w"], specs["nbr_idx"], specs["src"],
                specs["dst_local"], specs["emask"], specs["ewt"],
                specs["params"], specs["temp"])
            comp = lowered.compile()
            cost = analyze_text(comp.as_text(), world={p})
            print(json.dumps(dict(p={p}, mode="{mode}", flops=cost.flops,
                                  bytes=cost.bytes, coll=cost.coll_bytes)))
            """
            env = dict(os.environ)
            env["PYTHONPATH"] = os.path.join(REPO, "src")
            out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                                 capture_output=True, text=True, env=env,
                                 timeout=600)
            assert out.returncode == 0, out.stderr[-2000:]
            rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
            r = rows[-1]
            print(f"  table3-model p={r['p']:3d} mode={r['mode']:9s} "
                  f"flops/worker={r['flops']:.3e} "
                  f"bytes/worker={r['bytes']:.3e} coll/worker={r['coll']:.3e}",
                  flush=True)
    return rows


def wallclock_scaling(small: bool = False):
    sizes = [(2_000, 3), (8_000, 3), (40_000, 3)] if small else \
            [(5_000, 3), (20_000, 3), (60_000, 3), (150_000, 3)]
    cfg = LayoutConfig(seed=1)
    rows = []
    for n, m_attach in sizes:
        edges, nn = G.scale_free(n, m_attach, seed=5)
        t0 = time.perf_counter()
        pos, stats = multigila_layout(edges, nn, cfg)
        dt = time.perf_counter() - t0
        # the finest level's repulsion mode, from the size actually laid
        # out (post-pruning), mirroring make_schedule's selection
        n0 = stats.level_sizes[0][0] if stats.level_sizes else nn
        finest = ("exact" if n0 <= cfg.exact_threshold else
                  "neighbor" if n0 <= cfg.grid_threshold else "grid")
        rows.append({"n": nn, "m": len(edges), "t": dt,
                     "levels": stats.levels, "finest_mode": finest})
        print(f"  table3-time n={nn:7d} m={len(edges):8d} "
              f"levels={stats.levels} finest={finest} t={dt:7.1f}s",
              flush=True)
    return rows


def run(small: bool = False):
    model = bsp_cost_model((4, 8, 16) if small else (4, 8, 16, 32))
    wall = wallclock_scaling(small)
    return {"model": model, "wall": wall}


def csv_rows(res):
    out = []
    for r in res["model"]:
        out.append((f"table3_bsp_{r['mode']}_p{r['p']}", 0.0,
                    f"flops={r['flops']:.3e};coll={r['coll']:.3e}"))
    for r in res["wall"]:
        out.append((f"table3_wall_m{r['m']}", r["t"] * 1e6,
                    f"levels={r['levels']};finest={r['finest_mode']}"))
    return out
