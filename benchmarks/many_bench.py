"""Batched multi-graph layout benchmark: one device program lays out B graphs.

The multi-tenant serving scenario (DESIGN.md §9): B concurrent users each
submit a (small) graph and expect a finished drawing. This bench measures
the batched driver ``multigila_layout_many`` against the sequential
single-graph driver on a same-bucket B-graph suite, warm cache both ways:

  * ``sequential`` — one ``multigila_layout`` call per graph (the PR-4
    bucketed driver, warm compile cache);
  * ``batched``    — ONE ``multigila_layout_many`` call for the whole
    suite: per-level refinements grouped by shape bucket, one vmapped
    device program per level wave, lanes re-padded to the finer batch
    buckets (graphs/packing.py).

Both passes run on FRESH graphs (``seed_shift``) against caches warmed by
a preceding warm-up suite — the steady-state serving scenario. The two
DETERMINISTIC acceptance properties are asserted (CI fails on
regression): ``bit_identical`` per-graph results vs the sequential pass
and ``new_compiles == 0`` during the measured batched pass. ``speedup``
is recorded, not asserted — it depends on machine load (bar: ≥ 3× on the
16-graph suite; measured 5.3×, EXPERIMENTS.md §Many).

    PYTHONPATH=src python -m benchmarks.many_bench [--smoke] \
        [--out BENCH_many.json]

Writes the JSON trajectory file that CI uploads as an artifact;
EXPERIMENTS.md §Many records the measured numbers.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def suite(kind: str, seed_shift: int = 0):
    """B same-bucket graphs: one generator family and one size, so every
    level of every hierarchy lands in a warm shape bucket (the per-seed
    wobble of coarse-level sizes stays inside one pow2 bucket)."""
    from repro.graphs import generators as G

    if kind == "smoke":
        count, nn = 6, 100
    else:
        count, nn = 16, 120
    return [(f"delaunay_{nn}_{i}", *G.delaunay(nn, seed_shift + 10 + i))
            for i in range(count)]


def run(kind: str = "full") -> dict:
    import jax

    from repro.core import (LayoutConfig, multigila_layout,
                            multigila_layout_many, bucketing)

    cfg = LayoutConfig(seed=3)
    warm = suite(kind)
    graphs = suite(kind, seed_shift=1000)
    B = len(graphs)
    res = dict(bench="many", suite=kind, backend=jax.default_backend(),
               n_graphs=B,
               total_vertices=int(sum(n for _, _, n in graphs)),
               total_edges=int(sum(len(e) for _, e, _ in graphs)))

    print(f"[many] warm-up pass ({B} graphs, batched + sequential)...",
          flush=True)
    t0 = time.perf_counter()
    multigila_layout_many([(e, n) for _, e, n in warm], cfg)
    for _, e, n in warm:
        multigila_layout(e, n, cfg)
    res["warmup_seconds"] = round(time.perf_counter() - t0, 3)

    print(f"[many] sequential pass ({B} fresh same-bucket graphs)...",
          flush=True)
    bucketing.PHASES.reset()
    t0 = time.perf_counter()
    seq = [multigila_layout(e, n, cfg) for _, e, n in graphs]
    t_seq = time.perf_counter() - t0
    res["sequential"] = dict(
        seconds=round(t_seq, 3), graphs_per_sec=round(B / t_seq, 3),
        phases={k: round(v, 4) for k, v in
                bucketing.PHASES.snapshot().items()})

    print("[many] batched pass (one multi-graph driver call)...", flush=True)
    bucketing.PHASES.reset()
    stats0 = bucketing.cache_stats()
    t0 = time.perf_counter()
    out = multigila_layout_many([(e, n) for _, e, n in graphs], cfg)
    t_bat = time.perf_counter() - t0
    stats1 = bucketing.cache_stats()
    res["batched"] = dict(
        seconds=round(t_bat, 3), graphs_per_sec=round(B / t_bat, 3),
        phases={k: round(v, 4) for k, v in
                bucketing.PHASES.snapshot().items()},
        new_compiles=stats1["misses"] - stats0["misses"],
        jit_entries_added=stats1["jit_entries"] - stats0["jit_entries"])

    res["bit_identical"] = bool(all(
        np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
        for a, b in zip(seq, out)))
    res["speedup"] = round(t_seq / t_bat, 2)
    # deterministic acceptance properties — fail loudly (CI runs --smoke)
    assert res["bit_identical"], \
        "batched results diverged from the sequential driver"
    assert res["batched"]["new_compiles"] == 0, \
        f"warm batched pass compiled {res['batched']['new_compiles']} steps"
    print(f"[many] sequential {res['sequential']['graphs_per_sec']} g/s, "
          f"batched {res['batched']['graphs_per_sec']} g/s → "
          f"{res['speedup']}x (bar ≥3x on the 16-graph suite), "
          f"bit_identical={res['bit_identical']}, "
          f"warm compiles={res['batched']['new_compiles']}", flush=True)
    from repro.obs import metrics as obs_metrics
    res["metrics"] = obs_metrics.REGISTRY.snapshot()
    return res


def csv_rows(res: dict):
    return [
        ("many_sequential_total", res["sequential"]["seconds"] * 1e6,
         f"{res['sequential']['graphs_per_sec']}_graphs_per_sec"),
        ("many_batched_total", res["batched"]["seconds"] * 1e6,
         f"{res['batched']['graphs_per_sec']}_graphs_per_sec"),
        ("many_speedup", 0.0,
         f"{res['speedup']}x_bit_identical={res['bit_identical']}"
         f"_compiles={res['batched']['new_compiles']}"),
    ]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 6 graphs, still writes the JSON")
    ap.add_argument("--out", default="BENCH_many.json")
    args = ap.parse_args(argv)
    res = run("smoke" if args.smoke else "full")
    res["date"] = time.strftime("%Y-%m-%d")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"[many] wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
