"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--small] [--only table1,...]

Prints ``name,us_per_call,derived`` CSV at the end (per the scaffold
contract). Roofline tables come from launch/dryrun + launch/report (they
need the 512-device environment, not this process).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CI-sized instances")
    ap.add_argument("--only", default="",
                    help="comma list: table1,fig5,table3,kernels,serve,"
                         "pipeline,many,service")
    args = ap.parse_args(argv)
    want = set(args.only.split(",")) if args.only else {
        "table1", "fig5", "table3", "kernels", "serve", "pipeline", "many",
        "service"}

    csv = []
    if "table1" in want:
        print("== Table 1: drawing quality (CRE/NELD), Multi-GiLA vs "
              "centralized ==", flush=True)
        from benchmarks import quality_table1 as t1
        csv += t1.csv_rows(t1.run(small=args.small))
    if "fig5" in want:
        print("== Fig 5: hierarchy levels, distributed vs centralized "
              "merger ==", flush=True)
        from benchmarks import levels_fig5 as f5
        csv += f5.csv_rows(f5.run(small=args.small))
    if "table3" in want:
        print("== Table 3 / Fig 3: strong scalability ==", flush=True)
        from benchmarks import scaling_table3 as t3
        csv += t3.csv_rows(t3.run(small=args.small))
    if "kernels" in want:
        print("== Kernel + per-arch step micro-benchmarks ==", flush=True)
        from benchmarks import kernel_bench as kb
        csv += kb.csv_rows(kb.run(small=args.small))
    if "serve" in want:
        print("== Serving: batched viewport-query throughput ==", flush=True)
        from benchmarks import serve_bench as sb
        csv += sb.csv_rows(sb.run(small=args.small))
    if "pipeline" in want:
        print("== Pipeline: end-to-end multilevel driver, bucketed vs "
              "exact-shape compilation ==", flush=True)
        kind = "smoke" if args.small else "small"
        # the full-size pipeline suite (n up to 20k × 3 passes) is a
        # standalone run: python -m benchmarks.pipeline_bench
        print(f"[pipeline] running the '{kind}' suite here; use "
              "benchmarks.pipeline_bench directly for the full suite",
              flush=True)
        from benchmarks import pipeline_bench as pb
        csv += pb.csv_rows(pb.run(kind))

    if "many" in want:
        print("== Many: batched multi-graph layout vs sequential driver ==",
              flush=True)
        from benchmarks import many_bench as mb
        csv += mb.csv_rows(mb.run("smoke" if args.small else "full"))

    if "service" in want:
        print("== Service: continuous batching vs fixed window under "
              "Poisson load ==", flush=True)
        from benchmarks import service_bench as svb
        csv += svb.csv_rows(svb.run("smoke" if args.small else "full"))

    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
