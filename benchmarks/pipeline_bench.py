"""End-to-end multilevel pipeline benchmark (the paper's headline metric).

The paper's result is wall clock for the WHOLE coarsen → place → refine
driver, not a kernel microbenchmark. This bench times ``multigila_layout``
end-to-end over a multi-graph suite three ways:

  * ``bucketed_cold`` — pow2 shape buckets + compile cache
    (LayoutConfig.bucketing=True), empty cache: pays one compile per shape
    bucket, amortized across ALL graphs of the suite;
  * ``bucketed_warm`` — the same suite regenerated with fresh seeds (fresh
    graphs, same shape buckets) against the now-warm cache: the
    steady-state serving scenario — new compiles should be ~0;
  * ``exact_shape`` — the pre-refactor behavior (bucketing=False): every
    level of every graph retraces (static n/m/iters), measured via
    ``gila_layout``'s jit cache growth.

Passes run in that order, which is CONSERVATIVE for the reported speedups:
the exact_shape pass inherits any trace-cache overlap from the bucketed
passes, never the reverse.

Per-phase wall clock (coarsen / place / refine / compile) comes from
``core.bucketing.PHASES``; "compile" is the first call into a cold cache
entry (trace + XLA compile + first execution — inseparable under jit
dispatch), and merger-superstep compiles land inside "coarsen" the same
way on both drivers.

    PYTHONPATH=src python -m benchmarks.pipeline_bench [--smoke|--small]
        [--out BENCH_pipeline.json]

Writes the JSON trajectory file (repo root by default) that CI uploads as
an artifact; EXPERIMENTS.md §Pipeline records the measured numbers.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def suite(kind: str, seed_shift: int = 0):
    """(name, edges, n) list: RegularGraphs families + gnp / scale_free /
    delaunay at several sizes. ``seed_shift`` regenerates the gnp /
    scale_free / delaunay entries with fresh seeds but identical sizes —
    fresh graphs landing in the SAME shape buckets (the warm-path
    scenario). The RegularGraphs families are deterministic constructions
    and repeat verbatim; the warm pass still re-lays them out from scratch
    with a different ``LayoutConfig.seed`` (different election coins and
    initial positions), so no result of the cold pass is reusable — only
    the compiled programs are."""
    from repro.graphs import generators as G

    s = seed_shift
    graphs = list(G.regulargraphs_suite(small=(kind != "full")))
    if kind == "smoke":
        sizes = [600]
    elif kind == "small":
        sizes = [1000, 4000]
    else:
        sizes = [2000, 8000, 20000]
    for nn in sizes:
        graphs.append((f"gnp_{nn}", *G.gnp(nn, 4.0, 11 + s)))
        graphs.append((f"scale_free_{nn}", *G.scale_free(nn, 2, 12 + s)))
        graphs.append((f"delaunay_{nn}", *G.delaunay(nn, 13 + s)))
    return graphs


def _jit_entries_of(fn) -> int:
    size = getattr(fn, "_cache_size", None)
    try:
        return int(size()) if callable(size) else 0
    except Exception:
        return 0


def _run_pass(graphs, *, bucketing_on: bool, seed: int = 0) -> dict:
    from repro.core import LayoutConfig, multigila_layout, bucketing, gila

    bucketing.PHASES.reset()
    stats0 = bucketing.cache_stats()
    legacy0 = _jit_entries_of(gila.gila_layout)
    per_graph = []
    t_pass = time.perf_counter()
    for name, e, n in graphs:
        t0 = time.perf_counter()
        pos, st = multigila_layout(
            e, n, LayoutConfig(seed=seed, bucketing=bucketing_on))
        per_graph.append(dict(name=name, n=int(n), m=int(len(e)),
                              levels=int(st.levels),
                              seconds=time.perf_counter() - t0))
    total = time.perf_counter() - t_pass
    stats1 = bucketing.cache_stats()
    return dict(
        seconds=total,
        phases={k: round(v, 4) for k, v in
                bucketing.PHASES.snapshot().items()},
        new_compiles=stats1["misses"] - stats0["misses"],
        jit_entries_added=stats1["jit_entries"] - stats0["jit_entries"],
        legacy_gila_layout_compiles=_jit_entries_of(gila.gila_layout) - legacy0,
        per_graph=per_graph,
    )


def _coarsen_ab(graphs, passes: int = 5) -> dict:
    """Steady-state coarsen A/B: the device-resident merger + on-device
    compaction vs the host-bound reference drivers (``run_merger_host`` +
    ``next_level_host`` — the pre-DESIGN.md-§13 behavior, kept in-tree as
    the bit-parity reference). Both sides run the identical
    ``build_hierarchy`` walk over prebuilt level-0 graphs, min-of-N to
    strip scheduler noise; the device path goes first so the host side
    inherits any shared warm-up, never the reverse."""
    from repro.core import LayoutConfig, multilevel, solar_merger
    from repro.graphs.graph import build_graph

    cfg = LayoutConfig(seed=0, bucketing=True)
    g0s = [build_graph(e, n, bucket=True) for _, e, n in graphs]

    def one_pass():
        t0 = time.perf_counter()
        for g0 in g0s:
            multilevel.build_hierarchy(g0, cfg)
        return time.perf_counter() - t0

    one_pass()                                      # warm compiles/caches
    dev = min(one_pass() for _ in range(passes))
    orig = multilevel.run_merger, multilevel.next_level
    try:
        multilevel.run_merger = solar_merger.run_merger_host
        multilevel.next_level = solar_merger.next_level_host
        one_pass()
        host = min(one_pass() for _ in range(passes))
    finally:
        multilevel.run_merger, multilevel.next_level = orig
    return dict(device_seconds=round(dev, 4), host_seconds=round(host, 4),
                speedup=round(host / dev, 2), passes=passes)


def _engine_compare(kind: str) -> dict:
    """Both refinement engines (gila vs maxent-stress, core/engine.py) on a
    stress-favorable mesh-like suite: per-graph wall clock + quality
    (NELD / sampled stress / CRE), identical seeds and iteration schedules.
    Warm-started (one throwaway layout per engine pays the compiles) so the
    wall-clock comparison is steady-state."""
    from repro.graphs import generators as G
    from repro.graphs.graph import build_graph
    from repro.graphs.metrics import quality_report
    from repro.core import LayoutConfig, multigila_layout

    if kind == "smoke":
        graphs = [("grid_12_12", *G.grid(12, 12)),
                  ("tri_8_8", *G.tri_mesh(8, 8))]
    else:
        graphs = [("grid_20_20", *G.grid(20, 20)),
                  ("tri_14_14", *G.tri_mesh(14, 14)),
                  ("delaunay_600", *G.delaunay(600, 3)),
                  ("torus_14_10", *G.torus(14, 10))]

    out = {"suite": [g[0] for g in graphs], "engines": {}}
    for engine in ("gila", "stress"):
        cfg = LayoutConfig(seed=0, engine=engine)
        for _, e, n in graphs:                      # warm pass: pay every
            multigila_layout(e, n, cfg)             # compile off the clock
        rows = []
        for name, e, n in graphs:
            t0 = time.perf_counter()
            pos, _ = multigila_layout(e, n, cfg)
            dt = time.perf_counter() - t0
            g = build_graph(e, n)
            p = np.zeros((g.n_pad, 2), np.float32)
            p[:n] = pos
            rep = quality_report(g, p)
            rows.append(dict(name=name, seconds=round(dt, 4),
                             neld=round(rep["neld"], 4),
                             stress=round(rep["stress"], 5),
                             cre=round(rep["cre"], 4)))
        out["engines"][engine] = dict(
            per_graph=rows,
            mean_seconds=round(float(np.mean([r["seconds"] for r in rows])), 4),
            mean_neld=round(float(np.mean([r["neld"] for r in rows])), 4),
            mean_stress=round(float(np.mean([r["stress"] for r in rows])), 5))
    ge = out["engines"]["gila"]
    se = out["engines"]["stress"]
    out["stress_wins_neld"] = bool(se["mean_neld"] < ge["mean_neld"])
    out["stress_wins_stress_metric"] = bool(
        se["mean_stress"] < ge["mean_stress"])
    out["wallclock_ratio_stress_vs_gila"] = round(
        se["mean_seconds"] / max(ge["mean_seconds"], 1e-9), 2)
    return out


def run(kind: str = "small", skip_exact: bool = False,
        trace: str | None = None) -> dict:
    import jax

    graphs_cold = suite(kind)
    graphs_warm = suite(kind, seed_shift=1000)
    res = dict(bench="pipeline", suite=kind,
               backend=jax.default_backend(),
               n_graphs=len(graphs_cold),
               total_vertices=int(sum(n for _, _, n in graphs_cold)),
               total_edges=int(sum(len(e) for _, e, _ in graphs_cold)))

    print(f"[pipeline] bucketed cold pass ({len(graphs_cold)} graphs)...",
          flush=True)
    res["bucketed_cold"] = _run_pass(graphs_cold, bucketing_on=True, seed=0)
    print(f"[pipeline]   {res['bucketed_cold']['seconds']:.1f}s, "
          f"{res['bucketed_cold']['new_compiles']} compiled steps", flush=True)

    print("[pipeline] bucketed warm pass (fresh same-bucket graphs)...",
          flush=True)
    res["bucketed_warm"] = _run_pass(graphs_warm, bucketing_on=True, seed=1)
    print(f"[pipeline]   {res['bucketed_warm']['seconds']:.1f}s, "
          f"{res['bucketed_warm']['new_compiles']} compiled steps", flush=True)

    print("[pipeline] coarsen A/B (device path vs host-bound drivers)...",
          flush=True)
    res["coarsen_ab"] = _coarsen_ab(graphs_cold)
    ab = res["coarsen_ab"]
    print(f"[pipeline]   device {ab['device_seconds']:.3f}s vs host-bound "
          f"{ab['host_seconds']:.3f}s → {ab['speedup']}x", flush=True)

    print("[pipeline] engine compare (gila vs stress, mesh suite)...",
          flush=True)
    res["engine_compare"] = _engine_compare(kind)
    ec = res["engine_compare"]
    print(f"[pipeline]   neld {ec['engines']['gila']['mean_neld']} (gila) vs "
          f"{ec['engines']['stress']['mean_neld']} (stress), wall-clock "
          f"ratio {ec['wallclock_ratio_stress_vs_gila']}x", flush=True)

    if trace:
        # tracing-overhead measurement: the IDENTICAL warm workload, span
        # tracer off vs on, in interleaved pairs; min-of-N on each side
        # strips scheduler/dispatch noise (single warm passes vary by
        # several %, far above the tracer's real cost — ~100 span records
        # per pass). Acceptance: within 2% — EXPERIMENTS.md §Observability.
        from repro.obs import trace as obs_trace
        pairs = 5
        print(f"[pipeline] tracing overhead ({pairs} off/on pass pairs)...",
              flush=True)
        off_s, on_s = [res["bucketed_warm"]["seconds"]], []
        traced_pass = None
        for _ in range(pairs):
            obs_trace.reset()
            obs_trace.enable()
            traced_pass = _run_pass(graphs_warm, bucketing_on=True, seed=1)
            obs_trace.disable()
            on_s.append(traced_pass["seconds"])
            off_s.append(_run_pass(graphs_warm, bucketing_on=True,
                                   seed=1)["seconds"])
        obs_trace.export(trace)             # the last traced pass's events
        res["bucketed_warm_traced"] = traced_pass
        res["trace_events"] = len(obs_trace.get_tracer())
        res["trace_seconds_off"] = [round(s, 4) for s in off_s]
        res["trace_seconds_on"] = [round(s, 4) for s in on_s]
        res["trace_overhead_pct"] = round(
            (min(on_s) / min(off_s) - 1) * 100, 2)
        print(f"[pipeline]   min off {min(off_s):.2f}s, min on "
              f"{min(on_s):.2f}s ({res['trace_events']} events) → overhead "
              f"{res['trace_overhead_pct']:+.2f}% — wrote {trace}",
              flush=True)

    if not skip_exact:
        print("[pipeline] exact-shape (pre-refactor) pass...", flush=True)
        res["exact_shape"] = _run_pass(graphs_cold, bucketing_on=False, seed=0)
        ex = res["exact_shape"]
        print(f"[pipeline]   {ex['seconds']:.1f}s, "
              f"{ex['legacy_gila_layout_compiles']} level retraces", flush=True)
        res["speedup_cold_vs_exact"] = round(
            ex["seconds"] / res["bucketed_cold"]["seconds"], 2)
        res["speedup_warm_vs_exact"] = round(
            ex["seconds"] / res["bucketed_warm"]["seconds"], 2)
        print(f"[pipeline] speedup: cold {res['speedup_cold_vs_exact']}x, "
              f"warm {res['speedup_warm_vs_exact']}x", flush=True)

    from repro.obs import metrics as obs_metrics
    res["metrics"] = obs_metrics.REGISTRY.snapshot()
    return res


def csv_rows(res: dict):
    rows = []
    for p in ("bucketed_cold", "bucketed_warm", "exact_shape"):
        if p not in res:
            continue
        # the exact-shape pass never touches the step cache; its compile
        # count is the gila_layout per-level retrace count
        compiles = (res[p]["legacy_gila_layout_compiles"]
                    if p == "exact_shape" else res[p]["new_compiles"])
        rows.append((f"pipeline_{p}_total", res[p]["seconds"] * 1e6,
                     f"compiles={compiles}"))
    if "speedup_warm_vs_exact" in res:
        rows.append(("pipeline_speedup_warm", 0.0,
                     f"{res['speedup_warm_vs_exact']}x_vs_exact_shape"))
    return rows


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny suite, still writes the JSON")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--skip-exact", action="store_true",
                    help="skip the slow pre-refactor baseline pass")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="rerun the warm suite with the span tracer on, "
                         "measure the overhead, write the Perfetto trace")
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args(argv)
    kind = "smoke" if args.smoke else ("small" if args.small else "full")
    res = run(kind, skip_exact=args.skip_exact, trace=args.trace or None)
    res["date"] = time.strftime("%Y-%m-%d")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"[pipeline] wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
