"""Paper Fig. 5: hierarchy level counts — Distributed Solar Merger vs the
centralized Solar Merger on RegularGraphs families. The paper finds the
distributed variant produces comparable counts (±1–2 levels)."""
from __future__ import annotations

import time

from repro.graphs import generators as G
from repro.graphs.graph import build_graph
from repro.core import build_hierarchy, LayoutConfig
from repro.core.solar_merger import centralized_levels


def run(small: bool = False):
    specs = G.regulargraphs_suite(small=small) if small else [
        ("grid_20_20", *G.grid(20, 20)),
        ("grid_40_40", *G.grid(40, 40)),
        ("tree_06_04", *G.tree(6, 4)),
        ("sierpinski_06", *G.sierpinski(6)),
        ("cylinder_032", *G.cylinder(32, 31)),
        ("spider_B", *G.spider(25, 39, 1)),
        ("grid_rnd_100", *G.random_regular(9499, 4, 6)),
        ("3elt_like", *G.delaunay(4720, 11)),
        ("sf_10k", *G.scale_free(10000, 3, 9)),
    ]
    rows = []
    for name, edges, n in specs:
        t0 = time.perf_counter()
        graphs, _ = build_hierarchy(build_graph(edges, n), LayoutConfig())
        dist_levels = len(graphs)
        dt = time.perf_counter() - t0
        cent = centralized_levels(edges, n)
        rows.append({"name": name, "n": n, "m": len(edges),
                     "distributed": dist_levels, "centralized": len(cent),
                     "dist_sizes": [g.n for g in graphs],
                     "cent_sizes": cent, "t": dt})
        print(f"  fig5 {name:14s} distributed={dist_levels} "
              f"centralized={len(cent)}  sizes={[g.n for g in graphs]} "
              f"vs {cent}", flush=True)
    return rows


def csv_rows(rows):
    return [("fig5_" + r["name"], r["t"] * 1e6,
             f"dist_levels={r['distributed']};cent_levels={r['centralized']}")
            for r in rows]
