"""Quickstart: draw a graph with Multi-GiLA and train a small LM — both on
one CPU device, using the same public API the production launchers use.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.graphs import generators as G
from repro.graphs.metrics import cre, neld
from repro.graphs.io import save_svg
from repro.core import multigila_layout, LayoutConfig


def layout_demo():
    print("== Multi-GiLA layout: 40x40 grid ==")
    edges, n = G.grid(40, 40)
    pos, stats = multigila_layout(edges, n, LayoutConfig(seed=0))
    print(f"levels: {stats.levels}  sizes: {stats.level_sizes}")
    print(f"CRE: {cre(pos, edges):.3f}  NELD: {neld(pos, edges):.3f} "
          f"(paper Table 1 Grid_40_40: CRE 0.00, NELD 0.32; "
          f"see EXPERIMENTS.md on the residual-fold gap)")
    save_svg("/tmp/quickstart_grid.svg", pos, edges)
    print("wrote /tmp/quickstart_grid.svg")


def train_demo():
    print("\n== LM training: gemma-2b family (reduced config) ==")
    from repro.launch.train import main
    main(["--arch", "gemma-2b", "--smoke", "--steps", "30", "--seq", "128",
          "--batch", "4", "--log-every", "10"])


if __name__ == "__main__":
    layout_demo()
    train_demo()
