"""End-to-end driver example: lay out a RealGraphs-class instance (the
paper's scalability scenario, scaled to this container) and report the
paper's metrics + per-phase timing.

    PYTHONPATH=src python examples/layout_biggraph.py [--n 30000]
"""
import argparse
import time

import numpy as np

from repro.graphs import generators as G
from repro.graphs.graph import build_graph
from repro.graphs.metrics import neld, sampled_stress
from repro.graphs.io import save_svg
from repro.core import (multigila_layout, LayoutConfig, build_hierarchy)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30000)
    ap.add_argument("--svg", default="/tmp/biggraph.svg")
    args = ap.parse_args()

    edges, n = G.scale_free(args.n, 3, seed=11)
    print(f"scale-free graph: n={n} m={len(edges)} (amazon/DBLP family)")

    t0 = time.perf_counter()
    g0 = build_graph(edges, n)
    graphs, _ = build_hierarchy(g0, LayoutConfig())
    t_coarse = time.perf_counter() - t0
    print(f"coarsening: {[gg.n for gg in graphs]} in {t_coarse:.1f}s")

    t0 = time.perf_counter()
    pos, stats = multigila_layout(edges, n, LayoutConfig(seed=1))
    t_total = time.perf_counter() - t0
    print(f"full pipeline: {t_total:.1f}s  levels={stats.levels}")
    print(f"NELD={neld(pos, edges):.3f}  "
          f"stress={sampled_stress(pos, edges, n):.4f}")
    save_svg(args.svg, pos, edges, stroke=0.25)
    print(f"wrote {args.svg}")


if __name__ == "__main__":
    main()
