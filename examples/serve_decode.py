"""Serving example: batched prefill + token-by-token decode with KV/SSM
caches (greedy), for any assigned architecture family.

    PYTHONPATH=src python examples/serve_decode.py --arch jamba-v0.1-52b
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_params, prefill, decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--chunks", type=int, default=1,
                    help="chunked prefill (vLLM-style)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.enc_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.05, jnp.bfloat16)

    cache_len = S + args.new_tokens + 8
    t0 = time.perf_counter()
    logits, state, pos = prefill(params, cfg, batch, cache_len=cache_len,
                                 chunks=args.chunks)
    jax.block_until_ready(logits)
    print(f"prefill ({S} tokens, chunks={args.chunks}): "
          f"{time.perf_counter()-t0:.2f}s")

    enc_out = None
    if cfg.enc_layers:
        from repro.models.model import _encode
        enc_out = _encode(params, cfg, batch["frames"])

    step = jax.jit(lambda p, t, s, i: decode_step(p, cfg, t, s, i,
                                                  enc_out=enc_out))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.new_tokens - 1):
        logits, state = step(params, tok, state, jnp.asarray(pos + i,
                                                             jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    seq = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decoded {args.new_tokens} tokens/seq × {B} seqs in {dt:.2f}s "
          f"({args.new_tokens * B / dt:.1f} tok/s on CPU)")
    print("sample:", seq[0][:16].tolist())


if __name__ == "__main__":
    main()
