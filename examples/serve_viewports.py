"""Serving example: layout a graph, build the quadtree tile pyramid, and
answer concurrent viewport queries through the micro-batching front door
(the layout-serving analogue of examples/serve_decode.py's batched
prefill).

    PYTHONPATH=src python examples/serve_viewports.py
"""
import argparse
import time

import numpy as np

from repro.core import multigila_layout, LayoutConfig
from repro.graphs import generators
from repro.serve import build_pyramid, QueryEngine, MicroBatcher
from repro.serve.query import random_viewports


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="gnp")
    ap.add_argument("--args", nargs="*", type=float, default=[3000, 4.0])
    ap.add_argument("--requests", type=int, default=64)
    args = ap.parse_args()

    edges, n, gargs = generators.from_cli(args.graph, args.args)
    print(f"layout {args.graph}{gargs}: n={n} m={len(edges)}")
    pos, stats, exp = multigila_layout(
        edges, n, LayoutConfig(seed=0, coarsest_iters=60, finest_iters=10),
        export=True)
    pyr = build_pyramid(exp)
    print("bands:", [(b.zoom, b.n, b.m) for b in pyr.bands])

    eng = QueryEngine(pyr)
    eng.warmup((1, 16, 64))
    mb = MicroBatcher(eng, max_batch=64, window_s=0.002)
    zoom_max = max(b.zoom for b in pyr.bands)
    boxes, zs = random_viewports(pyr.lo, pyr.hi, zoom_max, args.requests)
    t0 = time.perf_counter()
    futs = [mb.submit(boxes[i], int(zs[i])) for i in range(args.requests)]
    results = [f.result(timeout=60) for f in futs]
    dt = time.perf_counter() - t0
    mb.close()
    nv = np.array([len(r["vid"]) for r in results])
    print(f"{args.requests} viewports in {dt*1e3:.1f} ms "
          f"({args.requests/dt:.0f} qps) via {mb.batches} device batch(es); "
          f"vertices/viewport min/median/max = "
          f"{nv.min()}/{int(np.median(nv))}/{nv.max()}")


if __name__ == "__main__":
    main()
