"""Fault-tolerance example: train, kill mid-run (simulated), auto-resume
from the newest valid checkpoint — including a corrupted-checkpoint skip.

    PYTHONPATH=src python examples/train_resume.py
"""
import os
import shutil
import tempfile

from repro.launch.train import main


def run():
    ckpt = tempfile.mkdtemp(prefix="repro_resume_")
    print(f"== phase 1: train 40 steps, checkpoint every 20 → {ckpt} ==")
    main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "40",
          "--seq", "128", "--batch", "4", "--ckpt", ckpt,
          "--ckpt-every", "20", "--log-every", "20"])

    # simulate a node failure that corrupted the newest checkpoint
    newest = max(d for d in os.listdir(ckpt) if d.startswith("step_"))
    victim = os.path.join(ckpt, newest, "manifest.json")
    print(f"== simulating corruption: truncating {victim} ==")
    with open(victim, "w") as f:
        f.write("{corrupt")

    print("== phase 2: resume (skips the corrupt checkpoint, falls back) ==")
    main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "60",
          "--seq", "128", "--batch", "4", "--ckpt", ckpt,
          "--resume", "auto", "--log-every", "20"])
    shutil.rmtree(ckpt, ignore_errors=True)
    print("resume-after-failure demo complete")


if __name__ == "__main__":
    run()
