"""Roofline extractor validation against XLA's own cost_analysis."""
import re

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.roofline import (analyze_text, normalize_cost_analysis,
                                   roofline_terms, Cost)


def _compile(fn, *specs, shardings=None):
    j = jax.jit(fn) if shardings is None else jax.jit(fn,
                                                      in_shardings=shardings)
    return j.lower(*specs).compile()


def test_flops_match_cost_analysis_dot_dominated():
    def f(x, ws):
        for i in range(4):
            x = jnp.maximum(x @ ws[i], 0)
        return x.sum()
    comp = _compile(jax.grad(f, argnums=1),
                    jax.ShapeDtypeStruct((256, 512), jnp.float32),
                    jax.ShapeDtypeStruct((4, 512, 512), jnp.float32))
    ca = normalize_cost_analysis(comp.cost_analysis())
    cost = analyze_text(comp.as_text(), world=1)
    assert cost.flops == pytest.approx(ca["flops"], rel=0.05)
    # bytes is a fusion-boundary proxy: where XLA draws fusion boundaries
    # varies by version (0.4.x CPU fuses less), so only the order of
    # magnitude is stable — assert agreement within 3×.
    ratio = cost.bytes / ca["bytes accessed"]
    assert 1 / 3 < ratio < 3, (cost.bytes, ca["bytes accessed"])


def test_scan_trip_count_multiplied():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()
    L = 7
    comp = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                    jax.ShapeDtypeStruct((L, 64, 64), jnp.float32))
    c1 = analyze_text(comp.as_text(), world=1, force_trip_one=True)
    cL = analyze_text(comp.as_text(), world=1)
    assert cL.flops == pytest.approx(L * c1.flops, rel=0.02)


def test_collective_ring_model():
    """all-reduce over an 8-way axis moves 2·(8−1)/8·size bytes/device."""
    import os
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (run under dryrun env)")


def test_collective_bytes_parsed(tmp_path):
    hlo = """
HloModule test

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024] parameter(0)
  ROOT %ar = f32[1024] all-reduce(%p), replica_groups=[64,8]<=[512], to_apply=%add
}

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}
"""
    cost = analyze_text(hlo, world=512)
    # f32 halved (CPU bf16-emulation correction): 2·(7/8)·4096 / 2
    assert cost.coll_bytes == pytest.approx(2 * (7 / 8) * 4096 * 0.5)


def test_roofline_terms_bottleneck():
    t = roofline_terms(Cost(flops=197e12, bytes=1.0, coll_bytes=1.0),
                       model_flops_per_device=197e12)
    assert t["bottleneck"] == "compute"
    assert t["roofline_frac"] == pytest.approx(1.0)
    t = roofline_terms(Cost(flops=1.0, bytes=819e9 * 2, coll_bytes=0.0))
    assert t["bottleneck"] == "memory"
