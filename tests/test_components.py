"""Parity + scale tests for the vectorized ``connected_components``.

The old implementation was a per-edge Python union-find loop — O(m)
interpreter time that alone dominated ingest on million-edge graphs. The
replacement (scipy.sparse.csgraph, with a numpy pointer-jumping fallback)
must preserve the exact labels contract: label = minimum vertex id in the
component.
"""
import time

import numpy as np
import pytest

from repro.graphs import generators as G
from repro.core.multilevel import (connected_components,
                                   _components_pointer_jumping)


def _union_find_reference(edges: np.ndarray, n: int) -> np.ndarray:
    """The replaced per-edge implementation, kept verbatim as the parity
    oracle (min-id labels via path-compressed union by min root)."""
    parent = np.arange(n, dtype=np.int64)

    def find(x):
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for u, v in np.asarray(edges, dtype=np.int64):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(i) for i in range(n)], dtype=np.int64)


CASES = [
    ("grid", *G.grid(9, 11)),
    ("two_comps", np.array([[0, 1], [1, 2], [3, 4]]), 6),
    ("self_loops", np.array([[0, 0], [1, 2], [2, 1]]), 4),
    ("scale_free", *G.scale_free(400, 2, 3)),
    ("empty_edges", np.zeros((0, 2), np.int64), 5),
]


@pytest.mark.parametrize("name,edges,n", CASES, ids=[c[0] for c in CASES])
def test_components_parity_vs_union_find(name, edges, n):
    ref = _union_find_reference(edges, n)
    assert np.array_equal(connected_components(edges, n), ref)
    e2 = np.asarray(edges, np.int64).reshape(-1, 2)
    if len(e2):
        assert np.array_equal(_components_pointer_jumping(e2, n), ref)


def test_components_parity_shredded_graph():
    """Many components of varied sizes: keep every 3rd edge of a big grid."""
    edges, n = G.grid(40, 40)
    edges = np.asarray(edges)[::3]
    ref = _union_find_reference(edges, n)
    assert np.array_equal(connected_components(edges, n), ref)
    assert np.array_equal(
        _components_pointer_jumping(np.asarray(edges, np.int64), n), ref)


def test_components_labels_are_min_vertex_ids():
    edges = np.array([[5, 9], [9, 7], [2, 3]])
    lab = connected_components(edges, 10)
    assert lab[5] == lab[9] == lab[7] == 5
    assert lab[2] == lab[3] == 2
    for v in (0, 1, 4, 6, 8):
        assert lab[v] == v


def test_components_empty_graph():
    assert connected_components(np.zeros((0, 2), np.int64), 0).shape == (0,)


def test_components_million_edge_time_budget():
    """Scale regression: ~1M edges must label in seconds, not the minutes
    the per-edge Python loop took (the loop alone was ~30s+ here)."""
    edges, n = G.grid(700, 700)              # 490k vertices, ~979k edges
    assert len(edges) > 900_000
    t0 = time.perf_counter()
    lab = connected_components(edges, n)
    dt = time.perf_counter() - t0
    assert (lab == 0).all()                  # one component, min id 0
    assert dt < 10.0, f"connected_components took {dt:.1f}s on ~1M edges"
