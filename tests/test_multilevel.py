"""End-to-end Multi-GiLA pipeline tests (the paper's quality claims, scaled
to CI sizes)."""
import numpy as np
import pytest

from repro.graphs import generators as G, build_graph
from repro.graphs.metrics import cre, neld, sampled_stress
from repro.core import multigila_layout, LayoutConfig
from repro.core.pruning import prune_degree_one, reinsert
from repro.core.solar_placer import solar_placer
from repro.core import run_merger, next_level


def test_grid_layout_quality():
    """Paper Table 1: grids draw crossing-free (CRE 0.00 for Grid_20_20)."""
    e, n = G.grid(12, 12)
    pos, stats = multigila_layout(e, n, LayoutConfig(seed=1))
    assert cre(pos, e) < 0.05
    assert neld(pos, e) < 0.45


def test_multilevel_beats_flat_on_mesh():
    """The paper's core claim: the hierarchy mitigates GiLA's locality
    approximation — multilevel stress ≤ flat stress on regular graphs."""
    e, n = G.sierpinski(5)
    p_ml, _ = multigila_layout(e, n, LayoutConfig(engine="multigila", seed=2))
    p_fl, _ = multigila_layout(e, n, LayoutConfig(engine="flat", seed=2))
    s_ml = sampled_stress(p_ml, e, n)
    s_fl = sampled_stress(p_fl, e, n)
    assert s_ml < s_fl, (s_ml, s_fl)


def test_pruning_roundtrip():
    e, n = G.with_degree_one_fringe(*G.grid(8, 8), frac=0.4, seed=1)
    pr = prune_degree_one(e, n)
    assert pr.n < n
    # host masses absorb the pruned leaves
    assert abs(float(pr.mass.sum()) - n) < 1e-6
    pos_kept = np.random.default_rng(0).random((pr.n, 2)).astype(np.float32)
    pos = reinsert(pr, pos_kept, pr.edges)
    assert pos.shape == (n, 2)
    # kept vertices keep their positions
    np.testing.assert_allclose(pos[pr.old_of_new], pos_kept[: pr.n])
    # leaves land near their hosts (≤ host's mean edge length)
    for leaf, host in zip(pr.leaves[:20], pr.leaf_host[:20]):
        d = np.linalg.norm(pos[leaf] - pos[host])
        assert 0 < d < 5.0


def test_disconnected_components_packed():
    e1, n1 = G.grid(5, 5)
    e2, n2 = G.tree(3, 3)
    e = np.concatenate([e1, e2 + n1], axis=0)
    n = n1 + n2
    pos, _ = multigila_layout(e, n, LayoutConfig(seed=0))
    assert pos.shape == (n, 2)
    # components do not overlap: bounding boxes disjoint
    b1 = (pos[:n1].min(0), pos[:n1].max(0))
    b2 = (pos[n1:].min(0), pos[n1:].max(0))
    sep_x = b1[1][0] < b2[0][0] or b2[1][0] < b1[0][0]
    sep_y = b1[1][1] < b2[0][1] or b2[1][1] < b1[0][1]
    assert sep_x or sep_y


def test_placer_puts_suns_at_coarse_positions():
    e, n = G.grid(10, 10)
    g = build_graph(e, n)
    st = run_merger(g, seed=3)
    cg, info = next_level(g, st)
    rng = np.random.default_rng(0)
    coarse_pos = rng.random((cg.n_pad, 2)).astype(np.float32) * 10
    pos = solar_placer(g, info, coarse_pos, seed=0)
    pos = np.asarray(pos)
    suns = np.nonzero((info.state == 1) & np.asarray(g.vmask))[0]
    for s in suns[:20]:
        np.testing.assert_allclose(pos[s], coarse_pos[info.parent_coarse[s]],
                                   atol=1e-5)
    # members land within a few ideal lengths of their sun
    members = np.nonzero((info.state > 1) & np.asarray(g.vmask))[0]
    for v in members[:50]:
        sun_pos = coarse_pos[info.parent_coarse[v]]
        assert np.linalg.norm(pos[v] - sun_pos) < 12.0


def test_placer_scatter_fallback_radius():
    """Members with NO inter-system link scatter around their sun at a
    radius proportional to their depth (solar_placer's fallback branch)."""
    from repro.core.solar_merger import LevelInfo
    # two path systems joined only sun-to-sun: members 1,2 and 4,5 have no
    # cross-system edges, so none of them receives a barycentric suggestion
    e = np.array([[0, 1], [1, 2], [3, 4], [4, 5], [0, 3]])
    n = 6
    g = build_graph(e, n)
    n_pad = g.n_pad
    state = np.zeros(n_pad, np.int32)
    state[:n] = [1, 2, 3, 1, 2, 3]          # SUN, PLANET, MOON × 2
    sun_of = np.full(n_pad, n_pad, np.int32)
    sun_of[:n] = [0, 0, 0, 3, 3, 3]
    depth = np.zeros(n_pad, np.int32)
    depth[:n] = [0, 1, 2, 0, 1, 2]
    parent_coarse = np.full(n_pad, -1, np.int32)
    parent_coarse[:n] = [0, 0, 0, 1, 1, 1]
    info = LevelInfo(parent_coarse=parent_coarse, sun_of=sun_of, depth=depth,
                     state=state, sun_pos_index=np.array([0, 3], np.int32))
    coarse_pos = np.array([[0.0, 0.0], [10.0, 0.0]], np.float32)
    scatter = 0.7
    pos = np.asarray(solar_placer(g, info, coarse_pos, seed=0,
                                  scatter_scale=scatter))
    for v, sun, d in [(1, 0, 1), (2, 0, 2), (4, 3, 1), (5, 3, 2)]:
        r = np.linalg.norm(pos[v] - coarse_pos[parent_coarse[sun]])
        np.testing.assert_allclose(r, scatter * d, atol=1e-5,
                                   err_msg=f"vertex {v}")
    # suns sit exactly at their coarse positions
    np.testing.assert_allclose(pos[0], coarse_pos[0], atol=1e-6)
    np.testing.assert_allclose(pos[3], coarse_pos[1], atol=1e-6)


def test_centralized_baseline_runs():
    e, n = G.grid(8, 8)
    pos, stats = multigila_layout(e, n, LayoutConfig(engine="centralized",
                                                    seed=0))
    assert cre(pos, e) < 0.05
