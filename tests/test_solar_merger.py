import numpy as np
import pytest

from repro.graphs import generators as G, build_graph
from repro.graphs.metrics import bfs_distances
from repro.core import run_merger, next_level, build_hierarchy, LayoutConfig
from repro.core.solar_merger import SUN, PLANET, MOON


GRAPHS = [
    ("grid", *G.grid(16, 16)),
    ("tree", *G.tree(4, 4)),
    ("scale_free", *G.scale_free(1200, 3, 2)),
    ("sierpinski", *G.sierpinski(5)),
    ("flower", *G.flower(8, 8)),
]


@pytest.mark.parametrize("name,edges,n", GRAPHS, ids=[g[0] for g in GRAPHS])
def test_merger_invariants(name, edges, n):
    g = build_graph(edges, n)
    st = run_merger(g, seed=1)
    state = np.asarray(st.state)
    sun = np.asarray(st.sun)
    depth = np.asarray(st.depth)
    parent = np.asarray(st.parent)
    vm = np.asarray(g.vmask)

    # every valid vertex assigned, depth ∈ {0,1,2} (system diameter ≤ 4)
    assert (state[vm] > 0).all()
    assert ((depth[vm] >= 0) & (depth[vm] <= 2)).all()
    # sun pointers point at suns; suns point at themselves
    assert (state[sun[vm]] == SUN).all()
    suns = np.nonzero((state == SUN) & vm)[0]
    assert (sun[suns] == suns).all()

    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    em = np.asarray(g.emask)
    adj = set(zip(src[em].tolist(), dst[em].tolist()))
    # planets adjacent to their sun, moons adjacent to a same-system planet
    for p in np.nonzero((state == PLANET) & vm)[0][:100]:
        assert (int(sun[p]), int(p)) in adj
    for mo in np.nonzero((state == MOON) & vm)[0][:100]:
        par = int(parent[mo])
        assert (par, int(mo)) in adj
        assert state[par] == PLANET and sun[par] == sun[mo]


def test_first_round_suns_are_3_apart():
    """Before desperation kicks in, elected suns respect distance ≥ 3."""
    e, n = G.grid(20, 20)
    g = build_graph(e, n)
    import jax, jax.numpy as jnp
    from repro.core.solar_merger import init_state, sun_election
    st = sun_election(g, init_state(g), jax.random.PRNGKey(0),
                      jnp.asarray(0.5), jnp.asarray(False), jnp.asarray(True))
    suns = np.nonzero(np.asarray(st.state) == SUN)[0]
    suns = suns[suns < n]
    D = bfs_distances(e, n, suns[:20])
    for i in range(min(20, len(suns))):
        d = D[i][suns]
        d = d[d > 0]
        assert (d >= 3).all()


def test_next_level_mass_and_edges():
    e, n = G.grid(16, 16)
    g = build_graph(e, n)
    st = run_merger(g, seed=0)
    cg, info = next_level(g, st)
    # total mass preserved
    assert abs(float(np.asarray(cg.mass).sum()) - n) < 1e-3
    # coarse graph strictly smaller, weights ≥ 1 (path lengths)
    assert 0 < cg.n < n
    w = np.asarray(cg.ewt)[np.asarray(cg.emask)]
    assert (w >= 1.0).all()
    # parent_coarse maps every valid vertex into [0, cg.n)
    pc = info.parent_coarse[np.asarray(g.vmask)]
    assert (pc >= 0).all() and (pc < cg.n).all()


def test_hierarchy_shrinks():
    e, n = G.delaunay(3000, 5)
    graphs, infos = build_hierarchy(build_graph(e, n), LayoutConfig())
    sizes = [gg.n for gg in graphs]
    assert len(sizes) >= 2
    assert all(sizes[i + 1] < sizes[i] for i in range(len(sizes) - 1))
    # FM3-like shrink rate: at least 2× per level on meshes
    assert sizes[1] <= sizes[0] / 2
