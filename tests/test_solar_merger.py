import numpy as np
import pytest

from repro.graphs import generators as G, build_graph
from repro.graphs.metrics import bfs_distances
from repro.core import run_merger, next_level, build_hierarchy, LayoutConfig
from repro.core.solar_merger import SUN, PLANET, MOON


GRAPHS = [
    ("grid", *G.grid(16, 16)),
    ("tree", *G.tree(4, 4)),
    ("scale_free", *G.scale_free(1200, 3, 2)),
    ("sierpinski", *G.sierpinski(5)),
    ("flower", *G.flower(8, 8)),
]


@pytest.mark.parametrize("name,edges,n", GRAPHS, ids=[g[0] for g in GRAPHS])
def test_merger_invariants(name, edges, n):
    g = build_graph(edges, n)
    st = run_merger(g, seed=1)
    state = np.asarray(st.state)
    sun = np.asarray(st.sun)
    depth = np.asarray(st.depth)
    parent = np.asarray(st.parent)
    vm = np.asarray(g.vmask)

    # every valid vertex assigned, depth ∈ {0,1,2} (system diameter ≤ 4)
    assert (state[vm] > 0).all()
    assert ((depth[vm] >= 0) & (depth[vm] <= 2)).all()
    # sun pointers point at suns; suns point at themselves
    assert (state[sun[vm]] == SUN).all()
    suns = np.nonzero((state == SUN) & vm)[0]
    assert (sun[suns] == suns).all()

    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    em = np.asarray(g.emask)
    adj = set(zip(src[em].tolist(), dst[em].tolist()))
    # planets adjacent to their sun, moons adjacent to a same-system planet
    for p in np.nonzero((state == PLANET) & vm)[0][:100]:
        assert (int(sun[p]), int(p)) in adj
    for mo in np.nonzero((state == MOON) & vm)[0][:100]:
        par = int(parent[mo])
        assert (par, int(mo)) in adj
        assert state[par] == PLANET and sun[par] == sun[mo]


def test_first_round_suns_are_3_apart():
    """Before desperation kicks in, elected suns respect distance ≥ 3."""
    e, n = G.grid(20, 20)
    g = build_graph(e, n)
    import jax, jax.numpy as jnp
    from repro.core.solar_merger import init_state, sun_election
    st = sun_election(g, init_state(g), jax.random.PRNGKey(0),
                      jnp.asarray(0.5), jnp.asarray(False), jnp.asarray(True))
    suns = np.nonzero(np.asarray(st.state) == SUN)[0]
    suns = suns[suns < n]
    D = bfs_distances(e, n, suns[:20])
    for i in range(min(20, len(suns))):
        d = D[i][suns]
        d = d[d > 0]
        assert (d >= 3).all()


def test_next_level_mass_and_edges():
    e, n = G.grid(16, 16)
    g = build_graph(e, n)
    st = run_merger(g, seed=0)
    cg, info = next_level(g, st)
    # total mass preserved
    assert abs(float(np.asarray(cg.mass).sum()) - n) < 1e-3
    # coarse graph strictly smaller, weights ≥ 1 (path lengths)
    assert 0 < cg.n < n
    w = np.asarray(cg.ewt)[np.asarray(cg.emask)]
    assert (w >= 1.0).all()
    # parent_coarse maps every valid vertex into [0, cg.n)
    pc = info.parent_coarse[np.asarray(g.vmask)]
    assert (pc >= 0).all() and (pc < cg.n).all()


def test_hierarchy_shrinks():
    e, n = G.delaunay(3000, 5)
    graphs, infos = build_hierarchy(build_graph(e, n), LayoutConfig())
    sizes = [gg.n for gg in graphs]
    assert len(sizes) >= 2
    assert all(sizes[i + 1] < sizes[i] for i in range(len(sizes) - 1))
    # FM3-like shrink rate: at least 2× per level on meshes
    assert sizes[1] <= sizes[0] / 2


# -- hypothesis property tests: Solar Merger invariants on random graphs ------

try:
    from hypothesis import given, settings, strategies as st_
    HAVE_HYPOTHESIS = True
except ImportError:          # dev extra — pip install -r requirements-dev.txt
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st_.composite
    def random_graph(draw, max_n=32):
        n = draw(st_.integers(4, max_n))
        m = draw(st_.integers(0, min(3 * n, n * (n - 1) // 2)))
        rng = np.random.default_rng(draw(st_.integers(0, 2 ** 31)))
        e = rng.integers(0, n, size=(m, 2))
        e = e[e[:, 0] != e[:, 1]]
        e = np.unique(np.sort(e, axis=1), axis=0) if len(e) else \
            np.zeros((0, 2), np.int64)
        return e, n

    @given(random_graph())
    @settings(max_examples=25, deadline=None)
    def test_merger_depth_and_parent_chains_property(g):
        """Final depth ∈ {0,1,2} for every real vertex; every parent chain
        reaches a SUN in ≤ 2 hops (sun→itself, planet→sun, moon→planet→sun).
        Holds on arbitrary random graphs, isolated vertices included."""
        edges, n = g
        pg = build_graph(edges, n)
        stt = run_merger(pg, seed=3)
        state = np.asarray(stt.state)
        depth = np.asarray(stt.depth)
        parent = np.asarray(stt.parent)
        vm = np.asarray(pg.vmask)

        assert (state[vm] > 0).all()
        assert ((depth[vm] >= 0) & (depth[vm] <= 2)).all()
        for v in np.nonzero(vm)[0]:
            u, hops = int(v), 0
            while state[u] != SUN:
                u = int(parent[u])
                hops += 1
                assert hops <= 2, (v, hops)
                assert u < pg.n_pad and vm[u], (v, u)
            assert hops == depth[v], (v, hops, depth[v])

    @given(random_graph())
    @settings(max_examples=20, deadline=None)
    def test_merger_new_suns_independent_per_round_property(g):
        """Suns elected within one round form an independent set — even in
        desperation mode, two adjacent candidates cannot both survive the
        1-hop conflict broadcast (the larger id dominates)."""
        import jax
        import jax.numpy as jnp
        from repro.core.solar_merger import (init_state, sun_election,
                                             system_growth)
        edges, n = g
        pg = build_graph(edges, n)
        vm = np.asarray(pg.vmask)
        und = np.asarray(edges, np.int64).reshape(-1, 2)

        # replicate run_merger's control flow (incl. stall → desperation)
        stt = init_state(pg)
        key = jax.random.PRNGKey(11)
        prev_remaining, stalls, desperate = n + 1, 0, False
        for r in range(96):
            key, sub = jax.random.split(key)
            desperate = desperate or stalls >= 2
            forced = jnp.asarray(desperate or r % 4 == 3)
            suns_before = (np.asarray(stt.state) == SUN) & vm
            stt = sun_election(pg, stt, sub, jnp.asarray(0.35, jnp.float32),
                               forced, jnp.asarray(not desperate))
            new_sun = ((np.asarray(stt.state) == SUN) & vm) & ~suns_before
            if len(und):
                both = new_sun[und[:, 0]] & new_sun[und[:, 1]]
                assert not both.any(), und[both]
            stt = system_growth(pg, stt)
            remaining = int(((np.asarray(stt.state) == 0) & vm).sum())
            if remaining == 0:
                return
            stalls = 0 if remaining < prev_remaining else stalls + 1
            prev_remaining = remaining
        raise AssertionError("merger replica did not converge")

    @given(random_graph())
    @settings(max_examples=20, deadline=None)
    def test_next_level_conserves_mass_property(g):
        """Collapsing systems into suns conserves total vertex mass."""
        edges, n = g
        pg = build_graph(edges, n)
        stt = run_merger(pg, seed=5)
        cg, info = next_level(pg, stt)
        total = float(np.asarray(pg.mass)[np.asarray(pg.vmask)].sum())
        coarse = float(np.asarray(cg.mass)[np.asarray(cg.vmask)].sum())
        assert abs(total - coarse) < 1e-3 * max(total, 1.0), (total, coarse)
        # every valid vertex landed in exactly one system
        pc = info.parent_coarse[np.asarray(pg.vmask)]
        assert (pc >= 0).all() and (pc < cg.n).all()
