"""Continuous-batching layout service: deterministic-simulation suite.

Every scheduling behavior of serve/engine.py — admission order, deadline
expiry, priority preemption, backpressure, cancellation — is asserted
under a VirtualClock with scripted arrivals, so there is no timing slack
anywhere: the same trace replays to the same scheduling log, bit for bit.
Bit-parity tests (mid-flight joins, cancelled siblings, the hypothesis
interleaving property) run the REAL dispatch path and compare against
dedicated ``multigila_layout`` calls. Plus the fixed-window front door's
edge cases and the HTTP layer round-trip.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import urllib.request
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.core import LayoutConfig, bucketing, multigila_layout
from repro.graphs import generators as G
from repro.serve import LayoutService
from repro.serve.engine import (ContinuousLayoutService, DeadlineExceeded,
                                EngineBusy, EngineCore, SimEvent,
                                SystemClock, VirtualClock, null_dispatch,
                                poisson_trace, run_sim, validate_graph)

CFG = LayoutConfig(seed=0)


def path_graph(k: int):
    e = np.stack([np.arange(k - 1), np.arange(1, k)], 1).astype(np.int64)
    return e, k


def sim_core(**kw):
    kw.setdefault("dispatch", null_dispatch)
    kw.setdefault("clock", VirtualClock())
    return EngineCore(CFG, **kw)


def dedicated(edges, n, seed):
    pos, _ = multigila_layout(edges, n, dataclasses.replace(CFG, seed=seed))
    return np.asarray(pos, np.float32)


# -- the service boundary -------------------------------------------------------

def test_validate_graph_copies_and_checks():
    e = np.array([[0, 1], [1, 2]], dtype=np.int64)
    out, n = validate_graph(e, 3)
    assert out is not e and np.array_equal(out, e)
    e[:] = 0                                   # caller scribbles afterwards
    assert np.array_equal(out, [[0, 1], [1, 2]])
    with pytest.raises(ValueError):
        validate_graph(e, 0)
    with pytest.raises(ValueError):
        validate_graph([[0, 5]], 3)
    with pytest.raises(ValueError):
        validate_graph([[-1, 0]], 3)


def test_layout_service_mutation_after_submit():
    # regression: submit() must defensively copy — np.asarray aliases
    # same-dtype input, so scrambling the caller's array after submit used
    # to corrupt the in-flight batch
    e, n = G.delaunay(40, 3)
    ref = dedicated(e, n, CFG.seed)
    svc = LayoutService(CFG)
    try:
        fut = svc.submit(e, n)
        e[:] = 0                               # scramble while batch forms
        pos, _ = fut.result(300)
    finally:
        svc.close()
    assert np.array_equal(np.asarray(pos, np.float32), ref)


def test_continuous_service_mutation_after_submit():
    e, n = G.delaunay(40, 3)
    ref = dedicated(e, n, CFG.seed)
    svc = ContinuousLayoutService(CFG, max_lanes=4)
    try:
        req = svc.submit(e, n)
        e[:] = 0
        pos, _ = req.result(300)
    finally:
        svc.close()
    assert np.array_equal(np.asarray(pos, np.float32), ref)


# -- deterministic simulation: scheduling behaviors -----------------------------

def test_sim_admission_order_priority_deadline_fifo():
    core = sim_core(max_lanes=1)               # one admission at a time
    e, n = path_graph(8)
    core.submit(e, n)                          # rid 0: low priority
    core.submit(e, n, priority=2)              # rid 1: high, no deadline
    core.submit(e, n, priority=2, deadline_s=10.0)   # rid 2: high + deadline
    core.submit(e, n, priority=2)              # rid 3: high, later
    core.run_until_idle()
    admits = [rid for _, kind, rid, _ in core.log if kind == "admit"]
    # priority first, then earliest deadline, then submission order
    assert admits == [2, 1, 3, 0]
    assert core.counters["completed"] == 4


def test_sim_deadline_expiry_queued():
    core = sim_core(max_lanes=1)
    e, n = G.delaunay(60, 1)                   # several levels: stays running
    r0 = core.submit(e, n)
    core.tick()                                # r0 admitted, holds the lane
    r1 = core.submit(e, n, deadline_s=0.05)
    core.clock.advance(0.06)
    core.tick()
    assert r1.status == "expired"
    with pytest.raises(DeadlineExceeded):
        r1.result(0)
    assert any(k == "expire" and rid == r1.rid and ("where", "queued") in d
               for _, k, rid, d in core.log)
    core.run_until_idle()
    assert r0.status == "done"


def test_sim_deadline_expiry_running_frees_lane():
    core = sim_core(max_lanes=2)
    e, n = G.delaunay(60, 1)
    r0 = core.submit(e, n, deadline_s=0.05)
    r1 = core.submit(e, n, seed=7)
    core.tick()                                # both admitted, one wave each
    assert r0.status == "running"
    core.clock.advance(0.06)
    core.tick()
    assert r0.status == "expired"
    assert any(k == "expire" and rid == r0.rid and ("where", "running") in d
               for _, k, rid, d in core.log)
    core.run_until_idle()
    assert r1.status == "done"                 # sibling rode on unharmed
    assert core.stats()["lanes_live"] == 0


def test_sim_priority_preemption():
    # wave_lanes=1: only the most urgent lane rides each wave, so a
    # late high-priority request overtakes the one already mid-flight
    core = sim_core(max_lanes=4, wave_lanes=1)
    e, n = G.delaunay(60, 1)
    lo = core.submit(e, n)
    core.tick()                                # lo admitted, rides wave 1
    hi = core.submit(e, n, priority=5)
    core.run_until_idle()
    order = [rid for _, k, rid, _ in core.log if k == "complete"]
    assert order == [hi.rid, lo.rid]
    assert lo.status == hi.status == "done"


def test_sim_backpressure_rejection():
    core = sim_core(max_queue=2, max_lanes=1)
    e, n = path_graph(8)
    core.submit(e, n)
    core.submit(e, n)
    with pytest.raises(EngineBusy):
        core.submit(e, n)                      # queue full: bounced
    assert core.counters["rejected"] == 1
    assert any(k == "reject" for _, k, _, _ in core.log)
    core.run_until_idle()                      # the queued two still finish
    assert core.counters["completed"] == 2


def test_sim_cancel_queued_and_running():
    core = sim_core(max_lanes=1)
    e, n = G.delaunay(60, 1)
    r0 = core.submit(e, n)
    r1 = core.submit(e, n)
    core.tick()                                # r0 running, r1 queued
    assert core.cancel(r1)                     # queued: gone immediately
    assert r1.status == "cancelled"
    assert core.cancel(r0)                     # running: freed at boundary
    core.tick()
    assert r0.status == "cancelled"
    assert core.stats()["lanes_live"] == 0
    with pytest.raises(CancelledError):
        r0.result(0)
    assert not core.cancel(r0)                 # already finished


def test_sim_identical_log_for_same_trace():
    graphs = [path_graph(6), path_graph(12), G.delaunay(30, 2)]
    mk = lambda i, rng: graphs[i % len(graphs)]
    trace = poisson_trace(40.0, 14, mk, seed=5, priorities=(0, 1, 2),
                          deadline_s=0.4)
    trace += [SimEvent(t=0.08, kind="cancel", ref=2),
              SimEvent(t=0.15, kind="cancel", ref=9)]
    logs, counters = [], []
    for _ in range(2):
        core = sim_core(max_queue=4, max_lanes=2)   # small: forces rejects
        run_sim(core, trace)
        logs.append(list(core.log))
        counters.append(dict(core.counters))
    assert logs[0] == logs[1] and len(logs[0]) > 20
    assert counters[0] == counters[1]
    assert counters[0]["submitted"] == 14


def test_run_sim_requires_virtual_clock():
    core = EngineCore(CFG, clock=SystemClock(), dispatch=null_dispatch)
    with pytest.raises(TypeError):
        run_sim(core, [])


# -- bit-parity against the dedicated driver (real dispatch) --------------------

def test_mid_flight_join_bit_parity():
    clock = VirtualClock()
    core = EngineCore(CFG, clock=clock, max_lanes=8)
    g1, g2 = G.delaunay(50, 11), G.delaunay(72, 12)
    r1 = core.submit(*g1, seed=11)
    core.tick()                                # r1 already mid-hierarchy
    r2 = core.submit(*g2, seed=12)             # joins the next wave
    core.run_until_idle()
    for req, (e, n), seed in ((r1, g1, 11), (r2, g2, 12)):
        pos, _ = req.result(0)
        assert np.array_equal(np.asarray(pos, np.float32),
                              dedicated(e, n, seed)), \
            "mid-flight join changed a lane's arithmetic"


def test_cancel_frees_lanes_siblings_bit_identical():
    core = EngineCore(CFG, clock=VirtualClock(), max_lanes=8)
    graphs = [G.delaunay(50, 20), G.delaunay(72, 21), G.delaunay(50, 22)]
    reqs = [core.submit(e, n, seed=20 + i)
            for i, (e, n) in enumerate(graphs)]
    core.tick()                                # everyone mid-flight
    core.cancel(reqs[1])
    core.run_until_idle()
    assert reqs[1].status == "cancelled"
    assert core.stats()["lanes_live"] == 0
    for i in (0, 2):
        pos, _ = reqs[i].result(0)
        assert np.array_equal(np.asarray(pos, np.float32),
                              dedicated(*graphs[i], 20 + i)), \
            "cancelling a lane perturbed a sibling"


# -- property test: arbitrary interleavings keep bit-parity ---------------------
#
# With hypothesis installed the op sequences are drawn (and shrunk) by the
# library; without it, a seeded generator sweeps the same op space so the
# property is still exercised (the container has no hypothesis).

# mixed shape buckets: two pads, plus a disconnected graph (multi-lane job)
_POOL = [path_graph(6), G.delaunay(30, 1),
         (np.array([[0, 1], [1, 2], [2, 3], [4, 5], [5, 6]]), 7)]
_OP_KINDS = ("submit", "submit_deadline", "tick", "advance", "cancel")


def _random_ops(rng: np.random.RandomState) -> list:
    ops = []
    for _ in range(int(rng.randint(1, 13))):
        op = _OP_KINDS[int(rng.randint(len(_OP_KINDS)))]
        if op in ("submit", "submit_deadline"):
            arg = int(rng.randint(len(_POOL)))
        elif op == "advance":
            arg = int(rng.randint(1, 41))      # centiseconds
        else:
            arg = int(rng.randint(8))
        ops.append((op, arg))
    return ops


def _check_interleaving(ops):
    """Any submit/cancel/deadline-expiry interleaving: every request that
    COMPLETES is bit-identical to a dedicated run with the same seed."""
    core = EngineCore(CFG, clock=VirtualClock(), max_queue=8, max_lanes=4)
    handles = []
    for op, arg in ops:
        if op in ("submit", "submit_deadline"):
            e, n = _POOL[arg]
            try:
                handles.append(core.submit(
                    e, n, seed=len(handles),
                    deadline_s=0.1 if op == "submit_deadline" else None))
            except EngineBusy:
                pass
        elif op == "tick":
            core.tick()
        elif op == "advance":
            core.clock.advance(arg / 100.0)    # may blow deadlines: good
        elif op == "cancel" and handles:
            core.cancel(handles[arg % len(handles)])
    core.run_until_idle()
    assert core.stats()["lanes_live"] == 0
    for k, req in enumerate(handles):
        if req.status == "done":
            pos, _ = req.result(0)
            assert np.array_equal(np.asarray(pos, np.float32),
                                  dedicated(req.edges, req.n, k))
        elif req.status == "expired":
            with pytest.raises(DeadlineExceeded):
                req.result(0)
        else:
            assert req.status == "cancelled"
            with pytest.raises(CancelledError):
                req.result(0)


@pytest.mark.parametrize("seed", range(8))
def test_interleavings_keep_bit_parity(seed):
    _check_interleaving(_random_ops(np.random.RandomState(seed)))


try:
    from hypothesis import given, settings, strategies as st

    _OPS = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(0, len(_POOL) - 1)),
            st.tuples(st.just("submit_deadline"),
                      st.integers(0, len(_POOL) - 1)),
            st.tuples(st.just("tick"), st.just(0)),
            st.tuples(st.just("advance"), st.integers(1, 40)),
            st.tuples(st.just("cancel"), st.integers(0, 7)),
        ),
        min_size=1, max_size=12)

    @settings(max_examples=25, deadline=None)
    @given(ops=_OPS)
    def test_interleavings_keep_bit_parity_hypothesis(ops):
        _check_interleaving(ops)
except ImportError:                            # container has no hypothesis
    pass


# -- fixed-window batcher edge cases --------------------------------------------

def test_batcher_max_batch_one_and_zero_window():
    e, n = G.delaunay(40, 3)
    ref = dedicated(e, n, CFG.seed)
    svc = LayoutService(CFG, max_batch=1, window_s=0.0)
    try:
        futs = [svc.submit(e, n) for _ in range(3)]
        for f in futs:
            pos, _ = f.result(300)
            assert np.array_equal(np.asarray(pos, np.float32), ref)
    finally:
        svc.close()


def test_batcher_close_drains_pending():
    e, n = G.delaunay(40, 3)
    svc = LayoutService(CFG, max_batch=4, window_s=5.0)  # window outlives us
    futs = [svc.submit(e, n) for _ in range(3)]
    svc.close()                                # must flush, not drop
    for f in futs:
        pos, _ = f.result(0)
        assert np.asarray(pos).shape == (n, 2)


def test_batcher_submit_after_close_raises():
    e, n = G.delaunay(40, 3)
    svc = LayoutService(CFG)
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit(e, n)
    svc2 = ContinuousLayoutService(CFG)
    svc2.close()
    with pytest.raises(RuntimeError):
        svc2.submit(e, n)


# -- HTTP front door ------------------------------------------------------------

def test_http_round_trip():
    from repro.launch.service import make_server

    svc = ContinuousLayoutService(CFG, max_lanes=4)
    httpd = make_server(svc)
    host, port = httpd.server_address
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    base = f"http://{host}:{port}"
    try:
        e, n = G.delaunay(40, 3)
        body = json.dumps({"edges": e.tolist(), "n": int(n),
                           "seed": 9}).encode()
        with urllib.request.urlopen(f"{base}/layout", data=body,
                                    timeout=300) as resp:
            out = json.loads(resp.read())
        assert np.array_equal(np.asarray(out["pos"], np.float32),
                              dedicated(e, n, 9))
        with urllib.request.urlopen(f"{base}/healthz", timeout=30) as resp:
            assert json.loads(resp.read()) == {"ok": True}
        with urllib.request.urlopen(f"{base}/stats", timeout=30) as resp:
            stats = json.loads(resp.read())
        assert stats["engine"]["completed"] == 1
        assert "misses" in stats["compile_cache"]
        bad = json.dumps({"edges": [[0, 99]], "n": 3}).encode()
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{base}/layout", data=bad, timeout=30)
        assert ei.value.code == 400
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{base}/nowhere", data=b"{}", timeout=30)
        assert ei.value.code == 404
    finally:
        httpd.shutdown()
        svc.close()
