"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.nbody.kernel import nbody_repulsion_pallas
from repro.kernels.nbody.ref import nbody_repulsion_ref
from repro.kernels.neighbor_force.kernel import neighbor_repulsion_pallas
from repro.kernels.neighbor_force.ref import neighbor_repulsion_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@pytest.mark.parametrize("n,block", [(128, 128), (256, 128), (512, 256)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_nbody_kernel_sweep(n, block, dtype):
    rng = np.random.default_rng(n)
    pos = jnp.asarray(rng.random((n, 2)) * 10, dtype)
    mass = jnp.asarray(rng.random(n) + 0.5, dtype)
    vmask = jnp.asarray(rng.random(n) > 0.15)
    out = nbody_repulsion_pallas(pos, mass, vmask, 1.3, 0.8, 1e-2,
                                 block_rows=block, block_cols=block,
                                 interpret=True)
    ref = nbody_repulsion_ref(pos, mass, vmask, 1.3, 0.8, 1e-2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,K,block", [(128, 8, 128), (256, 32, 128),
                                       (384, 64, 128)])
def test_neighbor_kernel_sweep(n, K, block):
    rng = np.random.default_rng(K)
    pos = rng.random((n, 2)).astype(np.float32) * 5
    mass = (rng.random(n) + 0.5).astype(np.float32)
    vmask = rng.random(n) > 0.1
    nbr = rng.integers(0, n + 1, size=(n, K)).astype(np.int32)
    nmask = rng.random((n, K)) > 0.25
    w = np.where(vmask, mass, 0).astype(np.float32)
    pos_p = np.concatenate([pos, np.zeros((1, 2), np.float32)])
    w_p = np.concatenate([w, np.zeros(1, np.float32)])
    npos = pos_p[nbr]
    nw = np.where(nmask, w_p[nbr], 0).astype(np.float32)
    out = neighbor_repulsion_pallas(jnp.asarray(pos), jnp.asarray(npos),
                                    jnp.asarray(nw), 1.1, 0.9, 1e-2,
                                    block_rows=block, interpret=True)
    ref = neighbor_repulsion_ref(jnp.asarray(pos), jnp.asarray(mass),
                                 jnp.asarray(nbr), jnp.asarray(nmask),
                                 jnp.asarray(vmask), 1.1, 0.9, 1e-2)
    np.testing.assert_allclose(np.asarray(out) * vmask[:, None],
                               np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Sq,Sk,hd,bq,bk", [
    (2, 128, 128, 64, 128, 128),
    (1, 256, 256, 64, 128, 128),
    (2, 128, 256, 32, 128, 128),   # cross/cache: Sk > Sq
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Sk, hd, bq, bk, causal, dtype):
    if causal and Sk != Sq:
        pytest.skip("kernel causal mask assumes aligned q/k origins")
    rng = np.random.default_rng(Sq + Sk)
    q = jnp.asarray(rng.normal(size=(B, Sq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sk, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sk, hd)), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_gqa_wrapper_matches_model_sdpa(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.layers import _sdpa
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=True)
    o2 = _sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)
