"""Hypothesis property tests on the system's invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis",
                    reason="dev extra — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.graphs.graph import build_graph, push_max
from repro.core.solar_merger import run_merger, SUN
from repro.parallel.collectives import quantize_int8, dequantize_int8
from repro.launch.roofline import parse_module, analyze_text


@st.composite
def random_graph(draw, max_n=24):
    n = draw(st.integers(4, max_n))
    m = draw(st.integers(n - 1, min(3 * n, n * (n - 1) // 2)))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    e = rng.integers(0, n, size=(m, 2))
    e = e[e[:, 0] != e[:, 1]]
    e = np.unique(np.sort(e, axis=1), axis=0)
    return e, n


@given(random_graph())
@settings(max_examples=25, deadline=None)
def test_push_max_bounded_by_global_max(g):
    edges, n = g
    if len(edges) == 0:
        return
    pg = build_graph(edges, n)
    vals = jnp.asarray(np.arange(pg.n_pad), jnp.int32)
    out = np.asarray(push_max(pg, vals))
    # received max never exceeds the global max id and is -1 ⟺ isolated
    deg = np.asarray(pg.degrees())
    assert (out[:n] <= n - 1).all()
    assert ((out[:n] == -1) == (deg[:n] == 0)).all()


@given(random_graph(max_n=20))
@settings(max_examples=15, deadline=None)
def test_merger_total_assignment_property(g):
    edges, n = g
    if len(edges) == 0:
        return
    pg = build_graph(edges, n)
    stt = run_merger(pg, seed=0)
    state = np.asarray(stt.state)
    vm = np.asarray(pg.vmask)
    deg = np.asarray(pg.degrees())
    nonisolated = vm & (deg > 0)
    # every non-isolated vertex is assigned; sun pointers are suns
    assert (state[nonisolated] > 0).all()
    sun = np.asarray(stt.sun)
    assert (state[sun[nonisolated]] == SUN).all()


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1,
                max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_roundtrip_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    # symmetric per-tensor int8: error ≤ scale/2 everywhere
    assert (err <= float(s) * 0.5 + 1e-5).all()


@given(st.integers(1, 6), st.integers(8, 64), st.integers(8, 64))
@settings(max_examples=10, deadline=None)
def test_roofline_parser_dot_flops_exact(L, M, K):
    """Parsed dot FLOPs scale exactly with loop trip count × 2MNK."""
    M = (M // 8) * 8 or 8
    K = (K // 8) * 8 or 8

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((L, K, K), jnp.float32)).compile()
    cost = analyze_text(comp.as_text(), world=1)
    expect_dot = 2.0 * M * K * K * L
    assert cost.flops >= expect_dot * 0.99
    assert cost.flops <= expect_dot * 1.6 + 1e5  # + elementwise slack
