"""Layout serving subsystem: pyramid build, store round-trip, batched
query parity (bit-identical to the unpadded NumPy reference resolver),
and the micro-batching front door."""
import os

import numpy as np
import pytest

from repro.graphs import generators as G
from repro.core import multigila_layout, LayoutConfig
from repro.serve import (build_pyramid, save_pyramid, load_pyramid,
                         TileStore, QueryEngine, MicroBatcher,
                         reference_resolve, trim_result, band_for_zoom)
from repro.serve.query import random_viewports
from repro.serve.tiles import band_positions


@pytest.fixture(scope="module")
def layout_export():
    e, n = G.gnp(1500, 4.0, seed=0)
    cfg = LayoutConfig(seed=0, coarsest_iters=60, finest_iters=10)
    pos, stats, exp = multigila_layout(e, n, cfg, export=True)
    return e, n, pos, exp


@pytest.fixture(scope="module")
def pyramid(layout_export):
    _, _, _, exp = layout_export
    return build_pyramid(exp, tile_cap=32, edge_cap=48, max_zoom=6)


def test_export_structure(layout_export):
    e, n, pos, exp = layout_export
    assert exp.levels[0].n == n
    assert exp.pos.shape == (n, 2)
    np.testing.assert_array_equal(exp.levels[0].edges, e)
    sizes = [l.n for l in exp.levels]
    assert sizes == sorted(sizes, reverse=True)
    for b, lvl in enumerate(exp.levels[:-1]):
        assert lvl.parent.shape == (lvl.n,)
        nxt = exp.levels[b + 1].n
        assert lvl.parent.min() >= 0 and lvl.parent.max() < nxt
        # every coarse vertex has at least one member
        assert np.unique(lvl.parent).size == nxt
        assert lvl.rep.min() >= 0 and lvl.rep.max() < n
    assert exp.levels[-1].parent is None


def test_band_positions_are_member_centroids(layout_export):
    _, n, _, exp = layout_export
    pos, mass = band_positions(exp)
    # aggregate mass conserves the level-0 count at every band
    for m in mass:
        assert abs(float(m.sum()) - n) < 1e-3 * n
    # a coarse vertex with exactly one member sits on that member
    p = exp.levels[0].parent
    counts = np.bincount(p, minlength=exp.levels[1].n)
    singles = np.nonzero(counts == 1)[0][:5]
    for c in singles:
        member = int(np.nonzero(p == c)[0][0])
        np.testing.assert_allclose(pos[1][c], pos[0][member], atol=1e-5)


def test_pyramid_topk_by_mass(layout_export, pyramid):
    """Overfull tiles keep their heaviest vertices: min kept aggregate mass
    ≥ max dropped aggregate mass, per tile."""
    from repro.serve.tiles import tile_coords
    _, _, _, exp = layout_export
    pos, mass = band_positions(exp)
    checked = 0
    for band in pyramid.bands:
        b = band.level
        # no vertex appears in two tiles
        all_vid = band.tile_vid[band.tile_vid >= 0]
        assert len(all_vid) == len(np.unique(all_vid))
        over = np.nonzero(band.tile_total > band.tile_count)[0]
        if not len(over):
            continue
        tc = tile_coords(pos[b], pyramid.lo, pyramid.hi, band.zoom)
        tid = tc[:, 1].astype(np.int64) * band.tiles_per_axis + tc[:, 0]
        for t in over[:5]:
            members = np.nonzero(tid == t)[0]
            cnt = int(band.tile_count[t])
            kept = band.tile_vid[t][:cnt]
            assert len(members) == int(band.tile_total[t])
            dropped = np.setdiff1d(members, kept)
            assert mass[b][kept].min() >= mass[b][dropped].max() - 1e-6
            checked += 1
    if not checked:
        pytest.skip("no overfull tile at this size")


def test_store_roundtrip(pyramid, tmp_path):
    path = os.path.join(tmp_path, "pyr")
    save_pyramid(path, pyramid)
    pyr2 = load_pyramid(path, validate=True)
    assert np.array_equal(pyr2.lo, pyramid.lo)
    assert np.array_equal(pyr2.hi, pyramid.hi)
    assert len(pyr2.bands) == len(pyramid.bands)
    for b1, b2 in zip(pyramid.bands, pyr2.bands):
        assert b1.zoom == b2.zoom and b1.n == b2.n and b1.m == b2.m
        assert b1.level == b2.level
        for f in ("tile_vid", "tile_rep", "tile_pos", "tile_mass",
                  "tile_count", "tile_total", "tile_eid", "tile_epos",
                  "tile_ecount"):
            assert np.array_equal(getattr(b1, f), getattr(b2, f)), f


def test_store_lru_and_empty_tiles(pyramid, tmp_path):
    path = os.path.join(tmp_path, "pyr")
    save_pyramid(path, pyramid)
    store = TileStore(path, cache_tiles=4)
    # an absent tile resolves to the sentinel-filled empty tile
    bm = store.band_meta(0)
    G_ = 1 << bm["zoom"]
    present = store._present[0]
    absent = next(((tx, ty) for tx in range(G_) for ty in range(G_)
                   if (tx, ty) not in present), None)
    if absent is not None:
        t = store.tile(0, *absent)
        assert (t["vid"] == -1).all() and t["count"][0] == 0
    # LRU: repeated access hits, capacity bounds the cache
    some = sorted(present)[:6]
    for (tx, ty) in some:
        store.tile(0, tx, ty)
    assert len(store._cache) <= 4
    h0 = store.hits
    store.tile(0, *some[-1])
    assert store.hits == h0 + 1


def test_batched_query_matches_reference_bitwise(pyramid):
    """Acceptance: every request in a padded batch is bit-identical to the
    unpadded single-request NumPy resolver."""
    eng = QueryEngine(pyramid)
    zoom_max = max(b.zoom for b in pyramid.bands)
    B = 33                                # pads to a 64 bucket
    boxes, zs = random_viewports(pyramid.lo, pyramid.hi, zoom_max + 2, B,
                                 seed=7)
    # stress corners: full extent, degenerate point, fully outside
    boxes[0] = np.concatenate([pyramid.lo, pyramid.hi])
    zs[0] = 0
    boxes[1] = np.concatenate([pyramid.lo, pyramid.lo])
    boxes[2] = np.concatenate([pyramid.hi + 10, pyramid.hi + 11])
    out = eng.query(boxes, zs)
    n_nonempty = 0
    for i in range(B):
        got = trim_result(out, i)
        ref = reference_resolve(pyramid, boxes[i], int(zs[i]))
        assert got["band"] == ref["band"]
        assert got["covered"] == ref["covered"]
        for k in ("vid", "rep", "inside", "eid", "tiles"):
            assert np.array_equal(got[k], ref[k]), (i, k)
        for k in ("vpos", "epos", "vmass"):
            assert got[k].shape == ref[k].shape
            assert np.array_equal(
                np.asarray(got[k]).view(np.int32),
                np.asarray(ref[k]).view(np.int32)), (i, k)   # bitwise
        n_nonempty += len(got["vid"]) > 0
    assert n_nonempty >= B // 2


def test_cover_truncation_is_reported(pyramid):
    """A viewport needing more than MAX_TILES tiles is truncated row-major,
    and the result says so: covered (true wx·wy) exceeds len(tiles)."""
    from repro.serve import MAX_TILES
    eng = QueryEngine(pyramid)
    z_fine = pyramid.bands[0].zoom
    box = np.concatenate([pyramid.lo, pyramid.hi]).astype(np.float32)
    # full-extent box at the finest band's zoom → cover is the whole grid
    out = eng.query(box[None], np.asarray([z_fine + 1], np.int32))
    got = trim_result(out, 0)
    assert got["covered"] == (1 << z_fine) ** 2
    if got["covered"] > MAX_TILES:
        assert len(got["tiles"]) == MAX_TILES
    ref = reference_resolve(pyramid, box, z_fine + 1)
    assert ref["covered"] == got["covered"]


def test_band_selection_semantics(pyramid):
    zs = np.asarray([b.zoom for b in pyramid.bands])
    # zoom 0 (whole drawing) → coarsest band; huge zoom → finest band
    assert band_for_zoom(zs, np.asarray([0]))[0] == len(zs) - 1
    assert band_for_zoom(zs, np.asarray([zs[0] + 5]))[0] == 0
    # zooms are strictly decreasing → every stored band is selectable
    assert (np.diff(zs) < 0).all()
    selected = {int(band_for_zoom(zs, np.asarray([z]))[0])
                for z in range(zs[0] + 1)}
    assert selected == set(range(len(zs)))


def test_query_various_batch_buckets(pyramid):
    """Identical requests answer identically regardless of batch padding."""
    eng = QueryEngine(pyramid)
    zoom_max = max(b.zoom for b in pyramid.bands)
    boxes, zs = random_viewports(pyramid.lo, pyramid.hi, zoom_max, 5, seed=3)
    single = [trim_result(eng.query(boxes[i:i + 1], zs[i:i + 1]), 0)
              for i in range(5)]
    batched = eng.query(boxes, zs)
    for i in range(5):
        got = trim_result(batched, i)
        assert np.array_equal(got["vid"], single[i]["vid"])
        assert np.array_equal(got["eid"], single[i]["eid"])


def test_micro_batcher(pyramid):
    eng = QueryEngine(pyramid)
    zoom_max = max(b.zoom for b in pyramid.bands)
    boxes, zs = random_viewports(pyramid.lo, pyramid.hi, zoom_max, 16, seed=5)
    mb = MicroBatcher(eng, max_batch=16, window_s=0.02)
    futs = [mb.submit(boxes[i], int(zs[i])) for i in range(16)]
    res = [f.result(timeout=60) for f in futs]
    mb.close()
    assert mb.requests == 16
    for i in range(16):
        ref = reference_resolve(pyramid, boxes[i], int(zs[i]))
        assert np.array_equal(res[i]["vid"], ref["vid"])
    # coalescing happened: far fewer device batches than requests
    assert mb.batches <= 8


def test_batcher_close_rejects():
    e, n = G.grid(6, 6)
    pos, stats, exp = multigila_layout(e, n, LayoutConfig(seed=0),
                                       export=True)
    eng = QueryEngine(build_pyramid(exp, tile_cap=16, edge_cap=16))
    mb = MicroBatcher(eng)
    mb.close()
    with pytest.raises(RuntimeError):
        mb.submit(np.zeros(4), 0)
