"""Layout-quality regression gate.

``quality_report`` (NELD / CRE / sampled stress) on the CI-sized
RegularGraphs suite, asserted against recorded bounds. The bounds are the
values measured at the time this gate was recorded (PR 4, seed=0,
deterministic on the CPU backend) times a generous slack factor — future
PRs can refactor the driver freely but cannot *silently* degrade drawing
quality past the slack.

If a deliberate algorithm change moves a metric past its bound, re-record:

    PYTHONPATH=src python -m pytest tests/test_quality_regression.py -s \
        --tb=no  # the failure message prints measured vs bound
"""
import numpy as np
import pytest

from repro.graphs import generators as G, build_graph
from repro.graphs.metrics import quality_report
from repro.core import multigila_layout, LayoutConfig


# measured with LayoutConfig(seed=0) on the jax-cpu backend at record time;
# asserted with slack: neld ≤ 1.4·rec + 0.05, cre ≤ 1.5·rec + 0.1,
# stress ≤ 1.6·rec + 0.01
RECORDED = {
    "grid_8_8":   dict(neld=0.136, cre=0.000, stress=0.0226),
    "tree_3_3":   dict(neld=0.439, cre=0.000, stress=0.0830),
    "cyl_8_6":    dict(neld=0.198, cre=0.682, stress=0.0933),
    "sierp_3":    dict(neld=0.165, cre=0.000, stress=0.0115),
    "snow_3_2_1": dict(neld=0.401, cre=0.000, stress=0.0503),
    "spider_4_5": dict(neld=0.207, cre=0.154, stress=0.0511),
    "flower_4_5": dict(neld=0.521, cre=1.467, stress=0.0897),
    "rnd_64_4":   dict(neld=0.322, cre=4.065, stress=0.1827),
}

# the maxent-stress engine (core/stress.py) scored on the SAME suite, same
# seed/backend — recorded at PR 10. Stress wins NELD almost everywhere
# (meshes dramatically: grid 0.037 vs 0.136, cylinder 0.005 vs 0.198) and
# trades some CRE on the irregular graphs; the gate holds both engines to
# their own recorded envelope.
RECORDED_STRESS = {
    "grid_8_8":   dict(neld=0.037, cre=0.000, stress=0.0205),
    "tree_3_3":   dict(neld=0.354, cre=0.308, stress=0.0759),
    "cyl_8_6":    dict(neld=0.005, cre=0.818, stress=0.1118),
    "sierp_3":    dict(neld=0.108, cre=2.000, stress=0.1129),
    "snow_3_2_1": dict(neld=0.299, cre=0.000, stress=0.0379),
    "spider_4_5": dict(neld=0.002, cre=0.231, stress=0.0743),
    "flower_4_5": dict(neld=0.280, cre=3.533, stress=0.1158),
    "rnd_64_4":   dict(neld=0.058, cre=5.371, stress=0.1889),
}

SUITE = G.regulargraphs_suite(small=True)


def _check(name, e, n, engine, rec):
    pos, _ = multigila_layout(e, n, LayoutConfig(seed=0, engine=engine))
    g = build_graph(e, n)
    p = np.zeros((g.n_pad, 2), np.float32)
    p[:n] = pos
    rep = quality_report(g, p)
    bounds = dict(neld=1.4 * rec["neld"] + 0.05,
                  cre=1.5 * rec["cre"] + 0.1,
                  stress=1.6 * rec["stress"] + 0.01)
    for metric, bound in bounds.items():
        assert rep[metric] <= bound, (
            f"{name}.{metric} [{engine}] regressed: measured "
            f"{rep[metric]:.4f} > bound {bound:.4f} "
            f"(recorded {rec[metric]:.4f})")


@pytest.mark.parametrize("name,e,n", SUITE, ids=[s[0] for s in SUITE])
def test_quality_no_regression(name, e, n):
    _check(name, e, n, "gila", RECORDED[name])


@pytest.mark.parametrize("name,e,n", SUITE, ids=[s[0] for s in SUITE])
def test_quality_no_regression_stress(name, e, n):
    _check(name, e, n, "stress", RECORDED_STRESS[name])
