"""Multi-device SPMD tests — run in a subprocess with 8 host devices so the
main pytest process keeps its single-device jax config."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, extra_env: dict | None = None) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.update(extra_env or {})
    # every snippet builds meshes through the version-portable constructor
    prelude = "from repro.launch.mesh import make_compat_mesh\n"
    out = subprocess.run([sys.executable, "-c",
                          prelude + textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_nbody_matches_reference():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed as D
        from repro.graphs import generators as G
        from repro.graphs.graph import build_graph
        from repro.kernels.nbody.ref import nbody_repulsion_ref
        mesh = make_compat_mesh((4,2), ("data","model"))
        n_pad = 256
        e, n = G.grid(12, 12)
        g = build_graph(e, n, n_pad=n_pad)
        pos = np.random.default_rng(0).random((n_pad,2)).astype(np.float32)
        w = np.where(np.asarray(g.vmask), np.asarray(g.mass), 0).astype(np.float32)
        fn = D.sharded_nbody(mesh, n_pad)
        out = fn(jnp.asarray(pos), jnp.asarray(w),
                 jnp.asarray([1.,1.,1e-3], jnp.float32))
        ref = nbody_repulsion_ref(jnp.asarray(pos), g.mass, g.vmask, 1., 1., 1e-3)
        err = float(jnp.abs(jnp.where(g.vmask[:,None], out - ref, 0)).max())
        assert err < 1e-3, err
        print("OK", err)
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """Same params/batch → same loss with and without the mesh (GSPMD is
    numerically faithful for this model at f32)."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import init_params, loss_fn
        from repro.models.model import param_specs
        from repro.parallel.sharding import make_rules, use_shardings, param_shardings
        cfg = get_smoke_config("internlm2-1.8b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32)}
        l0, _ = jax.jit(lambda p,b: loss_fn(p, cfg, b))(params, batch)
        mesh = make_compat_mesh((4,2), ("data","model"))
        rules = make_rules(mesh, cfg)
        with use_shardings(mesh, rules):
            sh = param_shardings(mesh, rules, param_specs(cfg, rules))
            psh = jax.tree.map(lambda p, s: jax.device_put(p, s), params, sh)
            l1, _ = jax.jit(lambda p,b: loss_fn(p, cfg, b))(psh, batch)
        d = abs(float(l0) - float(l1))
        assert d < 2e-2, (float(l0), float(l1))
        print("OK", float(l0), float(l1))
    """)
    assert "OK" in out


def test_ring_collective_matmul_matches_allgather():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.parallel.collectives import ring_collective_matmul
        mesh = make_compat_mesh((1,8), ("data","model"))
        S, K, N = 64, 32, 48
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(S,K)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K,N)), jnp.float32)
        fn = jax.jit(ring_collective_matmul(mesh, "model"))
        y = fn(x, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_spinner_partition_improves_shuffled_cut():
    out = run_sub("""
        import numpy as np
        from repro.core.partition import spinner_partition, edge_cut
        from repro.graphs import generators as G
        from repro.graphs.graph import build_graph
        e, n = G.grid(24, 24)
        perm = np.random.default_rng(0).permutation(n)
        e2 = perm[e]
        g = build_graph(e2, n)
        blocked = np.minimum(np.arange(g.n_pad)*4//max(g.n,1), 3)
        labels = spinner_partition(g, 4, iters=48)
        c0, c1 = edge_cut(g, blocked), edge_cut(g, labels)
        assert c1 < c0 * 0.8, (c0, c1)
        print("OK", c0, c1)
    """)
    assert "OK" in out


def test_spinner_partition_respects_slack_capacity():
    """Regression for the unquotaed-flip overshoot: simultaneous label
    adoptions are now admitted against a per-label migration quota, so the
    max partition load stays ≤ floor(slack · n / P) at every slack tried
    (the docstring's 'balanced within slack' promise, previously false)."""
    import numpy as np
    from repro.core.partition import spinner_partition, edge_cut
    from repro.graphs import generators as G
    from repro.graphs.graph import build_graph

    e, n = G.grid(24, 24)
    perm = np.random.default_rng(0).permutation(n)
    g = build_graph(perm[e], n)
    vm = np.asarray(g.vmask)
    for P, slack, seed in [(4, 1.10, 0), (4, 1.03, 5), (8, 1.05, 2)]:
        labels = np.asarray(spinner_partition(g, P, iters=48, slack=slack,
                                              seed=seed))
        loads = np.bincount(labels[vm], minlength=P)
        cap = np.floor(slack * n / P)
        assert loads.max() <= cap, (P, slack, loads, cap)
    # and the quota must not cost the cut-quality contract
    blocked = np.minimum(np.arange(g.n_pad) * 4 // max(g.n, 1), 3)
    labels = spinner_partition(g, 4, iters=48)
    assert edge_cut(g, labels) < edge_cut(g, blocked) * 0.8


def test_shardmap_moe_matches_gspmd():
    """§Perf hillclimb B: the explicit shard_map MoE is numerically
    identical to the GSPMD-partitioned formulation."""
    out = run_sub("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.models import moe as MOE
        from repro.configs.base import MoEConfig
        from repro.parallel.sharding import make_rules, use_shardings
        mesh = make_compat_mesh((2,4), ("data","model"))
        m = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=2.0)
        p = MOE.init_moe(jax.random.PRNGKey(0), 32, m)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32), jnp.float32)
        rules = dataclasses.replace(make_rules(mesh, None), experts="model")
        with use_shardings(mesh, rules):
            y1, a1 = jax.jit(lambda p, x: MOE.apply_moe(p, x, m))(p, x)
            y2, a2 = jax.jit(lambda p, x: MOE.apply_moe_shardmap(p, x, m))(p, x)
        err = float(jnp.abs(y1 - y2).max())
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_a2a_moe_matches_reference():
    """§Perf hillclimb B iteration 3: EP-via-all-to-all MoE is exactly the
    reference MoE (dropless capacity)."""
    out = run_sub("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.models import moe as MOE
        from repro.configs.base import MoEConfig
        from repro.parallel.sharding import make_rules, use_shardings
        mesh = make_compat_mesh((2, 4), ("data", "model"))
        m = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=4.0)
        p = MOE.init_moe(jax.random.PRNGKey(0), 32, m)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32), jnp.float32)
        y1, _ = jax.jit(lambda p, x: MOE.apply_moe(p, x, m))(p, x)
        rules = dataclasses.replace(make_rules(mesh, None), experts="model",
                                    batch=("data","model"),
                                    moe_impl="all_to_all")
        with use_shardings(mesh, rules):
            y2, _ = jax.jit(lambda p, x: MOE.apply_moe_a2a(p, x, m))(p, x)
        err = float(jnp.abs(y1 - y2).max())
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_sharded_grid_force_matches_single_device():
    """Tentpole parity: the sharded grid repulsion (psum'd aggregates +
    all_gathered bucketed positions) matches single-device grid_repulsion
    within 1e-4 relative error — uniform AND cell-overflow inputs."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed as D
        from repro.kernels.grid_force.ops import (grid_repulsion, choose_grid,
                                                  bin_vertices)
        mesh = make_compat_mesh((4, 2), ("data", "model"))
        n_pad = 512
        rng = np.random.default_rng(0)
        params = jnp.asarray([1.2, 0.9, 1e-2], jnp.float32)
        # uniform case (with masked padding), then a tight cluster that
        # overflows its cell's bucket cap
        uni = (rng.random((n_pad, 2)) * 10).astype(np.float32)
        vmask = rng.random(n_pad) > 0.1
        uni = np.where(vmask[:, None], uni, 0.0).astype(np.float32)
        w_uni = np.where(vmask, rng.random(n_pad) + 0.5, 0.0)
        clu = np.concatenate([rng.normal(0, 0.05, (200, 2)),
                              rng.random((n_pad - 200, 2)) * 8])
        w_clu = rng.random(n_pad) + 0.5
        for name, pos, w in (("uniform", uni, w_uni),
                             ("overflow", clu, w_clu)):
            pos = jnp.asarray(pos, jnp.float32)
            w = jnp.asarray(w, jnp.float32)
            G, cap = choose_grid(n_pad)
            if name == "overflow":
                _, _, inb = bin_vertices(pos, w > 0, G, cap)
                assert int((~np.asarray(inb)).sum()) > 50   # caps overflowed
            fn = D.sharded_grid_force(mesh, n_pad, G, cap)
            got = np.asarray(fn(pos, w, params))
            ref = grid_repulsion(pos, w, w > 0, 1.2, 0.9, 1e-2,
                                 grid_dim=G, cell_cap=cap)
            ref = np.asarray(jnp.where((w > 0)[:, None], ref, 0.0))
            rel = np.abs(got - ref).max() / np.abs(ref).max()
            assert rel < 1e-4, (name, rel)
            print("OK", name, rel)
    """)
    assert out.count("OK") == 2


def test_sharded_grid_force_halo_matches_under_band_partition():
    """Halo variant: exchanging only the two boundary-cell bucket rows
    reproduces grid_repulsion when each shard's vertices sit in its grid
    row band — including a bucket-overflow cluster inside one band."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed as D
        from repro.kernels.grid_force.ops import grid_repulsion, bin_vertices
        mesh = make_compat_mesh((4, 2), ("data", "model"))
        n_pad, vsize = 512, 4
        n_loc = n_pad // vsize
        G, cap = 8, 16                       # G % vsize == 0 (band contract)
        rng = np.random.default_rng(1)
        # device d's block lies in grid rows [2d, 2d+2) of a [0,10)² box
        pos = np.zeros((n_pad, 2), np.float32)
        for d in range(vsize):
            ylo, yhi = d * 2.5 + 0.05, (d + 1) * 2.5 - 0.05
            blk = rng.random((n_loc, 2)).astype(np.float32)
            pos[d*n_loc:(d+1)*n_loc, 0] = blk[:, 0] * 10
            pos[d*n_loc:(d+1)*n_loc, 1] = ylo + blk[:, 1] * (yhi - ylo)
        pos[0] = (0.0, 0.0); pos[-1] = (10.0, 10.0)   # pin the bbox
        # overflow: cram 40 > cap vertices of block 1 into one cell
        pos[n_loc:n_loc + 40] = (5.2, 3.1) + \\
            rng.normal(0, 0.02, (40, 2)).astype(np.float32)
        w = (rng.random(n_pad) + 0.5).astype(np.float32)
        params = jnp.asarray([1.2, 0.9, 1e-2], jnp.float32)
        _, _, inb = bin_vertices(jnp.asarray(pos), jnp.ones(n_pad, bool),
                                 G, cap)
        assert int((~np.asarray(inb)).sum()) > 10
        fn = D.sharded_grid_force(mesh, n_pad, G, cap, variant="halo")
        got = np.asarray(fn(jnp.asarray(pos), jnp.asarray(w), params))
        ref = np.asarray(grid_repulsion(jnp.asarray(pos), jnp.asarray(w),
                                        jnp.ones(n_pad, bool), 1.2, 0.9,
                                        1e-2, grid_dim=G, cell_cap=cap))
        rel = np.abs(got - ref).max() / np.abs(ref).max()
        assert rel < 1e-4, rel
        print("OK", rel)
        # band-contract violation degrades gracefully: the violator is
        # reclassified as overflow (softened far-field forces, mass kept
        # for its neighbors), everyone else stays on the single-device op
        pos[5] = (5.0, 9.0)              # stored on shard 0, sits in band 3
        got = np.asarray(fn(jnp.asarray(pos), jnp.asarray(w), params))
        ref = np.asarray(grid_repulsion(jnp.asarray(pos), jnp.asarray(w),
                                        jnp.ones(n_pad, bool), 1.2, 0.9,
                                        1e-2, grid_dim=G, cell_cap=cap))
        assert np.isfinite(got).all()
        assert np.linalg.norm(got[5]) > 0.1 * np.linalg.norm(ref[5])
        others = np.delete(np.arange(n_pad), 5)
        rel = np.abs(got[others] - ref[others]).max() / np.abs(ref).max()
        assert rel < 0.05, rel
        print("OK violation", rel)
    """)
    assert out.count("OK") == 2


def test_layout_grid_step_lowers_and_matches():
    """Acceptance: layout_train_step(mode="grid") lowers under shard_map on
    a 4-vertex-shard mesh and one superstep equals the single-device update
    built from grid_repulsion, within 1e-4 relative error."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import layout_train_step, layout_step_specs
        from repro.kernels.grid_force.ops import grid_repulsion, choose_grid
        mesh = make_compat_mesh((4, 2), ("data", "model"))
        n_pad, m_pad = 512, 64
        G, cap = choose_grid(n_pad)
        rng = np.random.default_rng(3)
        pos = (rng.random((n_pad, 2)) * 10).astype(np.float32)
        w = (rng.random(n_pad) + 0.5).astype(np.float32)
        nbr = np.full((n_pad, 1), n_pad, np.int32)
        # no edges → the superstep is repulsion + clamped update only
        src = np.full(m_pad, n_pad, np.int32)
        dst_l = np.zeros(m_pad, np.int32)
        emask = np.zeros(m_pad, bool)
        ewt = np.ones(m_pad, np.float32)
        params = jnp.asarray([1.2, 0.9, 1e-2], jnp.float32)
        temp = jnp.asarray(0.5, jnp.float32)
        step, sh = layout_train_step(mesh, n_pad, m_pad, 1, mode="grid",
                                     grid_dim=G, cell_cap=cap)
        specs = layout_step_specs(n_pad, m_pad, 1, mode="grid")
        lowered = jax.jit(step, in_shardings=(
            sh["pos"], sh["w"], sh["nbr_idx"], sh["edge"], sh["edge"],
            sh["edge"], sh["edge"], sh["scalar"], sh["scalar"])).lower(
            specs["pos"], specs["w"], specs["nbr_idx"], specs["src"],
            specs["dst_local"], specs["emask"], specs["ewt"],
            specs["params"], specs["temp"])
        lowered.compile()                    # sharding config is coherent
        got = np.asarray(jax.jit(step)(pos, w, nbr, src, dst_l, emask, ewt,
                                       params, temp))
        f = grid_repulsion(jnp.asarray(pos), jnp.asarray(w),
                           jnp.ones(n_pad, bool), 1.2, 0.9, 1e-2,
                           grid_dim=G, cell_cap=cap)
        norm = jnp.sqrt(jnp.sum(f * f, 1) + 1e-12)
        ref = np.asarray(pos + f / norm[:, None]
                         * jnp.minimum(norm, temp)[:, None])
        rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
        assert rel < 1e-4, rel
        print("OK", rel)
    """)
    assert "OK" in out


def test_multigila_dist_engine_end_to_end():
    """engine="multigila_dist": the full multilevel pipeline with every
    level refined by the sharded superstep (exact/neighbor/grid by size)
    produces a finite layout that untangles the graph."""
    out = run_sub("""
        import numpy as np
        from repro.graphs import generators as G
        from repro.graphs.graph import build_graph
        from repro.graphs.metrics import sampled_stress
        from repro.core import multigila_layout, LayoutConfig
        from repro.core.gila import random_init
        edges, n = G.grid(18, 18)
        pos, stats = multigila_layout(edges, n, LayoutConfig(
            seed=0, engine="multigila_dist", mesh_shape=(4, 2)))
        assert np.isfinite(pos).all()
        g = build_graph(edges, n)
        p0 = np.asarray(random_init(g, 6.0, 0))[:n]
        s0, s1 = sampled_stress(p0, edges, n), sampled_stress(pos, edges, n)
        assert s1 < s0 * 0.5, (s0, s1)
        print("OK", stats.levels, s0, s1)
    """, extra_env={"JAX_TRANSFER_GUARD": "disallow"})
    # the guard proves the sharded hot path does no implicit host<->device
    # hops: every intentional one sits in a utils/transfer.io_boundary()
    assert "OK" in out


def test_multigila_dist_stress_engine_end_to_end():
    """driver="multigila_dist" × engine="stress": every level refined by
    the sharded maxent-stress superstep (its extra annealing scalar staged
    per iteration) produces a finite layout that untangles the graph."""
    out = run_sub("""
        import numpy as np
        from repro.graphs import generators as G
        from repro.graphs.graph import build_graph
        from repro.graphs.metrics import sampled_stress
        from repro.core import multigila_layout, LayoutConfig
        from repro.core.gila import random_init
        edges, n = G.grid(18, 18)
        pos, stats = multigila_layout(edges, n, LayoutConfig(
            seed=0, driver="multigila_dist", engine="stress",
            mesh_shape=(4, 2)))
        assert np.isfinite(pos).all()
        g = build_graph(edges, n)
        p0 = np.asarray(random_init(g, 6.0, 0))[:n]
        s0, s1 = sampled_stress(p0, edges, n), sampled_stress(pos, edges, n)
        assert s1 < s0 * 0.5, (s0, s1)
        print("OK", stats.levels, s0, s1)
    """, extra_env={"JAX_TRANSFER_GUARD": "disallow"})
    assert "OK" in out


def test_layout_halo_step_runs():
    """§Perf hillclimb C: halo-exchange superstep compiles and matches the
    all-gather superstep when every neighbor is covered by the halo."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import (layout_train_step,
                                            layout_train_step_halo)
        mesh = make_compat_mesh((4, 2), ("data", "model"))
        n_pad, cap = 64, 8
        vsize, n_loc = 4, 16
        halo = n_loc                     # full halo → exactly the AG step
        rng = np.random.default_rng(0)
        pos = rng.random((n_pad, 2)).astype(np.float32)
        w = np.ones(n_pad, np.float32)
        params = jnp.asarray([1., 1., 1e-2], jnp.float32)
        temp = jnp.asarray(0.5, jnp.float32)
        # global neighbor list: each vertex talks to 8 random others
        nbr = rng.integers(0, n_pad, (n_pad, cap)).astype(np.int32)
        # no edges (pure repulsion) keeps the remap simple
        m_pad = 8
        src = np.full(m_pad, n_pad, np.int32); dst_l = np.zeros(m_pad, np.int32)
        emask = np.zeros(m_pad, bool); ewt = np.ones(m_pad, np.float32)

        step, sh = layout_train_step(mesh, n_pad, m_pad, cap, mode="neighbor")
        out1 = jax.jit(step)(pos, w, nbr, src, dst_l, emask, ewt, params, temp)

        # halo version: send_idx[d][p] = all local indices (full halo);
        # remap neighbor ids: owner o, local l → if o == self: l
        # else n_loc + recv_slot(o, l) with recv layout [peer, halo]
        send_idx = np.tile(np.arange(n_loc, dtype=np.int32), (vsize*vsize, 1))
        nbr_local = np.zeros_like(nbr)
        for v in range(n_pad):
            me = v // n_loc
            for j in range(cap):
                u = nbr[v, j]; o, l = u // n_loc, u % n_loc
                nbr_local[v, j] = l if o == me else n_loc + o * n_loc + l
        step2, sh2 = layout_train_step_halo(mesh, n_pad, m_pad, cap, halo)
        out2 = jax.jit(step2)(pos, w, nbr_local, send_idx, src, dst_l,
                              emask, ewt, params, temp)
        err = float(jnp.abs(out1 - out2).max())
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_reference():
    """GPipe over the pod axis equals the plain forward, and jax.grad
    differentiates through the pipeline (reverse schedule for free).
    f32 activations: XLA:CPU crashes on bf16 inside partial-manual regions
    (TPU-native bf16 is unaffected) — see parallel/pipeline.py."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import init_params, forward
        from repro.parallel.pipeline import pipeline_forward
        from repro.parallel.sharding import make_rules, use_shardings
        mesh = make_compat_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_smoke_config("internlm2-1.8b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)),
                                       jnp.int32)}
        ref, _ = forward(params, cfg, batch)
        rules = make_rules(mesh, cfg)
        with use_shardings(mesh, rules):
            pp = jax.jit(lambda p, b: pipeline_forward(p, cfg, b, mesh,
                                                       n_microbatches=4))
            got = pp(params, batch)
            err = float(jnp.abs(np.asarray(ref, np.float32)
                                - np.asarray(got, np.float32)).max())
            assert err < 0.05, err
            # grads flow through the pipeline (reverse schedule)
            def loss(p):
                lg = pipeline_forward(p, cfg, batch, mesh, n_microbatches=4)
                return jnp.sum(lg.astype(jnp.float32) ** 2) * 1e-6
            g = jax.jit(jax.grad(loss))(params)
            gn = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
                     for x in jax.tree.leaves(g["groups"]))
            assert gn > 0
        print("OK", err, gn)
    """, extra_env={"REPRO_ACT_DTYPE": "float32"})
    assert "OK" in out


def test_ring_attention_matches_sdpa():
    """Context parallelism: ring attention (seq-sharded, ppermute KV ring,
    streaming softmax) equals the reference SDPA, causal and full, f32+bf16."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.parallel.ring_attention import ring_attention
        from repro.models.layers import _sdpa
        mesh = make_compat_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        B, S, H, KV, hd = 2, 256, 4, 2, 32
        for dtype, tol in ((jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)):
            q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
            k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
            v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
            for causal in (True, False):
                fn = jax.jit(ring_attention(mesh, causal=causal))
                got = fn(q, k, v)
                ref = _sdpa(q, k, v, causal=causal)
                err = float(jnp.abs(got.astype(jnp.float32)
                                    - ref.astype(jnp.float32)).max())
                assert err < tol, (dtype, causal, err)
        print("OK")
    """)
    assert "OK" in out


def test_small_mesh_dryrun_decode():
    """decode_step lowers+compiles on an 8-device mesh with sharded caches —
    the fast version of the production dry-run."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from functools import partial
        from repro.configs import get_smoke_config, SHAPES
        from repro.models import model as M
        from repro.parallel.sharding import make_rules, use_shardings
        cfg = get_smoke_config("gemma-2b")
        mesh = make_compat_mesh((4,2), ("data","model"))
        rules = make_rules(mesh, cfg)
        B, cache = 8, 256
        params_struct = jax.eval_shape(partial(M.init_params, cfg),
                                       jax.random.PRNGKey(0))
        state_struct = jax.eval_shape(partial(M.init_decode_state, cfg, B, cache))
        with use_shardings(mesh, rules):
            def step(params, tok, state, pos):
                return M.decode_step(params, cfg, tok, state, pos)
            lowered = jax.jit(step).lower(
                params_struct,
                jax.ShapeDtypeStruct((B,1), jnp.int32),
                state_struct, jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
            print("OK", compiled.memory_analysis().temp_size_in_bytes)
    """)
    assert "OK" in out
