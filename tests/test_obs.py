"""Observability layer tests (DESIGN.md §12): tracer fast path and export
determinism, metrics registry + Prometheus exposition, padding-occupancy
hand checks, the PHASES thread-safety fix, and the engine stats snapshot."""
import json
import threading
import urllib.request

import numpy as np
import pytest

from repro.core import LayoutConfig, bucketing
from repro.core.schedule import make_schedule
from repro.graphs import generators as G
from repro.graphs.graph import build_graph, bucket_pad
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.clock import SystemClock, VirtualClock
from repro.serve.engine import (EngineCore, SimEvent, null_dispatch, run_sim)


# -- tracer basics -------------------------------------------------------------

def test_disabled_tracer_emits_nothing_and_allocates_no_contexts():
    tr = obs_trace.Tracer()
    assert not tr.enabled
    # the fast path returns ONE shared nullcontext — identity, not just
    # equality — so a disabled span costs no allocation
    assert tr.span("a") is tr.span("b", x=1)
    with tr.span("a"):
        pass
    tr.instant("i", x=1)
    tr.counter("c", 3)
    tr.complete("r", 0.0, 1.0)
    assert len(tr) == 0
    # the module-level hooks share the same fast path object
    assert not obs_trace.TRACER.enabled
    assert obs_trace.span("a") is tr.span("b")


def test_span_nesting_and_export_shape():
    vc = VirtualClock()
    tr = obs_trace.Tracer(clock=vc, enabled=True)
    with tr.span("outer", cat="host", level=1):
        vc.advance(1.0)
        with tr.span("inner", key=(64, 512)):
            vc.advance(0.5)
    tr.instant("mark", ts=0.25, rid=3)
    tr.counter("depth", 2, ts=0.25)
    d = tr.to_dict()
    evs = d["traceEvents"]
    assert all(e["pid"] == 1 for e in evs)
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    by_name = {e["name"]: e for e in evs if e["ph"] != "M"}
    # inner closed first; both carry µs timestamps and durations
    assert by_name["inner"]["ts"] == 1.0e6
    assert by_name["inner"]["dur"] == 0.5e6
    assert by_name["outer"]["ts"] == 0.0
    assert by_name["outer"]["dur"] == 1.5e6
    assert by_name["outer"]["args"] == {"level": 1}
    assert by_name["inner"]["args"] == {"key": [64, 512]}  # json-safe tuples
    assert by_name["mark"]["ph"] == "i" and by_name["mark"]["ts"] == 0.25e6
    assert by_name["depth"]["ph"] == "C"
    json.loads(tr.json_bytes())             # valid JSON document


def test_tracer_thread_tracks_use_names_not_os_ids():
    tr = obs_trace.Tracer(clock=VirtualClock(), enabled=True)

    def work():
        tr.instant("from-worker")

    t = threading.Thread(target=work, name="engine-worker")
    t.start()
    t.join()
    tr.instant("from-main")
    evs = tr.to_dict()["traceEvents"]
    names = {e["args"]["name"]: e["tid"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert set(names) == {"engine-worker", "MainThread"}
    by = {e["name"]: e for e in evs if e["ph"] == "i"}
    assert by["from-worker"]["tid"] == names["engine-worker"]
    assert by["from-main"]["tid"] == names["MainThread"]


# -- metrics registry ----------------------------------------------------------

def test_registry_families_and_prometheus_text():
    r = obs_metrics.Registry()
    c = r.counter("t_hits_total", "hits", "")
    c.inc(); c.inc(2, kind="warm")
    g = r.gauge("t_ratio", "a ratio", "ratio")
    g.set(0.5, bucket="n64")
    h = r.histogram("t_lat_seconds", "latency", "seconds", buckets=(0.1, 1.0))
    h.observe(0.05); h.observe(0.5); h.observe(2.0)
    cb = r.gauge("t_live", "callback", fn=lambda: 7)
    assert c.value() == 1.0 and c.value(kind="warm") == 2.0
    assert cb.value() == 7.0
    st = h.stats()
    assert st["count"] == 3 and st["sum"] == pytest.approx(2.55)
    assert st["buckets"] == {"0.1": 1, "1": 2}      # cumulative
    text = r.to_prometheus()
    assert "# TYPE t_hits_total counter" in text
    assert 't_hits_total{kind="warm"} 2' in text
    assert 't_ratio{bucket="n64"} 0.5' in text
    assert 't_lat_seconds_bucket{le="+Inf"} 3' in text
    assert "t_lat_seconds_count 3" in text
    assert "t_live 7" in text
    # registration is idempotent; re-registering returns the same family
    assert r.counter("t_hits_total") is c
    # snapshot is JSON-able and reset zeroes values but keeps families
    json.dumps(r.snapshot())
    r.reset()
    assert c.value(kind="warm") == 0.0 and r.get("t_lat_seconds") is h
    assert cb.value() == 7.0                        # callbacks survive reset


def test_phase_times_is_thread_safe():
    """The PR 7 race regression: concurrent PHASES.add from many threads
    must lose no update (the old dict read-modify-write could)."""
    before = bucketing.PHASES.snapshot().get("hammer", 0.0)
    N, K = 8, 2000

    def work():
        for _ in range(K):
            bucketing.PHASES.add("hammer", 1.0)

    ts = [threading.Thread(target=work) for _ in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    after = bucketing.PHASES.snapshot()["hammer"]
    assert after - before == N * K                  # 1.0 sums are exact


# -- padding occupancy ---------------------------------------------------------

def _path_request(n, seed=0):
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    g = build_graph(edges, n, bucket=True)
    sched = make_schedule(0, 1, g.n, g.m, exact_threshold=2048,
                          grid_threshold=32768, coarsest_iters=5,
                          ideal_len=1.0, n_pad=g.n_pad)
    pos0 = np.zeros((g.n_pad, 2), np.float32)
    return bucketing.make_request(g, pos0, sched, seed), edges


def test_padding_occupancy_gauges_match_hand_computed():
    """Mixed-bucket 3-graph wave: two paths share the n64 lane bucket, the
    third lands in n128; the gauges must equal true/padded exactly."""
    (r1, e1), (r2, e2), (r3, e3) = (_path_request(10), _path_request(20),
                                    _path_request(100))
    assert bucketing.group_key(r1) == bucketing.group_key(r2)
    assert bucketing.group_key(r3) != bucketing.group_key(r1)

    bucketing.refine_level_many([r1, r2], ideal_len=1.0, rep_const=1.0)
    lanes = 8                                       # lane_bucket(2, 8)
    n_pad, m_pad = r1.g.n_pad, r1.g.m_pad
    assert (n_pad, m_pad) == (bucket_pad(10, 64), bucket_pad(2 * 9, 512))
    occ_v = obs_metrics.REGISTRY.get("gila_wave_padding_occupancy_vertices")
    occ_e = obs_metrics.REGISTRY.get("gila_wave_padding_occupancy_edges")
    occ_l = obs_metrics.REGISTRY.get("gila_wave_lane_occupancy")
    b = f"n{n_pad}_e{m_pad}"
    assert occ_v.value(bucket=b) == (10 + 20) / (lanes * n_pad)
    assert occ_e.value(bucket=b) == (2 * 9 + 2 * 19) / (lanes * m_pad)
    assert occ_l.value(bucket=b) == 2 / lanes

    bucketing.refine_level_many([r3], ideal_len=1.0, rep_const=1.0)
    b3 = f"n{r3.g.n_pad}_e{r3.g.m_pad}"
    assert r3.g.n_pad == 128
    assert occ_v.value(bucket=b3) == 100 / (8 * r3.g.n_pad)
    assert occ_l.value(bucket=b3) == 1 / 8


# -- sim trace replay determinism ----------------------------------------------

def _scripted_events():
    out = []
    for i in range(5):
        e, n = G.gnp(24 + 4 * i, 2.0, 50 + i)
        out.append(SimEvent(t=0.02 * i, edges=e, n=n, seed=i,
                            priority=i % 2))
    # one doomed request: deadline already passed at delivery
    e, n = G.gnp(30, 2.0, 99)
    out.append(SimEvent(t=0.01, edges=e, n=n, seed=9, deadline_s=0.0))
    return out


def _run_traced_sim():
    vc = VirtualClock()
    tr = obs_trace.Tracer(clock=vc, enabled=True)
    core = EngineCore(LayoutConfig(seed=0), clock=vc, max_lanes=4,
                      wave_lanes=2, dispatch=null_dispatch, tracer=tr)
    run_sim(core, _scripted_events())
    return core, tr


def test_sim_trace_replays_byte_identical():
    core1, tr1 = _run_traced_sim()
    core2, tr2 = _run_traced_sim()
    assert core1.log == core2.log
    b1, b2 = tr1.json_bytes(), tr2.json_bytes()
    assert len(tr1) > 10
    assert b1 == b2, "sim trace is not replay-deterministic"
    names = {e["name"] for e in json.loads(b1)["traceEvents"]}
    # the scheduling log, wave spans, per-lane refine spans, and request
    # lifetimes all ride one timeline
    for expected in ("engine.submit", "engine.admit", "engine.complete",
                     "engine.expire", "wave", "refine.group", "refine",
                     "request", "engine.queue_depth"):
        assert expected in names, (expected, names)


def test_engine_stats_snapshot_against_scripted_trace():
    """EngineCore.stats(): counters, queue-depth high-water mark, and the
    atomically-taken metrics snapshot agree with the scripted run."""
    fam = obs_metrics.REGISTRY.get("gila_engine_requests_total")
    before = {k: v for k, v in fam.values().items()}
    core, _ = _run_traced_sim()
    s = core.stats()
    assert s["completed"] == 5 and s["expired"] == 1
    assert s["queued"] == 0 and s["running"] == 0
    assert s["queue_depth_hwm"] >= 1
    assert s["straggler_waves"] == 0        # VirtualClock waves take 0s
    snap = s["metrics"]["gila_engine_requests_total"]["values"]
    for event, want in (("submitted", 6), ("completed", 5), ("expired", 1)):
        key = (("event", event),)
        delta = snap[f'event="{event}"'] - before.get(key, 0.0)
        assert delta == want, (event, delta)
    # the snapshot is JSON-able end-to-end (it rides /stats and BENCH json)
    json.dumps(s["metrics"])


# -- HTTP: /metrics round trip -------------------------------------------------

def test_prometheus_endpoint_round_trip():
    from repro.launch.service import make_server
    from repro.serve.engine import ContinuousLayoutService

    svc = ContinuousLayoutService(LayoutConfig(seed=0), max_lanes=4)
    httpd = make_server(svc)
    host, port = httpd.server_address
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    try:
        e, n = G.delaunay(80, 3)
        pos, _ = svc.layout(e, n, timeout=600)
        assert pos.shape == (n, 2)
        with urllib.request.urlopen(f"http://{host}:{port}/metrics",
                                    timeout=60) as resp:
            assert resp.headers["Content-Type"].startswith("text/plain")
            text = resp.read().decode()
    finally:
        httpd.shutdown()
        svc.close()
    # every sample line parses as <name>[{labels}] <float>
    samples = {}
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name_part, value = line.rsplit(" ", 1)
        float(value)
        samples[name_part] = float(value)
    prefixed = [k for k in samples if k.startswith("gila_")]
    assert prefixed, text[:400]
    # the acceptance series: cache hit/miss and padding occupancy
    assert samples["gila_compile_cache_misses_total"] >= 1
    assert "gila_compile_cache_hits_total" in samples
    occ = {k: v for k, v in samples.items()
           if k.startswith("gila_wave_padding_occupancy_vertices")}
    assert occ and all(0.0 < v <= 1.0 for v in occ.values()), occ
    assert any(k.startswith("gila_engine_requests_total") for k in samples)
    assert any(k.startswith("gila_request_latency_seconds_bucket")
               for k in samples)
