"""Optimizer, checkpointing (incl. fault injection), gradient compression."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train.optim import AdamWConfig, init_opt_state, apply_updates, lr_at
from repro.ckpt import (CheckpointManager, save_checkpoint,
                        restore_checkpoint, latest_step)
from repro.parallel.collectives import (quantize_int8, dequantize_int8,
                                        compress_grads, decompress_grads,
                                        init_error_state)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = init_opt_state(cfg, params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, st, m = apply_updates(cfg, params, g, st)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0          # warmup
    assert lrs[100] == pytest.approx(0.1, rel=0.05)  # decay floor


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    back = restore_checkpoint(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(10))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_corruption_detected_and_skipped(tmp_path):
    tree = {"a": jnp.arange(16, dtype=jnp.float32)}
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save_async(1, tree)
    mgr.save_async(2, jax.tree.map(lambda x: x + 1, tree))
    mgr.wait()
    # corrupt the newest checkpoint (simulated node failure mid-write)
    with open(os.path.join(str(tmp_path), "step_2", "a.npy"), "wb") as f:
        f.write(b"garbage")
    step, back = mgr.restore_latest(tree)
    assert step == 1                       # fell back to the older valid one
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(16))
    mgr.close()


def test_partial_tmp_checkpoint_ignored(tmp_path):
    tree = {"a": jnp.zeros(4)}
    save_checkpoint(str(tmp_path), 3, tree)
    os.makedirs(os.path.join(str(tmp_path), "step_9.tmp"))
    assert latest_step(str(tmp_path)) == 3


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint written unsharded restores onto an explicit device set —
    the reshard-on-load path used when the mesh shape changes."""
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, tree)
    dev = jax.devices()[0]
    sh = {"w": jax.sharding.SingleDeviceSharding(dev)}
    back = restore_checkpoint(str(tmp_path), 1, tree, shardings=sh)
    assert back["w"].sharding == sh["w"]


def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)) * 3)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_small_grads():
    """EF property: a constant gradient smaller than one quantization step
    still gets applied over time (error carries over, never lost) — the
    cumulative transmitted value stays within ONE quantum of the truth."""
    g = {"w": jnp.full((8,), 1e-3)}
    # one large component forces a coarse quantization scale
    g["w"] = g["w"].at[0].set(10.0)
    err = init_error_state(g)
    applied = jnp.zeros((8,))
    steps = 400
    scale = 10.0 / 127.0
    for _ in range(steps):
        qg, err = compress_grads(g, err)
        deq = decompress_grads(qg)
        applied = applied + deq["w"]
    expected = steps * 1e-3
    assert (np.abs(np.asarray(applied)[1:] - expected) <= scale + 1e-6).all()
    # without EF nothing would ever be transmitted for the small entries
    assert np.asarray(applied)[1:].min() > 0
