"""Bit-parity suite for the device-resident coarsening path.

The device merger (``run_merger``: one jitted ``lax.while_loop`` carrying
the BSP halting vote and the stall → desperation state machine) must
replicate the per-round host driver (``run_merger_host``) bit-for-bit:
identical key stream (one split per round), identical round sequencing
(forced rounds, desperation transitions, the terminal forced round), hence
identical ``MergerState``. Likewise the on-device ``next_level`` compaction
(``bucket=True``) must produce coarse graphs identical element-for-element
to the host-numpy reference (``next_level_host``). These hold across the
seeded suite AND across shape buckets (padding invariance).
"""
import numpy as np
import pytest

from repro.graphs import generators as G, build_graph
from repro.core.solar_merger import (MergerState, next_level, next_level_host,
                                     round_budget, run_merger,
                                     run_merger_host)

GRAPHS = [
    ("grid", *G.grid(16, 16)),
    ("tree", *G.tree(4, 4)),
    ("scale_free", *G.scale_free(1200, 3, 2)),
    ("sierpinski", *G.sierpinski(5)),
    ("flower", *G.flower(8, 8)),
]

STATE_FIELDS = ("state", "sun", "depth", "parent")


def _assert_states_equal(a: MergerState, b: MergerState, ctx=""):
    for f in STATE_FIELDS:
        av, bv = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert np.array_equal(av, bv), (ctx, f)


@pytest.mark.parametrize("name,edges,n", GRAPHS, ids=[g[0] for g in GRAPHS])
@pytest.mark.parametrize("seed", [0, 7])
def test_device_merger_bit_parity(name, edges, n, seed):
    g = build_graph(edges, n, bucket=True)
    _assert_states_equal(run_merger(g, seed=seed),
                         run_merger_host(g, seed=seed), (name, seed))


def test_device_merger_parity_through_desperation():
    """A tiny election probability stalls the vote (rounds electing nobody)
    until the stall counter trips desperation — the device loop must track
    the host's stall arithmetic and sticky-desperation flag exactly."""
    e, n = G.grid(12, 12)
    g = build_graph(e, n, bucket=True)
    for seed in (0, 1, 2):
        st_d = run_merger(g, seed=seed, p_sun=0.01, force_every=1000)
        st_h = run_merger_host(g, seed=seed, p_sun=0.01, force_every=1000)
        _assert_states_equal(st_d, st_h, seed)
        # the run actually converged through the desperation machinery
        assert (np.asarray(st_d.state)[np.asarray(g.vmask)] > 0).all()


@pytest.mark.parametrize("name,edges,n", GRAPHS[:3], ids=[g[0] for g in GRAPHS[:3]])
def test_device_next_level_bit_parity(name, edges, n):
    g = build_graph(edges, n, bucket=True)
    st = run_merger(g, seed=1)
    cg_d, info_d = next_level(g, st, bucket=True)
    cg_h, info_h = next_level_host(g, st, bucket=True)
    assert (cg_d.n, cg_d.m, cg_d.n_pad, cg_d.m_pad) == \
           (cg_h.n, cg_h.m, cg_h.n_pad, cg_h.m_pad)
    for f in ("src", "dst", "vmask", "emask", "mass", "ewt"):
        assert np.array_equal(np.asarray(getattr(cg_d, f)),
                              np.asarray(getattr(cg_h, f))), (name, f)
    for f in ("parent_coarse", "sun_of", "depth", "state", "sun_pos_index"):
        assert np.array_equal(np.asarray(getattr(info_d, f)),
                              np.asarray(getattr(info_h, f))), (name, f)


def test_device_hierarchy_bit_parity_across_levels():
    """Walk a whole hierarchy with both compaction paths in lockstep: every
    level's coarse graph and LevelInfo must agree, so the device pipeline's
    hierarchy is bit-identical to the pre-refactor host driver's."""
    e, n = G.delaunay(900, 4)
    g_d = g_h = build_graph(e, n, bucket=True)
    for lvl in range(6):
        if g_d.n <= 50:
            break
        st_d = run_merger(g_d, seed=5 + 101 * lvl)
        st_h = run_merger_host(g_h, seed=5 + 101 * lvl)
        _assert_states_equal(st_d, st_h, lvl)
        cg_d, info_d = next_level(g_d, st_d, bucket=True)
        cg_h, info_h = next_level_host(g_h, st_h, bucket=True)
        assert (cg_d.n, cg_d.m) == (cg_h.n, cg_h.m), lvl
        for f in ("src", "dst", "vmask", "emask", "mass", "ewt"):
            assert np.array_equal(np.asarray(getattr(cg_d, f)),
                                  np.asarray(getattr(cg_h, f))), (lvl, f)
        for f in ("parent_coarse", "sun_of", "depth", "state",
                  "sun_pos_index"):
            assert np.array_equal(np.asarray(getattr(info_d, f)),
                                  np.asarray(getattr(info_h, f))), (lvl, f)
        if cg_d.n >= g_d.n:
            break
        g_d, g_h = cg_d, cg_h


def test_device_merger_padding_invariance():
    """Same graph, two shape buckets → identical states on the real rows
    AND identical coarse graphs (the per-vertex RNG streams and the
    compaction are padding-invariant)."""
    e, n = G.delaunay(700, 8)
    g1 = build_graph(e, n, pad_mult=1024, bucket=False)   # n_pad = 1024
    g2 = build_graph(e, n, pad_mult=2048, bucket=False)   # n_pad = 2048
    assert g1.n_pad != g2.n_pad
    st1 = run_merger(g1, seed=2)
    st2 = run_merger(g2, seed=2)
    for f in STATE_FIELDS:
        assert np.array_equal(np.asarray(getattr(st1, f))[:n],
                              np.asarray(getattr(st2, f))[:n]), f
    cg1, info1 = next_level(g1, st1, bucket=True)
    cg2, info2 = next_level(g2, st2, bucket=True)
    assert (cg1.n, cg1.m) == (cg2.n, cg2.m)
    assert np.array_equal(np.asarray(info1.sun_pos_index),
                          np.asarray(info2.sun_pos_index))
    assert np.array_equal(np.asarray(cg1.mass)[: cg1.n],
                          np.asarray(cg2.mass)[: cg2.n])


@pytest.mark.parametrize("driver", [run_merger, run_merger_host],
                         ids=["device", "host"])
def test_tiny_round_budget_degrades_gracefully(driver):
    """Regression for the old ``RuntimeError`` at budget exhaustion: with
    max_rounds=1 the merger must still return a full assignment (terminal
    forced round: leftovers become their own suns), never raise."""
    e, n = G.grid(10, 10)
    g = build_graph(e, n, bucket=True)
    st = driver(g, max_rounds=1, seed=0)
    state = np.asarray(st.state)
    sun = np.asarray(st.sun)
    vm = np.asarray(g.vmask)
    assert (state[vm] > 0).all()
    # forced self-suns point at themselves with depth 0
    assert (state[sun[vm]] > 0).all()
    assert (np.asarray(st.depth)[vm] >= 0).all()


def test_tiny_round_budget_drivers_agree():
    e, n = G.grid(10, 10)
    g = build_graph(e, n, bucket=True)
    _assert_states_equal(run_merger(g, max_rounds=2, seed=4),
                         run_merger_host(g, max_rounds=2, seed=4))


def test_round_budget_scales_with_graph_size():
    assert round_budget(100) == 96                 # historical base preserved
    assert round_budget(4096) == 96
    assert round_budget(10_000_000) > round_budget(100_000) > 96
    # monotone in n
    budgets = [round_budget(n) for n in (10, 10**3, 10**5, 10**7, 10**9)]
    assert budgets == sorted(budgets)
