"""Shape-bucketed compilation of the multilevel driver (core/bucketing.py).

Three contracts:
  * PARITY — the bucketed driver (cached dynamic-iteration steps, donated
    buffers, normalized static fields, per-vertex RNG) is behavior-
    preserving vs. the exact-shape legacy path;
  * WARM PATH — a fresh graph whose levels land in already-compiled shape
    buckets triggers ZERO new compiles (via jit cache stats);
  * PADDING INVARIANCE — re-padding the same graph to a different bucket
    changes nothing for real vertices: same initial positions, same
    forces, same merger decisions.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.graphs import generators as G, build_graph
from repro.graphs.graph import bucket_pad
from repro.core import (multigila_layout, LayoutConfig, build_hierarchy,
                        run_merger, gila, bucketing)
from repro.utils.transfer import io_boundary, no_implicit_transfers


@pytest.fixture(autouse=True)
def _no_implicit_transfers():
    """The whole hot path runs under jax.transfer_guard("disallow"): every
    intentional host<->device hop in the drivers is wrapped in
    utils/transfer.io_boundary(); any bare transfer is a bug this guard
    (and gilalint R3) exists to catch."""
    with no_implicit_transfers():
        yield


PARITY_GRAPHS = [
    # n ≤ 512 keeps n_pad identical between round-256 and pow2 padding, so
    # parity is exact; the bucket-padding degree of freedom is covered
    # separately by the padding-invariance tests below (full-pipeline float
    # parity across DIFFERENT reduction shapes is not a meaningful contract
    # — ulp-level reduction-order differences amplify over hundreds of
    # chaotic force iterations).
    ("grid_20_20", *G.grid(20, 20)),
    ("delaunay_450", *G.delaunay(450, 3)),
    ("scale_free_480", *G.scale_free(480, 2, 4)),
]


@pytest.mark.parametrize("name,e,n", PARITY_GRAPHS,
                         ids=[g[0] for g in PARITY_GRAPHS])
def test_parity_bucketed_vs_exact_shape(name, e, n):
    """Golden parity: positions within 1e-5 (observed: bit-identical) and
    identical hierarchy level counts."""
    pb, sb = multigila_layout(e, n, LayoutConfig(seed=7, bucketing=True))
    pe, se = multigila_layout(e, n, LayoutConfig(seed=7, bucketing=False))
    assert sb.levels == se.levels
    np.testing.assert_allclose(pb, pe, atol=1e-5)


@pytest.mark.parametrize("kw", [dict(exact_threshold=128),
                                dict(grid_threshold=256)],
                         ids=["neighbor-mode", "grid-mode"])
def test_parity_covers_neighbor_and_grid_steps(kw):
    """The cached neighbor-mode and grid-mode refine steps are also
    behavior-preserving (thresholds forced down so a 400-vertex graph
    exercises them)."""
    e, n = G.grid(20, 20)
    pb, sb = multigila_layout(e, n, LayoutConfig(seed=7, bucketing=True, **kw))
    pe, se = multigila_layout(e, n, LayoutConfig(seed=7, bucketing=False, **kw))
    assert sb.levels == se.levels
    np.testing.assert_allclose(pb, pe, atol=1e-5)


def test_warm_path_zero_new_compiles():
    """Acceptance: a fresh same-bucket graph reuses every compiled program
    — no new step-cache misses AND no new jit trace entries anywhere in
    the driver (merger, placer, refine)."""
    e1, n1 = G.delaunay(3000, 5)
    multigila_layout(e1, n1, LayoutConfig(seed=5))
    before = bucketing.cache_stats()
    # guard against a vacuous pass: if the private jit cache-size probe
    # ever disappears from this JAX version, fail loudly instead of
    # comparing 0 == 0
    assert before["jit_entries"] > 0, "jit cache probe broken"
    # fresh graph, same generator sizes → same pow2 buckets at every level
    e2, n2 = G.delaunay(3000, 9)
    pos, st = multigila_layout(e2, n2, LayoutConfig(seed=6))
    after = bucketing.cache_stats()
    assert pos.shape == (n2, 2) and st.levels >= 2
    assert after["misses"] == before["misses"], (before, after)
    assert after["jit_entries"] == before["jit_entries"], (before, after)
    assert after["hits"] > before["hits"]


def test_padding_invariance_of_init_forces_and_merger():
    """Vertex v's random draws, forces, and merger fate do not depend on
    the padding bucket (the property that makes bucketing safe at all)."""
    e, n = G.delaunay(700, 3)
    g1 = build_graph(e, n, n_pad=1024, m_pad=8192)
    g2 = build_graph(e, n, n_pad=2048, m_pad=16384)

    pos1 = gila.random_init(g1, 5.0, 3)
    pos2 = gila.random_init(g2, 5.0, 3)
    np.testing.assert_allclose(np.asarray(pos1)[:n], np.asarray(pos2)[:n],
                               atol=1e-6)

    with io_boundary():                 # test-side staging
        params = jnp.asarray([1.0, 1.0, 1e-3], jnp.float32)
        dummy = (jnp.zeros((g1.n_pad, 1), jnp.int32),
                 jnp.zeros((g1.n_pad, 1), bool))
        dummy2 = (jnp.zeros((g2.n_pad, 1), jnp.int32),
                  jnp.zeros((g2.n_pad, 1), bool))
    f1 = gila.gila_forces(g1, pos1, *dummy, params, mode="exact")
    f2 = gila.gila_forces(g2, pos2, *dummy2, params, mode="exact")
    np.testing.assert_allclose(np.asarray(f1)[:n], np.asarray(f2)[:n],
                               atol=1e-5)

    st1 = run_merger(g1, seed=1)
    st2 = run_merger(g2, seed=1)
    for field in ("state", "sun", "depth", "parent"):
        a = np.asarray(getattr(st1, field))[:n]
        b = np.asarray(getattr(st2, field))[:n]
        assert (a == b).all(), field


def test_export_reports_true_n_not_bucket_padding():
    """The serve export path must see true vertex counts: bucket padding is
    an implementation detail of the compiled steps, never of the data
    contract."""
    e, n = G.delaunay(700, 3)          # 700 → bucket 1024: n ≠ n_pad
    pos, st, exp = multigila_layout(e, n, LayoutConfig(seed=2), export=True)
    assert pos.shape == (n, 2)
    assert exp.levels[0].n == n
    assert exp.pos.shape == (n, 2)
    sizes = [lvl.n for lvl in exp.levels]
    for lvl in exp.levels:
        assert lvl.rep.shape == (lvl.n,)
        if lvl.parent is not None:
            assert lvl.parent.shape == (lvl.n,)
        if len(lvl.edges):
            assert lvl.edges.max() < lvl.n
    # level sizes strictly decrease (true sizes, not padded buckets)
    assert all(sizes[i + 1] < sizes[i] for i in range(len(sizes) - 1))


def test_bucket_pad():
    assert bucket_pad(1) == 256
    assert bucket_pad(256) == 256
    assert bucket_pad(257) == 512
    assert bucket_pad(600) == 1024
    assert bucket_pad(1024) == 1024
    assert bucket_pad(3, minimum=8) == 8
    assert bucket_pad(9, minimum=8) == 16


def test_build_hierarchy_invariant_no_shrink():
    """Degenerate case: a graph that cannot shrink (edgeless — every vertex
    becomes its own sun). The final merger's coarse graph AND info are
    discarded together; the graphs/infos length invariant holds."""
    g0 = build_graph(np.zeros((0, 2), np.int64), 100)
    graphs, infos = build_hierarchy(g0, LayoutConfig())
    assert len(graphs) == len(infos) + 1
    assert len(graphs) == 1 and graphs[0] is g0


def test_build_hierarchy_invariant_normal():
    e, n = G.grid(16, 16)
    graphs, infos = build_hierarchy(build_graph(e, n, bucket=True),
                                    LayoutConfig())
    assert len(graphs) == len(infos) + 1
    assert len(graphs) >= 2
    # bucketed levels carry pow2 padded shapes
    for g in graphs:
        assert g.n_pad == bucket_pad(g.n_pad)
