"""Grid-force kernel sweeps (Pallas interpret vs jnp oracle), end-to-end
approximation-error bounds vs the all-pairs oracle, and schedule wiring."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.grid_force.kernel import grid_near_pallas, grid_far_pallas
from repro.kernels.grid_force.ref import grid_near_ref, grid_far_ref
from repro.kernels.grid_force.ops import grid_repulsion, choose_grid
from repro.kernels.nbody.ref import nbody_repulsion_ref


@pytest.mark.parametrize("nc,cap,block", [(16, 8, 1), (64, 16, 4),
                                          (25, 24, 5)])
def test_grid_near_kernel_matches_ref(nc, cap, block):
    rng = np.random.default_rng(nc + cap)
    rows = rng.random((nc, cap, 2)).astype(np.float32) * 4
    npos = rng.random((nc, 9 * cap, 2)).astype(np.float32) * 4
    nw = np.where(rng.random((nc, 9 * cap)) > 0.3,
                  rng.random((nc, 9 * cap)) + 0.5, 0.0).astype(np.float32)
    out = grid_near_pallas(jnp.asarray(rows), jnp.asarray(npos),
                           jnp.asarray(nw), 1.3, 0.8, 1e-2,
                           block_cells=block, interpret=True)
    ref = grid_near_ref(jnp.asarray(rows), jnp.asarray(npos),
                        jnp.asarray(nw), 1.3, 0.8, 1e-2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,nc,br,bc", [(256, 128, 128, 128),
                                        (384, 256, 128, 256)])
def test_grid_far_kernel_matches_ref(n, nc, br, bc):
    rng = np.random.default_rng(n)
    pos = rng.random((n, 2)).astype(np.float32) * 10
    cells = np.concatenate(
        [rng.random((nc, 2)).astype(np.float32) * 10,
         (rng.random((nc, 1)) * 20).astype(np.float32)], axis=1)
    out = grid_far_pallas(jnp.asarray(pos), jnp.asarray(cells), 1.1, 0.9,
                          1e-2, block_rows=br, block_cols=bc, interpret=True)
    ref = grid_far_ref(jnp.asarray(pos), jnp.asarray(cells), 1.1, 0.9, 1e-2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def _rel_err(f_approx, f_exact):
    """Per-vertex error normalized by |f_exact| + mean|f_exact| (avoids
    division blow-up at force-balance points)."""
    dn = np.linalg.norm(np.asarray(f_approx) - np.asarray(f_exact), axis=1)
    en = np.linalg.norm(np.asarray(f_exact), axis=1)
    return dn / (en + en.mean())


def test_grid_repulsion_error_bound_random():
    """Uniform-random positions (the layout-realistic regime): total force
    within 10% of the all-pairs oracle everywhere."""
    rng = np.random.default_rng(3)
    n = 3000
    pos = jnp.asarray(rng.random((n, 2)) * 12, jnp.float32)
    mass = jnp.asarray(rng.random(n) + 0.5, jnp.float32)
    vmask = jnp.asarray(rng.random(n) > 0.1)
    G, cap = choose_grid(n)
    f_g = grid_repulsion(pos, mass, vmask, 1.2, 0.9, 1e-2,
                         grid_dim=G, cell_cap=cap)
    f_e = nbody_repulsion_ref(pos, mass, vmask, 1.2, 0.9, 1e-2)
    rel = _rel_err(f_g, f_e)
    assert rel.max() < 0.10, rel.max()


def test_grid_repulsion_error_bound_cluster():
    """Gaussian clusters overflow cell caps: in-bucket vertices still stay
    within 10% far-field error; overflowed vertices degrade to the softened
    aggregate but remain bounded (never the raw-point-mass blow-up)."""
    from repro.kernels.grid_force.ops import bin_vertices
    rng = np.random.default_rng(5)
    pos_np = np.concatenate([rng.normal(0, 0.8, (800, 2)),
                             rng.normal(7, 0.6, (800, 2)),
                             rng.normal((0, 8), 1.2, (448, 2))])
    n = len(pos_np)
    pos = jnp.asarray(pos_np, jnp.float32)
    mass = jnp.asarray(rng.random(n) + 0.5, jnp.float32)
    vmask = jnp.ones((n,), bool)
    G, cap = choose_grid(n)
    f_g = grid_repulsion(pos, mass, vmask, 1.2, 0.9, 1e-2,
                         grid_dim=G, cell_cap=cap)
    f_e = nbody_repulsion_ref(pos, mass, vmask, 1.2, 0.9, 1e-2)
    rel = _rel_err(f_g, f_e)
    _, _, inb = bin_vertices(pos, vmask, G, cap)
    inb = np.asarray(inb)
    # vertices that made it into their bucket: near field exact except for
    # overflowed neighbors, far field within the flat-BH bound (observed
    # ~0.35 worst-case next to a saturated cell, ~0.02 median)
    assert rel[inb].max() < 0.45, rel[inb].max()
    assert np.median(rel[inb]) < 0.10
    # overflowed vertices: approximate near field, but softening keeps the
    # error the same order as the force scale
    assert rel.max() < 1.0, rel.max()


def test_grid_far_field_component_within_10pct():
    """The acceptance bound proper: the far-field approximation (everything
    outside the 3×3 neighborhood) is within 10% of its exact counterpart,
    even on clustered inputs."""
    from repro.kernels.grid_force.ops import (bin_vertices, _cell_aggregates,
                                              _neighbor_table, _agg_field_9,
                                              _far_all_cells)
    rng = np.random.default_rng(7)
    pos_np = np.concatenate([rng.normal(0, 0.8, (900, 2)),
                             rng.normal(6, 0.5, (900, 2)),
                             rng.random((900, 2)) * 10 - 2])
    n = len(pos_np)
    pos = jnp.asarray(pos_np, jnp.float32)
    mass = jnp.asarray(rng.random(n) + 0.5, jnp.float32)
    vmask = jnp.asarray(rng.random(n) > 0.05)
    C, L, md = 1.2, 0.9, 1e-2
    G, cap = choose_grid(n)
    nc = G * G
    w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)
    cid, _, _ = bin_vertices(pos, vmask, G, cap)
    M, _, mu = _cell_aggregates(pos, w, cid, nc)
    table = jnp.asarray(_neighbor_table(G))
    cell_xyw = jnp.concatenate([mu[:nc], M[:nc, None]], axis=1)
    f_far = np.asarray(
        _far_all_cells(pos, cell_xyw, C, L, md, "ref")
        - _agg_field_9(pos, mu[table[cid]], M[table[cid]], C, L, md))

    # exact far field: all pairs minus pairs within the 3×3 neighborhood
    cid_np = np.asarray(cid)
    cxy = np.stack([cid_np % G, cid_np // G], axis=1)
    p = np.asarray(pos)
    w_np = np.asarray(w)
    dx = p[:, 0][:, None] - p[:, 0][None, :]
    dy = p[:, 1][:, None] - p[:, 1][None, :]
    d2 = dx * dx + dy * dy + md * md
    inv = C * L * L * w_np[None, :] / d2
    cheb = np.maximum(np.abs(cxy[:, 0][:, None] - cxy[:, 0][None, :]),
                      np.abs(cxy[:, 1][:, None] - cxy[:, 1][None, :]))
    far_pair = (cheb > 1) & (cid_np[:, None] < nc) & (cid_np[None, :] < nc)
    f_far_exact = np.stack([(dx * inv * far_pair).sum(1),
                            (dy * inv * far_pair).sum(1)], axis=1)
    vm = np.asarray(vmask)
    err = np.linalg.norm((f_far - f_far_exact) * vm[:, None], axis=1)
    scale = np.linalg.norm(f_far_exact * vm[:, None], axis=1).mean()
    assert err.max() < 0.10 * scale, (err.max(), scale)


def test_grid_mode_reduces_stress():
    """gila_layout in grid mode lays out a grid graph about as well as
    exact mode (end-to-end integration through core/gila.py)."""
    from repro.graphs import generators as GEN
    from repro.graphs.graph import build_graph
    from repro.graphs.metrics import sampled_stress
    from repro.core import gila
    e, n = GEN.grid(16, 16)
    g = build_graph(e, n)
    pos0 = gila.random_init(g, 6.0, 1)
    G, cap = choose_grid(g.n_pad)
    dummy_i = jnp.zeros((g.n_pad, 1), jnp.int32)
    dummy_m = jnp.zeros((g.n_pad, 1), bool)
    pos1 = gila.gila_layout(g, pos0, dummy_i, dummy_m, mode="grid",
                            iters=200, temp0=2.0, temp_decay=0.98,
                            ideal_len=1.0, rep_const=1.0,
                            grid_dim=G, cell_cap=cap)
    s0 = sampled_stress(np.asarray(pos0)[:n], e, n)
    s1 = sampled_stress(np.asarray(pos1)[:n], e, n)
    assert np.isfinite(np.asarray(pos1)).all()
    assert s1 < s0 * 0.5, (s0, s1)


def test_make_schedule_selects_grid():
    from repro.core.schedule import make_schedule
    # small level → exact
    s = make_schedule(2, 3, 1000, 3000)
    assert s.mode == "exact" and s.grid_dim == 0
    # mid level → neighbor (the paper's regime)
    s = make_schedule(1, 3, 10_000, 30_000)
    assert s.mode == "neighbor" and s.grid_dim == 0
    # fine level of a big hierarchy → grid, with usable static params
    s = make_schedule(0, 3, 100_000, 400_000)
    assert s.mode == "grid"
    assert s.grid_dim >= 2 and s.cell_cap >= 8
    # thresholds are tunable (centralized engine forces exact everywhere)
    s = make_schedule(0, 3, 100_000, 400_000, exact_threshold=10 ** 9)
    assert s.mode == "exact"
    s = make_schedule(0, 3, 100_000, 400_000, grid_threshold=10 ** 9)
    assert s.mode == "neighbor"


def test_choose_grid_scaling():
    for n in (1, 100, 5_000, 50_000, 1_000_000):
        G, cap = choose_grid(n)
        assert 2 <= G <= 128
        assert 1 <= cap <= max(n, 8)
    G5, _ = choose_grid(50_000)
    G1m, _ = choose_grid(1_000_000)
    assert G1m > G5                   # finer grids for bigger levels
