"""The maxent-stress refinement engine (core/stress.py) through every layer.

Contracts (DESIGN.md §14):
  * PADDING INVARIANCE — a vertex's stress update does not depend on the
    padding bucket its level landed in;
  * DETERMINISM — same seed → bit-identical positions, across runs and
    across the sequential/batched drivers;
  * ENGINE SEAM — mixed-engine batches group by engine and stay
    bit-identical to dedicated runs; warm passes of either engine compile
    zero new programs (the engine id is a cache-key component, never a
    cache invalidator);
  * WEIGHTS — edge weights parsed by ``load_edgelist`` survive pruning and
    scale the stress target lengths ℓ_e = w_e·L.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.graphs import generators as G, build_graph
from repro.graphs.io import load_edgelist
from repro.core import (LayoutConfig, multigila_layout,
                        multigila_layout_many, bucketing, gila, stress)
from repro.core.engine import get_engine
from repro.core.pruning import prune_degree_one
from repro.utils.transfer import io_boundary, no_implicit_transfers


@pytest.fixture(autouse=True)
def _no_implicit_transfers():
    """Hot-path tests run under jax.transfer_guard("disallow"); see the
    twin fixture in tests/test_bucketing.py."""
    with no_implicit_transfers():
        yield


# -- the engine registry seam --------------------------------------------------

def test_engine_registry():
    assert get_engine("gila").name == "gila"
    assert get_engine("stress").name == "stress"   # lazily imported
    assert get_engine("stress").sched_k == 4
    with pytest.raises(ValueError, match="unknown refinement engine"):
        get_engine("nope")


def test_layoutconfig_driver_engine_shim():
    """Back-compat: the old ``engine=<driver>`` spelling selects the driver
    and leaves the refinement engine at gila."""
    cfg = LayoutConfig(engine="flat")
    assert (cfg.driver, cfg.engine) == ("flat", "gila")
    cfg = LayoutConfig(engine="stress")
    assert (cfg.driver, cfg.engine) == ("multigila", "stress")
    # dataclasses.replace re-runs the shim harmlessly
    cfg2 = dataclasses.replace(cfg, seed=9)
    assert (cfg2.driver, cfg2.engine) == ("multigila", "stress")


# -- padding invariance --------------------------------------------------------

def test_stress_layout_padding_invariant():
    """Vertex v's maxent-stress trajectory does not depend on the padding
    bucket (ρ = 0 keeps padding pinned; masked edges carry zero weight)."""
    e, n = G.delaunay(700, 3)
    g1 = build_graph(e, n, n_pad=1024, m_pad=8192)
    g2 = build_graph(e, n, n_pad=2048, m_pad=16384)
    kw = dict(mode="exact", iters=20, temp0=3.0, temp_decay=0.96,
              alpha0=0.05, alpha_decay=0.9, ideal_len=1.0, rep_const=1.0)
    with io_boundary():                 # test-side staging (dummies, scalars)
        p1 = stress.stress_layout(g1, gila.random_init(g1, 5.0, 3),
                                  jnp.zeros((g1.n_pad, 1), jnp.int32),
                                  jnp.zeros((g1.n_pad, 1), bool), **kw)
        p2 = stress.stress_layout(g2, gila.random_init(g2, 5.0, 3),
                                  jnp.zeros((g2.n_pad, 1), jnp.int32),
                                  jnp.zeros((g2.n_pad, 1), bool), **kw)
    np.testing.assert_allclose(np.asarray(p1)[:n], np.asarray(p2)[:n],
                               atol=1e-5)
    # padding rows stay pinned at the origin
    assert not np.asarray(p1)[n:].any()


# -- determinism + batched parity ----------------------------------------------

def test_stress_per_seed_determinism():
    e, n = G.tri_mesh(9, 9)
    cfg = LayoutConfig(seed=4, engine="stress")
    a, sa = multigila_layout(e, n, cfg)
    b, sb = multigila_layout(e, n, cfg)
    assert sa.levels == sb.levels
    assert np.array_equal(np.asarray(a), np.asarray(b))
    c, _ = multigila_layout(e, n, dataclasses.replace(cfg, seed=5))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def _assert_parity(graphs, cfg, seeds=None, engines=None):
    outs = multigila_layout_many(graphs, cfg, seeds=seeds, engines=engines)
    for i, (e, n) in enumerate(graphs):
        scfg = cfg
        if seeds is not None:
            scfg = dataclasses.replace(scfg, seed=int(seeds[i]))
        if engines is not None:
            scfg = dataclasses.replace(scfg, engine=engines[i])
        ps, ss = multigila_layout(e, n, scfg)
        pb, sb = outs[i]
        assert sb.levels == ss.levels
        assert np.array_equal(np.asarray(pb), np.asarray(ps)), f"graph {i}"
    return outs


def test_stress_batched_bit_identical_to_sequential():
    gs = [G.delaunay(150, 30 + i) for i in range(3)]
    _assert_parity(gs, LayoutConfig(seed=5, engine="stress"))


def test_stress_batched_mixed_buckets():
    gs = [G.delaunay(120, 3), G.delaunay(500, 4), G.grid(14, 14)]
    _assert_parity(gs, LayoutConfig(seed=2, engine="stress"),
                   seeds=[7, 8, 9])


@pytest.mark.parametrize("kw", [dict(exact_threshold=64),
                                dict(exact_threshold=64, grid_threshold=96)],
                         ids=["neighbor-mode", "grid-mode"])
def test_stress_batched_neighbor_and_grid_modes(kw):
    gs = [G.delaunay(150, 50 + i) for i in range(2)]
    _assert_parity(gs, LayoutConfig(seed=4, engine="stress", **kw))


def test_mixed_engine_wave_grouping():
    """One batch, both engines: lanes group by engine inside the wave loop
    (group_key leads with the engine id) and every lane stays bit-identical
    to its dedicated-engine sequential run."""
    gs = [G.delaunay(150, 60 + i) for i in range(4)]
    engines = ["gila", "stress", "gila", "stress"]
    _assert_parity(gs, LayoutConfig(seed=3), engines=engines)


def test_service_engine_override():
    """The continuous-batching service's per-request engine override:
    validated at the submit boundary (unknown ids bounce, they never reach
    the worker), and each request stays bit-identical to its dedicated
    sequential run even when the wave mixes engines."""
    from repro.serve.engine import ContinuousLayoutService
    e, n = G.delaunay(80, 2)
    ref_s, _ = multigila_layout(e, n, LayoutConfig(seed=0, engine="stress"))
    ref_g, _ = multigila_layout(e, n, LayoutConfig(seed=0))
    svc = ContinuousLayoutService(LayoutConfig(seed=0), max_lanes=4)
    try:
        with pytest.raises(ValueError, match="unknown refinement engine"):
            svc.submit(e, n, engine="nope")
        rs = svc.submit(e, n, engine="stress")
        rg = svc.submit(e, n)
        pos_s, _ = rs.result(300)
        pos_g, _ = rg.result(300)
    finally:
        svc.close()
    assert np.array_equal(np.asarray(pos_s), np.asarray(ref_s))
    assert np.array_equal(np.asarray(pos_g), np.asarray(ref_g))


# -- warm path: engine id widens the key, never invalidates it -----------------

def test_warm_cross_engine_zero_new_compiles():
    """After one pass of EACH engine over a bucket family, fresh same-bucket
    graphs under either engine trigger zero new compiles — the stress
    programs are cached beside the GiLA ones, not over them."""
    multigila_layout(*G.delaunay(3000, 5), LayoutConfig(seed=5))
    multigila_layout(*G.delaunay(3000, 6),
                     LayoutConfig(seed=5, engine="stress"))
    before = bucketing.cache_stats()
    assert before["jit_entries"] > 0, "jit cache probe broken"
    multigila_layout(*G.delaunay(3000, 7), LayoutConfig(seed=6))
    mid = bucketing.cache_stats()
    assert mid["misses"] == before["misses"], (before, mid)
    assert mid["jit_entries"] == before["jit_entries"], (before, mid)
    multigila_layout(*G.delaunay(3000, 8),
                     LayoutConfig(seed=6, engine="stress"))
    after = bucketing.cache_stats()
    assert after["misses"] == before["misses"], (before, after)
    assert after["jit_entries"] == before["jit_entries"], (before, after)
    assert after["hits"] > mid["hits"] > before["hits"]


# -- weighted graphs -----------------------------------------------------------

def test_load_edgelist_weights(tmp_path):
    p = tmp_path / "w.txt"
    p.write_text("# comment\n0 1 2.5\n1 2\n2 3 0.5\n")
    e, n = load_edgelist(str(p))                       # 2-tuple unchanged
    assert e.shape == (3, 2) and n == 4
    e, n, w = load_edgelist(str(p), weights=True)
    assert np.array_equal(e, [[0, 1], [1, 2], [2, 3]])
    np.testing.assert_allclose(w, [2.5, 1.0, 0.5])     # missing → 1.0
    assert w.dtype == np.float32

    m = tmp_path / "w.mtx"
    m.write_text("%%MatrixMarket matrix coordinate real general\n"
                 "3 3 2\n1 2 4.0\n2 3 0.25\n")
    e, n, w = load_edgelist(str(m), weights=True)
    assert np.array_equal(e, [[0, 1], [1, 2]]) and n == 3
    np.testing.assert_allclose(w, [4.0, 0.25])


def test_prune_preserves_weights():
    # triangle 0-1-2 with a leaf 3 on vertex 1; the leaf edge's weight is
    # dropped with the leaf, the surviving weights stay aligned
    edges = np.array([[0, 1], [1, 2], [2, 0], [1, 3]])
    w = np.array([2.0, 0.5, 1.5, 9.0], np.float32)
    pr = prune_degree_one(edges, 4, weights=w)
    assert pr.n == 3 and len(pr.edges) == 3
    np.testing.assert_allclose(pr.ewt, [2.0, 0.5, 1.5])
    assert prune_degree_one(edges, 4).ewt is None


def test_weighted_layout_scales_target_lengths():
    """ℓ_e = w_e·L: on a weighted path, the heavy edge draws ~w× longer
    than the unit edge under the stress engine."""
    edges, n = G.grid(10, 10)
    rng = np.random.default_rng(0)
    w = rng.uniform(0.5, 2.0, len(edges)).astype(np.float32)
    cfg = LayoutConfig(seed=1, engine="stress")
    pu, _ = multigila_layout(edges, n, cfg)
    pw, _ = multigila_layout(edges, n, cfg, weights=w)
    assert not np.array_equal(pu, pw), "weights must reach the layout"
    lens = np.linalg.norm(pw[edges[:, 0]] - pw[edges[:, 1]], axis=1)
    # weighted correlation: long-target edges draw longer
    r = np.corrcoef(w, lens)[0, 1]
    assert r > 0.5, f"edge lengths do not track weights (r={r:.2f})"


def test_weighted_layout_batched_parity():
    edges, n = G.grid(10, 10)
    rng = np.random.default_rng(1)
    w = rng.uniform(0.5, 2.0, len(edges)).astype(np.float32)
    cfg = LayoutConfig(seed=2, engine="stress")
    outs = multigila_layout_many([(edges, n)] * 2, cfg, seeds=[4, 5],
                                 weights=[w, None])
    pw, _ = multigila_layout(edges, n, dataclasses.replace(cfg, seed=4),
                             weights=w)
    pu, _ = multigila_layout(edges, n, dataclasses.replace(cfg, seed=5))
    assert np.array_equal(np.asarray(outs[0][0]), np.asarray(pw))
    assert np.array_equal(np.asarray(outs[1][0]), np.asarray(pu))
