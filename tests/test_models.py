"""Model zoo correctness: per-arch smoke, SSD oracle, prefill/decode
consistency, MoE properties."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import list_archs, get_smoke_config
from repro.models import (init_params, loss_fn, forward, prefill, decode_step,
                          input_specs)


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.enc_layers:
        b["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.05,
                                  jnp.bfloat16)
    if cfg.modality == "vlm":
        b["patches"] = jnp.asarray(rng.normal(size=(B, 16, cfg.d_model)) * 0.05,
                                   jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_loss(arch):
    """Reduced same-family config: one forward/loss step, shape + finiteness."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = forward(params, cfg, batch)
    S = batch["tokens"].shape[1]
    assert logits.shape == (2, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, parts = loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    assert 2.0 < float(loss) < 20.0  # ~log(vocab) at init


@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-1.3b", "jamba-v0.1-52b",
                                  "deepseek-moe-16b", "seamless-m4t-medium"])
def test_prefill_decode_matches_forward(arch):
    """Greedy next-token from (prefill S−1 → decode 1) must equal the
    next-token from the full forward — KV caches and SSM states are
    functionally exact."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 64
    batch = _batch(cfg, B, S, seed=3)
    logits_full, _ = forward(params, cfg, batch)

    pre = {"tokens": batch["tokens"][:, : S - 1]}
    if "frames" in batch:
        pre["frames"] = batch["frames"]
    if "patches" in batch:
        pre["patches"] = batch["patches"]
    lg, state, pos = prefill(params, cfg, pre, cache_len=S + 4)
    enc_out = None
    if cfg.enc_layers:
        from repro.models.model import _encode
        enc_out = _encode(params, cfg, batch["frames"])
    tok = batch["tokens"][:, S - 1: S]
    lg2, _ = decode_step(params, cfg, tok, state, jnp.asarray(pos, jnp.int32),
                         enc_out=enc_out)
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(lg2[:, 0], np.float32)
    # bf16 accumulation differences allowed; argmax must agree
    assert (a.argmax(-1) == b.argmax(-1)).all()
    np.testing.assert_allclose(a, b, rtol=0.1, atol=0.25)


@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-1.3b",
                                  "jamba-v0.1-52b"])
def test_chunked_prefill_matches_single_shot(arch):
    """vLLM-style chunked prefill (KV + SSM state threaded across
    super-chunks) equals single-shot prefill."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)),
                                   jnp.int32)}
    l1, s1, _ = prefill(params, cfg, batch, cache_len=80, chunks=1)
    l2, s2, _ = prefill(params, cfg, batch, cache_len=80, chunks=2)
    a = np.asarray(l1, np.float32)
    b = np.asarray(l2, np.float32)
    assert (a.argmax(-1) == b.argmax(-1)).all()
    np.testing.assert_allclose(a, b, rtol=0.1, atol=0.2)


def test_ssd_chunked_matches_sequential_recurrence():
    """The chunked SSD equals the naive per-step SSM recurrence (the
    state-space duality identity) — decode IS the recurrence, so prefill
    state vs step-by-step states must agree too."""
    from repro.models import ssm as SSM
    cfg = get_smoke_config("mamba2-1.3b")
    p = SSM.init_ssm(jax.random.PRNGKey(0), cfg)
    B, S, D = 2, 64, cfg.d_model
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, S, D)) * 0.3, jnp.float32)

    y_chunk, final = SSM.apply_ssm(p, x, cfg, return_state=True)

    state = SSM.init_ssm_state(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        yt, state = SSM.apply_ssm_decode(p, x[:, t:t+1], cfg, state)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final["h"]), np.asarray(state["h"]),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_and_aux():
    from repro.models import moe as MOE
    from repro.configs.base import MoEConfig
    m = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=1.0)
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe(key, 32, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32), jnp.float32)
    y, aux = MOE.apply_moe(p, x, m)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # Switch LB loss ≥ 1 (perfect balance == 1)
    assert float(aux) >= 0.99


def test_moe_dropless_when_capacity_huge():
    """With capacity ≥ tokens, every token is routed (combine weights sum
    to 1) — output must change if gates are perturbed."""
    from repro.models import moe as MOE
    from repro.configs.base import MoEConfig
    m = MoEConfig(n_experts=4, top_k=2, d_expert=16, capacity_factor=8.0)
    p = MOE.init_moe(jax.random.PRNGKey(0), 16, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16), jnp.float32)
    y, _ = MOE.apply_moe(p, x, m)
    # zeroing the expert weights must zero the MoE output (no passthrough)
    p0 = dict(p, wdown=jnp.zeros_like(p["wdown"]))
    y0, _ = MOE.apply_moe(p0, x, m)
    assert float(jnp.abs(y0).max()) < 1e-6
    assert float(jnp.abs(y).max()) > 1e-6


def test_param_count_matches_tree():
    from repro.utils.tree import tree_count
    for arch in ("gemma-2b", "internlm2-1.8b", "deepseek-moe-16b"):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        analytic = cfg.param_count()
        actual = tree_count(params)
        assert abs(analytic - actual) / actual < 0.06, (arch, analytic, actual)
