import numpy as np
import pytest
import jax.numpy as jnp

from repro.graphs import generators as G, build_graph
from repro.core import gila
from repro.core.schedule import make_schedule


def test_paper_k_schedule():
    # exactly the paper's §3.4 table
    assert gila.paper_k_schedule(999) == 6
    assert gila.paper_k_schedule(1_000) == 5
    assert gila.paper_k_schedule(4_999) == 5
    assert gila.paper_k_schedule(5_000) == 4
    assert gila.paper_k_schedule(9_999) == 4
    assert gila.paper_k_schedule(10_000) == 3
    assert gila.paper_k_schedule(99_999) == 3
    assert gila.paper_k_schedule(100_000) == 2
    assert gila.paper_k_schedule(999_999) == 2
    assert gila.paper_k_schedule(1_000_000) == 1


def test_khop_neighbors_match_bfs():
    import networkx as nx
    e, n = G.gnp(60, 3.0, 7)
    nxg = nx.Graph(e.tolist())
    idx, mask = gila.khop_neighbors(e, n, k=2, cap=n)
    for v in range(n):
        if v not in nxg:
            continue
        expect = {u for u, d in
                  nx.single_source_shortest_path_length(nxg, v, 2).items()
                  if 0 < d <= 2}
        got = set(idx[v][mask[v]].tolist())
        assert got == expect, (v, got, expect)


def test_khop_cap_respected():
    e, n = G.scale_free(300, 4, 0)
    idx, mask = gila.khop_neighbors(e, n, k=3, cap=16)
    assert mask.sum(axis=1).max() <= 16


def _khop_reference(edges, n, k):
    """Straightforward per-vertex BFS ball (no caps) — the content oracle
    for the vectorized builder."""
    adj = [set() for _ in range(n)]
    for a, b in edges:
        adj[a].add(int(b))
        adj[b].add(int(a))
    balls = []
    for v in range(n):
        seen, frontier = {v}, {v}
        for _ in range(k):
            frontier = set().union(*(adj[u] for u in frontier)) - seen \
                if frontier else set()
            seen |= frontier
        balls.append(seen - {v})
    return balls


@pytest.mark.parametrize("k", [1, 2, 3])
def test_khop_vectorized_matches_reference_contents(k):
    """Parity-shaped regression for the vectorized (CSR-sliced) builder:
    with cap ≥ the ball size, list CONTENTS equal the BFS k-hop ball
    exactly — the old per-vertex-Python-loop semantics."""
    e, n = G.gnp(70, 3.0, 9)
    idx, mask = gila.khop_neighbors(e, n, k=k, cap=n)
    balls = _khop_reference(e, n, k)
    for v in range(n):
        assert set(idx[v][mask[v]].tolist()) == balls[v], v


def test_khop_sampled_lists_are_valid_and_deterministic():
    """Under the cap, lists are a deterministic-in-seed subset of the true
    k-hop ball, and hop-1 neighbors fill before anything else when they
    fit (the expansion only tops up remaining room)."""
    e, n = G.scale_free(250, 3, 1)
    cap = 24
    i1, m1 = gila.khop_neighbors(e, n, k=3, cap=cap, seed=7)
    i2, m2 = gila.khop_neighbors(e, n, k=3, cap=cap, seed=7)
    assert np.array_equal(i1, i2) and np.array_equal(m1, m2)
    balls = _khop_reference(e, n, 3)
    hop1 = _khop_reference(e, n, 1)
    for v in range(n):
        got = set(i1[v][m1[v]].tolist())
        assert got <= balls[v]
        assert len(got) == min(cap, len(got))
        if len(hop1[v]) <= cap:
            assert hop1[v] <= got, v      # direct neighbors never sampled out
    assert m1.sum(axis=1).max() <= cap


def test_exact_vs_neighbor_forces_agree_on_full_lists():
    """With cap ≥ n and k ≥ diameter, neighbor mode equals exact mode
    (minus the self term, which is zero anyway)."""
    e, n = G.grid(6, 6)
    g = build_graph(e, n, n_pad=64)
    idx, mask = gila.khop_neighbors(e, n, k=12, cap=n)
    nbr_idx, nbr_mask = gila.pad_neighbors(idx, mask, g.n_pad)
    pos = gila.random_init(g, 3.0, 0)
    params = jnp.asarray([1.0, 1.0, 1e-3], jnp.float32)
    f_exact = gila.gila_forces(g, pos, nbr_idx, nbr_mask, params, mode="exact")
    f_nbr = gila.gila_forces(g, pos, nbr_idx, nbr_mask, params, mode="neighbor")
    np.testing.assert_allclose(np.asarray(f_exact), np.asarray(f_nbr),
                               rtol=1e-4, atol=1e-4)


def test_layout_reduces_stress():
    from repro.graphs.metrics import sampled_stress
    e, n = G.grid(10, 10)
    g = build_graph(e, n)
    pos0 = gila.random_init(g, 5.0, 3)
    sched = make_schedule(0, 1, g.n, g.m)
    pos1 = gila.gila_layout(g, pos0, jnp.zeros((g.n_pad, 1), jnp.int32),
                            jnp.zeros((g.n_pad, 1), bool), mode="exact",
                            iters=200, temp0=2.0, temp_decay=0.98,
                            ideal_len=1.0, rep_const=1.0)
    s0 = sampled_stress(np.asarray(pos0)[:n], e, n)
    s1 = sampled_stress(np.asarray(pos1)[:n], e, n)
    assert s1 < s0 * 0.5, (s0, s1)
