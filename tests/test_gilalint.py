"""gilalint self-tests: per-rule fixtures, jaxpr-audit smoke, and the
empty-baseline / clean-tree regressions that make the CI gate meaningful."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tools" / "gilalint" / "fixtures"
MESH_AXES = {"data", "model", "pod"}

sys.path.insert(0, str(REPO))            # import tools.* from the repo root

from tools.gilalint.rules import lint_paths                     # noqa: E402
from tools.gilalint.report import load_baseline                 # noqa: E402


# -- layer 1: per-rule fixtures ------------------------------------------------

@pytest.mark.parametrize("rule", ["R1", "R2", "R3", "R4", "R5", "R6"])
def test_rule_fires_on_bad_fixture_only(rule):
    bad = FIXTURES / f"{rule.lower()}_bad.py"
    good = FIXTURES / f"{rule.lower()}_good.py"
    bad_findings = lint_paths([str(bad)], mesh_axes=MESH_AXES)
    good_findings = lint_paths([str(good)], mesh_axes=MESH_AXES)
    assert bad_findings, f"{rule}: seeded violation not detected"
    assert {f.rule for f in bad_findings} == {rule}, bad_findings
    assert all(f.hint for f in bad_findings)
    assert good_findings == [], good_findings


def test_obs_hooks_stay_out_of_traced_contexts():
    """Observability instrumentation must be host-side only: a metric
    observation or span argument that forces a traced value to host is an
    R3 finding; the production pattern — span around the driver's existing
    dispatch + block_until_ready, metrics fed after the sync — is clean
    (and test_repo_tree_is_clean holds that line for the real tree)."""
    bad = lint_paths([str(FIXTURES / "r3_obs_bad.py")], mesh_axes=MESH_AXES)
    good = lint_paths([str(FIXTURES / "r3_obs_good.py")], mesh_axes=MESH_AXES)
    assert bad, "seeded obs-in-step violations not detected"
    assert {f.rule for f in bad} == {"R3"}, bad
    assert any("float" in f.message for f in bad)
    assert good == [], good


def test_r2_distinguishes_ambient_from_free_name():
    findings = lint_paths([str(FIXTURES / "r2_bad.py")])
    msgs = "\n".join(f.message for f in findings)
    assert "backend component" in msgs      # ambient os.environ read unkeyed
    assert "closes over 'cell_cap'" in msgs  # static not in the key tuple


def test_r2_flags_unkeyed_engine_id():
    """The engine seam's cache-safety contract: a cached-step key that
    omits the engine id while the builder branches on it is under-keyed —
    a warm stress pass would silently reuse the GiLA program."""
    bad = lint_paths([str(FIXTURES / "r2_engine_bad.py")])
    assert bad, "seeded unkeyed-engine violation not detected"
    assert {f.rule for f in bad} == {"R2"}, bad
    assert any("closes over 'engine'" in f.message for f in bad)
    good = lint_paths([str(FIXTURES / "r2_engine_good.py")])
    assert good == [], good


def test_r5_needs_declared_axes():
    # without an axis universe only the arity check can fire
    findings = lint_paths([str(FIXTURES / "r5_bad.py")])
    assert len(findings) == 1 and "2 entries" in findings[0].message
    findings = lint_paths([str(FIXTURES / "r5_bad.py")], mesh_axes=MESH_AXES)
    assert len(findings) == 2


# -- the repo's own tree + baseline --------------------------------------------

def test_repo_tree_is_clean():
    """src/repro carries zero findings — satellite 1's contract. Any new
    finding must be FIXED, not baselined (see next test)."""
    findings = lint_paths([str(REPO / "src" / "repro")], repo_root=REPO)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_baseline_ships_empty():
    path = REPO / "tools" / "gilalint" / "baseline.json"
    assert json.loads(path.read_text()) == []
    assert load_baseline(path) == set()


def test_cli_fails_on_seeded_violation():
    """The acceptance check: the exact CI command exits non-zero on a tree
    containing a seeded violation, zero on a clean one."""
    env = dict(os.environ, PYTHONPATH=f"{REPO}/src:{REPO}")
    run = lambda target: subprocess.run(
        [sys.executable, "-m", "tools.gilalint", target, "--no-audit"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=120)
    bad = run(str(FIXTURES / "r2_bad.py"))
    assert bad.returncode == 1 and "R2" in bad.stdout
    good = run(str(FIXTURES / "r2_good.py"))
    assert good.returncode == 0, good.stdout + good.stderr


# -- layer 2: jaxpr audit ------------------------------------------------------

def test_audit_checks_on_toy_step():
    """The audit's program checks, demonstrated on toy jitted steps: a
    callback primitive trips A1, and donation detection tells a donating
    jit from a plain one."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from tools.gilalint.jaxpr_audit import _check_program, _donates_arg0

    spec = jax.ShapeDtypeStruct((4,), jnp.float32)

    failures = []
    clean = jax.make_jaxpr(jax.jit(lambda x: x * 2.0))(spec)
    _check_program("toy", clean, failures)
    assert failures == []

    def hostful(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    dirty = jax.make_jaxpr(jax.jit(hostful))(spec)
    _check_program("toy", dirty, failures)
    assert [f["rule"] for f in failures] == ["A1"]

    assert _donates_arg0(jax.jit(lambda x, y: x + y, donate_argnums=(0,)),
                         spec, spec)
    assert not _donates_arg0(jax.jit(lambda x, y: x + y), spec, spec)


def test_full_audit_covers_all_families_and_passes():
    """run_audit() traces every production cached-step family and finds
    nothing — the in-process equivalent of CI's audit half."""
    from tools.gilalint.jaxpr_audit import run_audit

    report = run_audit()
    fams = report["families"]
    assert set(fams) == {"refine_single", "refine_many", "dist_step",
                         "merger", "coarsen", "refine_single_stress",
                         "refine_many_stress", "dist_step_stress"}
    for name, fam in fams.items():
        assert fam["failures"] == [], (name, fam["failures"])
        assert fam["entry"], name
