import numpy as np
import pytest

from repro.graphs import generators as G, build_graph, unique_edges, push_max
from repro.graphs import metrics as M


def test_generators_basic():
    for name, edges, n in G.regulargraphs_suite(small=True):
        assert edges.shape[1] == 2
        assert edges.min() >= 0 and edges.max() < n
        # no self loops, no duplicates
        assert (edges[:, 0] != edges[:, 1]).all()
        assert len(np.unique(edges, axis=0)) == len(edges)


def test_padded_graph_roundtrip():
    e, n = G.grid(7, 5)
    g = build_graph(e, n)
    assert g.n == n and g.m == len(e)
    back = unique_edges(g)
    assert np.array_equal(np.sort(back, axis=0), np.sort(e, axis=0))
    # degree sum = 2m
    assert int(g.degrees().sum()) == 2 * g.m


def test_push_max_is_one_hop_max():
    import networkx as nx
    e, n = G.gnp(40, 4.0, 3)
    g = build_graph(e, n)
    import jax.numpy as jnp
    vals = jnp.asarray(np.arange(g.n_pad), jnp.int32)
    out = np.asarray(push_max(g, vals))
    nxg = nx.Graph(e.tolist())
    for v in range(n):
        nbrs = list(nxg.neighbors(v)) if v in nxg else []
        expect = max(nbrs) if nbrs else -1
        assert out[v] == expect, (v, out[v], expect)


def test_crossings_grid_layout_zero():
    e, n = G.grid(6, 6)
    xs, ys = np.meshgrid(np.arange(6), np.arange(6))
    pos = np.stack([xs.ravel(), ys.ravel()], 1).astype(np.float32)
    assert M.count_crossings(pos, e) == 0
    assert M.neld(pos, e) == 0.0


def test_crossings_match_bruteforce():
    rng = np.random.default_rng(1)
    e, n = G.gnp(24, 3.0, 2)
    pos = rng.random((n, 2)).astype(np.float32)

    def brute(pos, edges):
        def o(p, q, r):
            return (q[0]-p[0])*(r[1]-p[1])-(q[1]-p[1])*(r[0]-p[0])
        cnt = 0
        for i in range(len(edges)):
            for j in range(i + 1, len(edges)):
                a, b = edges[i], edges[j]
                if len({a[0], a[1], b[0], b[1]}) < 4:
                    continue
                if (o(pos[a[0]], pos[a[1]], pos[b[0]]) *
                        o(pos[a[0]], pos[a[1]], pos[b[1]]) < 0 and
                        o(pos[b[0]], pos[b[1]], pos[a[0]]) *
                        o(pos[b[0]], pos[b[1]], pos[a[1]]) < 0):
                    cnt += 1
        return cnt

    assert M.count_crossings(pos, e) == brute(pos, e)


def test_crossings_and_cre_canonicalize_duplicates():
    """Regression: duplicated, reversed-duplicate and self-loop edges must
    not inflate the crossing count or the CRE denominator — the metric
    canonicalizes through the unique undirected edge set first."""
    rng = np.random.default_rng(3)
    e, n = G.gnp(30, 3.0, 4)
    pos = rng.random((n, 2)).astype(np.float32)
    base_x = M.count_crossings(pos, e)
    base_cre = M.cre(pos, e)
    assert base_x > 0          # non-degenerate instance
    messy = np.concatenate([
        e,                      # originals
        e[:, ::-1],             # every edge reversed
        e[:7],                  # straight duplicates
        np.stack([np.arange(5), np.arange(5)], 1),   # self loops
    ])
    assert M.count_crossings(pos, messy) == base_x
    assert M.cre(pos, messy) == base_cre


def test_canonical_edges():
    from repro.graphs.graph import canonical_edges
    e = np.array([[3, 1], [1, 3], [1, 3], [2, 2], [0, 4]])
    out = canonical_edges(e)
    assert out.tolist() == [[0, 4], [1, 3]]
    assert canonical_edges(np.zeros((0, 2), np.int64)).shape == (0, 2)


def test_load_edgelist_streaming(tmp_path):
    from repro.graphs.io import load_edgelist, save_edgelist
    # comments (# and %), blank lines, a trailing weight column
    p = tmp_path / "a.txt"
    p.write_text("# c\n0 1\n\n% c2\n1 2\n2 3 0.5\n")
    e, n = load_edgelist(str(p))
    assert e.tolist() == [[0, 1], [1, 2], [2, 3]] and n == 4
    # MatrixMarket: banner + size line + 1-based indices; n from the header
    p2 = tmp_path / "b.mtx"
    p2.write_text("%%MatrixMarket matrix coordinate pattern symmetric\n"
                  "% comment\n7 7 3\n1 2\n2 3\n4 5\n")
    e2, n2 = load_edgelist(str(p2))
    assert e2.tolist() == [[0, 1], [1, 2], [3, 4]] and n2 == 7
    # empty file: no warnings, empty result
    p3 = tmp_path / "c.txt"
    p3.write_text("")
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        e3, n3 = load_edgelist(str(p3))
    assert e3.shape == (0, 2) and n3 == 0
    # save → load round trip
    p4 = tmp_path / "d.txt"
    rng = np.random.default_rng(0)
    ed = rng.integers(0, 500, (2000, 2))
    save_edgelist(str(p4), ed)
    e4, _ = load_edgelist(str(p4))
    assert np.array_equal(e4, ed)
    # flat one-number-per-line files pair consecutive values (old
    # loadtxt(...).reshape(-1, 2) contract)
    p5 = tmp_path / "flat.txt"
    p5.write_text("0\n1\n1\n2\n")
    e5, n5 = load_edgelist(str(p5))
    assert e5.tolist() == [[0, 1], [1, 2]] and n5 == 3


def test_save_svg_edge_cap(tmp_path):
    from repro.graphs.io import save_svg
    rng = np.random.default_rng(1)
    pos = rng.random((40, 2)).astype(np.float32)
    edges = rng.integers(0, 40, (400, 2))
    p = tmp_path / "capped.svg"
    save_svg(str(p), pos, edges, max_edges=64)
    txt = p.read_text()
    assert "edge cap: drew" in txt
    assert txt.count("<line") <= 64
    # deterministic: same input → same bytes
    p2 = tmp_path / "capped2.svg"
    save_svg(str(p2), pos, edges, max_edges=64)
    assert p2.read_text() == txt
    # below the cap no note appears
    p3 = tmp_path / "uncapped.svg"
    save_svg(str(p3), pos, edges[:10], max_edges=64)
    assert "edge cap" not in p3.read_text()


def test_bfs_distances_match_networkx():
    import networkx as nx
    e, n = G.scale_free(60, 2, 4)
    D = M.bfs_distances(e, n, np.array([0, 5]))
    nxg = nx.Graph(e.tolist())
    sp = nx.single_source_shortest_path_length(nxg, 0)
    for v in range(n):
        assert D[0][v] == sp.get(v, -1)
