import numpy as np
import pytest

from repro.graphs import generators as G, build_graph, unique_edges, push_max
from repro.graphs import metrics as M


def test_generators_basic():
    for name, edges, n in G.regulargraphs_suite(small=True):
        assert edges.shape[1] == 2
        assert edges.min() >= 0 and edges.max() < n
        # no self loops, no duplicates
        assert (edges[:, 0] != edges[:, 1]).all()
        assert len(np.unique(edges, axis=0)) == len(edges)


def test_padded_graph_roundtrip():
    e, n = G.grid(7, 5)
    g = build_graph(e, n)
    assert g.n == n and g.m == len(e)
    back = unique_edges(g)
    assert np.array_equal(np.sort(back, axis=0), np.sort(e, axis=0))
    # degree sum = 2m
    assert int(g.degrees().sum()) == 2 * g.m


def test_push_max_is_one_hop_max():
    import networkx as nx
    e, n = G.gnp(40, 4.0, 3)
    g = build_graph(e, n)
    import jax.numpy as jnp
    vals = jnp.asarray(np.arange(g.n_pad), jnp.int32)
    out = np.asarray(push_max(g, vals))
    nxg = nx.Graph(e.tolist())
    for v in range(n):
        nbrs = list(nxg.neighbors(v)) if v in nxg else []
        expect = max(nbrs) if nbrs else -1
        assert out[v] == expect, (v, out[v], expect)


def test_crossings_grid_layout_zero():
    e, n = G.grid(6, 6)
    xs, ys = np.meshgrid(np.arange(6), np.arange(6))
    pos = np.stack([xs.ravel(), ys.ravel()], 1).astype(np.float32)
    assert M.count_crossings(pos, e) == 0
    assert M.neld(pos, e) == 0.0


def test_crossings_match_bruteforce():
    rng = np.random.default_rng(1)
    e, n = G.gnp(24, 3.0, 2)
    pos = rng.random((n, 2)).astype(np.float32)

    def brute(pos, edges):
        def o(p, q, r):
            return (q[0]-p[0])*(r[1]-p[1])-(q[1]-p[1])*(r[0]-p[0])
        cnt = 0
        for i in range(len(edges)):
            for j in range(i + 1, len(edges)):
                a, b = edges[i], edges[j]
                if len({a[0], a[1], b[0], b[1]}) < 4:
                    continue
                if (o(pos[a[0]], pos[a[1]], pos[b[0]]) *
                        o(pos[a[0]], pos[a[1]], pos[b[1]]) < 0 and
                        o(pos[b[0]], pos[b[1]], pos[a[0]]) *
                        o(pos[b[0]], pos[b[1]], pos[a[1]]) < 0):
                    cnt += 1
        return cnt

    assert M.count_crossings(pos, e) == brute(pos, e)


def test_bfs_distances_match_networkx():
    import networkx as nx
    e, n = G.scale_free(60, 2, 4)
    D = M.bfs_distances(e, n, np.array([0, 5]))
    nxg = nx.Graph(e.tolist())
    sp = nx.single_source_shortest_path_length(nxg, 0)
    for v in range(n):
        assert D[0][v] == sp.get(v, -1)
