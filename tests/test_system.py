"""End-to-end behaviour tests: training descends + resumes; layout pipeline
reproduces the paper's quality behavior on CI-scale instances."""
import os

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_loss_descends_and_resumes(tmp_path):
    from repro.launch.train import main
    ckpt = str(tmp_path / "run")
    loss1 = main(["--arch", "gemma-2b", "--smoke", "--steps", "30",
                  "--seq", "128", "--batch", "4", "--ckpt", ckpt,
                  "--ckpt-every", "15", "--log-every", "100"])
    assert loss1 < 6.0   # init loss ≈ log(512) ≈ 6.2
    # resume continues from step 30 (checkpointed) to 40
    loss2 = main(["--arch", "gemma-2b", "--smoke", "--steps", "40",
                  "--seq", "128", "--batch", "4", "--ckpt", ckpt,
                  "--resume", "auto", "--log-every", "100"])
    assert loss2 < loss1 + 0.5


def test_train_with_compression_descends(tmp_path):
    from repro.launch.train import main
    loss = main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "30",
                 "--seq", "128", "--batch", "4", "--compress-grads",
                 "--log-every", "100"])
    assert loss < 6.0


def test_layout_pipeline_end_to_end(tmp_path):
    from repro.launch.layout import main
    rep = main(["--graph", "grid", "--args", "10", "10",
                "--svg", str(tmp_path / "g.svg")])
    assert rep["cre"] < 0.1
    assert (tmp_path / "g.svg").exists()


def test_layout_flat_engine():
    from repro.launch.layout import main
    rep = main(["--graph", "tree", "--args", "3", "4", "--engine", "flat",
                "--no-cre"])
    assert rep["neld"] > 0
