"""Batched multi-graph layout (core/multilevel.py:multigila_layout_many).

Three contracts (DESIGN.md §9):
  * PARITY — every graph of a batch gets BIT-IDENTICAL positions to the
    sequential single-graph bucketed driver: B=1, homogeneous batches,
    mixed-bucket batches (which must split into groups), disconnected
    graphs, and the neighbor/grid refine modes;
  * WARM PATH — a fresh same-bucket batch triggers ZERO new compiles
    (``bucketing.cache_stats``);
  * PLUMBING — lane re-padding rewrites sentinels correctly, the
    incidence-gather aggregation is bitwise equal to ``segment_sum``, and
    the ``LayoutService`` front door coalesces concurrent requests into
    batched driver calls.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graphs import generators as G, build_graph
from repro.graphs.graph import unique_edges
from repro.graphs import packing
from repro.core import (LayoutConfig, multigila_layout,
                        multigila_layout_many, bucketing)
from repro.utils.transfer import io_boundary, no_implicit_transfers


@pytest.fixture(autouse=True)
def _no_implicit_transfers():
    """Hot-path tests run under jax.transfer_guard("disallow"); see the
    twin fixture in tests/test_bucketing.py."""
    with no_implicit_transfers():
        yield


def _assert_parity(graphs, cfg, seeds=None):
    outs = multigila_layout_many(graphs, cfg, seeds=seeds)
    assert len(outs) == len(graphs)
    for i, (e, n) in enumerate(graphs):
        scfg = (cfg if seeds is None
                else dataclasses.replace(cfg, seed=int(seeds[i])))
        ps, ss = multigila_layout(e, n, scfg)
        pb, sb = outs[i]
        assert sb.levels == ss.levels
        assert np.asarray(pb).shape == (n, 2)
        assert np.array_equal(np.asarray(pb), np.asarray(ps)), f"graph {i}"
    return outs


def test_single_graph_batch_bit_identical():
    _assert_parity([G.delaunay(150, 4)], LayoutConfig(seed=7))


def test_homogeneous_batch_bit_identical():
    gs = [G.delaunay(150, 10 + i) for i in range(4)]
    _assert_parity(gs, LayoutConfig(seed=5))


def test_mixed_bucket_batch_splits_into_groups():
    """Graphs whose levels land in different lane buckets must still come
    back bit-identical — the wave loop splits them into per-bucket groups
    (one compiled program each)."""
    gs = [G.delaunay(120, 3), G.delaunay(500, 4), G.grid(14, 14),
          G.scale_free(200, 2, 5)]
    keys = set()
    for e, n in gs:
        g0 = build_graph(e, n, bucket=True)
        keys.add(bucketing.lane_shape(g0.n, g0.m))
    assert len(keys) >= 2, "suite must actually span multiple lane buckets"
    _assert_parity(gs, LayoutConfig(seed=2))


def test_per_graph_seeds_and_disconnected_graph():
    """Per-graph seed overrides behave like per-graph LayoutConfig.seed;
    multi-component graphs go through per-component tasks + shelf packing
    identically to the sequential driver."""
    e1, n1 = G.delaunay(90, 1)
    e2, n2 = G.delaunay(70, 2)
    disc = (np.concatenate([e1, e2 + n1]), n1 + n2)
    gs = [disc, G.delaunay(150, 3)]
    _assert_parity(gs, LayoutConfig(seed=1), seeds=[11, 12])


@pytest.mark.parametrize("kw", [dict(exact_threshold=64),
                                dict(exact_threshold=64, grid_threshold=96)],
                         ids=["neighbor-mode", "grid-mode"])
def test_batched_neighbor_and_grid_modes(kw):
    """The batched neighbor-list and grid refine steps are also
    bit-identical (thresholds forced down so a 150-vertex graph exercises
    them)."""
    gs = [G.delaunay(150, 20 + i) for i in range(2)]
    _assert_parity(gs, LayoutConfig(seed=4, **kw))


def test_warm_path_zero_new_compiles():
    """Acceptance: a fresh same-bucket batch reuses every compiled program
    — no step-cache misses, no new jit trace entries."""
    cfg = LayoutConfig(seed=6)
    multigila_layout_many([G.delaunay(150, 70 + i) for i in range(3)], cfg)
    before = bucketing.cache_stats()
    assert before["jit_entries"] > 0, "jit cache probe broken"
    outs = multigila_layout_many([G.delaunay(150, 80 + i) for i in range(3)],
                                 cfg)
    after = bucketing.cache_stats()
    assert all(o[1].levels >= 2 for o in outs)
    assert after["misses"] == before["misses"], (before, after)
    assert after["jit_entries"] == before["jit_entries"], (before, after)
    assert after["hits"] > before["hits"]


def test_many_rejects_unsupported_configs():
    g = [G.grid(6, 6)]
    with pytest.raises(ValueError):
        multigila_layout_many(g, LayoutConfig(engine="flat"))
    with pytest.raises(ValueError):
        multigila_layout_many(g, LayoutConfig(bucketing=False))
    with pytest.raises(ValueError):
        multigila_layout_many(g, LayoutConfig(), seeds=[1, 2])


# -- packing plumbing ----------------------------------------------------------

def test_repad_graph_rewrites_sentinels():
    e, n = G.delaunay(60, 3)
    g = build_graph(e, n, bucket=True)            # n_pad 256
    g2 = packing.repad_graph(g, 64, 512)
    assert (g2.n_pad, g2.m_pad) == (64, 512)
    assert (g2.n, g2.m) == (g.n, g.m)
    src = np.asarray(g2.src)
    assert src[~np.asarray(g2.emask)].min() == 64          # new sentinel
    assert np.array_equal(unique_edges(g2), unique_edges(g))
    assert np.array_equal(np.asarray(g2.mass)[:n], np.asarray(g.mass)[:n])
    # round trip back up
    g3 = packing.repad_graph(g2, 256, g.m_pad)
    assert np.array_equal(unique_edges(g3), unique_edges(g))


def test_incidence_gather_bitwise_matches_segment_sum():
    """The unrolled incidence-gather aggregation (the batched driver's
    attraction) accumulates in exactly segment_sum's float order."""
    e, n = G.delaunay(80, 5)
    g = build_graph(e, n, bucket=True)
    inc, k = packing.incidence_table(g, 32)
    assert inc is not None and inc.shape == (g.n_pad, 32)
    rng = np.random.default_rng(0)
    with io_boundary():                 # eager op-by-op reference: every
        # primitive stages its scalar constants h2d, so the whole
        # computation is an intentional boundary (the production path runs
        # the same aggregation inside one jitted step)
        vec = jnp.asarray(rng.standard_normal((g.m_pad, 2)).astype(np.float32))
        vec = jnp.where(jnp.asarray(g.emask)[:, None], vec, 0.0)
        seg = jax.ops.segment_sum(vec, g.dst,
                                  num_segments=g.n_pad + 1)[: g.n_pad]
        vflat = jnp.concatenate([vec, jnp.zeros((1, 2), vec.dtype)], axis=0)
        acc = jnp.zeros((g.n_pad, 2), jnp.float32)
        for col in range(k):
            acc = acc + vflat[inc[:, col]]
        assert bool(jnp.all(acc == seg))


def test_incidence_table_hub_fallback():
    star = np.stack([np.zeros(40, np.int64),
                     np.arange(1, 41, dtype=np.int64)], axis=1)
    g = build_graph(star, 41, bucket=True)
    inc, dmax = packing.incidence_table(g, 32)
    assert inc is None and dmax == 40          # → flat-scatter path


def test_lane_bucket_floor():
    assert packing.lane_bucket(1) == 8
    assert packing.lane_bucket(8) == 8
    assert packing.lane_bucket(9) == 16
    assert packing.lane_bucket(16) == 16
    assert packing.lane_bucket(17) == 32


# -- the service front door ----------------------------------------------------

def test_layout_service_coalesces_and_matches():
    from repro.serve import LayoutService
    cfg = LayoutConfig(seed=2)
    svc = LayoutService(cfg, max_batch=8, window_s=0.05)
    try:
        gs = [G.delaunay(100, 40 + i) for i in range(4)]
        futs = [svc.submit(e, n) for e, n in gs]
        res = [f.result(timeout=300) for f in futs]
        for (e, n), (pos, stats) in zip(gs, res):
            ps, ss = multigila_layout(e, n, cfg)
            assert stats.levels == ss.levels
            assert np.array_equal(np.asarray(pos), np.asarray(ps))
        assert svc.requests == 4
        assert svc.batches <= 4            # window coalescing happened at all
        # malformed requests are rejected at submit(), never reaching the
        # shared batch (one bad graph must not fail its whole window)
        with pytest.raises(ValueError):
            svc.submit(np.array([[0, 5]]), 3)
        with pytest.raises(ValueError):
            svc.submit(np.array([[-1, 2]]), 4)
        with pytest.raises(ValueError):
            svc.submit(np.zeros((0, 2)), 0)
    finally:
        svc.close()
    with pytest.raises(RuntimeError):
        svc.submit(*G.grid(4, 4))
