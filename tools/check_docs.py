"""Docs-consistency check (CI): every Markdown file referenced from the
source tree must exist.

Scans ``src/**/*.py`` (docstrings + comments + string literals) for
references to Markdown files and resolves each against the repo root, the
source roots, and the referencing file's own directory. Fails listing the
dangling references — this is what keeps citations like "DESIGN.md §4.3"
honest.

    python tools/check_docs.py

Paths under results/ are generated outputs, not docs, and are skipped.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
MD_REF = re.compile(r"[\w][\w./-]*\.md\b")


def references(py: pathlib.Path) -> set[str]:
    refs = set()
    for tok in MD_REF.findall(py.read_text(encoding="utf-8")):
        tok = tok.lstrip("./")
        if tok.startswith("results/"):
            continue                       # generated output, not a doc
        refs.add(tok)
    return refs


def resolves(ref: str, py: pathlib.Path) -> bool:
    bases = [REPO, REPO / "src", REPO / "src" / "repro", py.parent]
    return any((b / ref).is_file() for b in bases)


def main() -> int:
    missing = []
    for py in sorted((REPO / "src").rglob("*.py")):
        for ref in sorted(references(py)):
            if not resolves(ref, py):
                missing.append((py.relative_to(REPO), ref))
    if missing:
        print("dangling Markdown references:")
        for py, ref in missing:
            print(f"  {py}: {ref}")
        return 1
    print("docs consistency OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
