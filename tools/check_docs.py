"""Docs-consistency check (CI), two directions:

1. every Markdown file referenced from ``src/**/*.py`` (docstrings,
   comments, string literals) must exist — keeps citations like
   "DESIGN.md §4.3" honest;
2. every backticked code reference in DESIGN.md / EXPERIMENTS.md —
   ``core/multilevel.py:multigila_layout_many`` file:symbol style or
   ``graphs.graph.bucket_pad`` dotted style — must resolve to a real file
   and a top-level symbol in it (checked via AST, no imports), so the
   docs cannot drift from a rename.

    python tools/check_docs.py

Paths under results/ are generated outputs, not docs, and are skipped.
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
MD_REF = re.compile(r"[\w][\w./-]*\.md\b")
CODE_SPAN = re.compile(r"`([^`\n]+)`")
FILE_REF = re.compile(r"^([\w][\w/.-]*\.py)(?::([A-Za-z_]\w*))?$")
DOTTED_REF = re.compile(r"^[a-z_][\w]*(\.[A-Za-z_]\w*){1,4}$")
# directories a dotted reference may start from (module search roots)
DOC_ROOTS = [REPO, REPO / "src", REPO / "src" / "repro",
             REPO / "src" / "repro" / "kernels"]
CHECKED_DOCS = ["DESIGN.md", "EXPERIMENTS.md"]


def references(py: pathlib.Path) -> set[str]:
    refs = set()
    for tok in MD_REF.findall(py.read_text(encoding="utf-8")):
        tok = tok.lstrip("./")
        if tok.startswith("results/"):
            continue                       # generated output, not a doc
        refs.add(tok)
    return refs


def resolves(ref: str, py: pathlib.Path) -> bool:
    bases = [REPO, REPO / "src", REPO / "src" / "repro", py.parent]
    return any((b / ref).is_file() for b in bases)


def _top_level_names(py: pathlib.Path) -> set[str]:
    """Top-level def/class/assignment names of a module (AST, no import)."""
    tree = ast.parse(py.read_text(encoding="utf-8"))
    names: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            names |= {t.id for t in node.targets if isinstance(t, ast.Name)}
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            names.add(node.target.id)
        elif isinstance(node, ast.ImportFrom):
            names |= {(a.asname or a.name) for a in node.names}
    return names


def _module_file(parts: list[str]) -> pathlib.Path | None:
    for root in DOC_ROOTS:
        as_mod = root.joinpath(*parts).with_suffix(".py")
        if as_mod.is_file():
            return as_mod
        as_pkg = root.joinpath(*parts) / "__init__.py"
        if as_pkg.is_file():
            return as_pkg
    return None


def check_code_ref(ref: str) -> str | None:
    """None if ``ref`` resolves (or is not a code reference at all);
    otherwise a reason string."""
    m = FILE_REF.match(ref)
    if m:
        path, symbol = m.groups()
        for root in DOC_ROOTS:
            f = root / path
            if f.is_file():
                if symbol and symbol not in _top_level_names(f):
                    return f"no top-level '{symbol}' in {path}"
                return None
        return "file not found"
    if not DOTTED_REF.match(ref):
        return None                        # prose/jnp.float32/etc — skip
    parts = ref.split(".")
    # only audit dotted refs anchored at a real source dir/module — this
    # is what keeps `np.random` or `time.perf_counter` out of scope
    if not any((r / parts[0]).is_dir() or (r / f"{parts[0]}.py").is_file()
               for r in DOC_ROOTS):
        return None
    if _module_file(parts) is not None:    # whole ref is a module
        return None
    mod = _module_file(parts[:-1])
    if mod is not None:
        if parts[-1] in _top_level_names(mod):
            return None
        return f"no top-level '{parts[-1]}' in {mod.relative_to(REPO)}"
    return "module not found"


def doc_code_refs() -> list[tuple[str, str, str]]:
    """(doc, ref, reason) for every dangling code reference in the docs."""
    bad = []
    for name in CHECKED_DOCS:
        doc = REPO / name
        if not doc.is_file():
            continue
        for ref in sorted(set(CODE_SPAN.findall(
                doc.read_text(encoding="utf-8")))):
            reason = check_code_ref(ref.strip())
            if reason is not None:
                bad.append((name, ref, reason))
    return bad


def main() -> int:
    missing = []
    for py in sorted((REPO / "src").rglob("*.py")):
        for ref in sorted(references(py)):
            if not resolves(ref, py):
                missing.append((py.relative_to(REPO), ref))
    bad_code = doc_code_refs()
    if missing:
        print("dangling Markdown references:")
        for py, ref in missing:
            print(f"  {py}: {ref}")
    if bad_code:
        print("dangling code references in docs:")
        for doc, ref, reason in bad_code:
            print(f"  {doc}: `{ref}` — {reason}")
    if missing or bad_code:
        return 1
    print("docs consistency OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
