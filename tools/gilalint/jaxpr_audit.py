"""Layer 2 of gilalint: trace every registered cached-step family and audit
the jaxprs the production code would actually run.

The AST layer (rules.py) reasons about source; this layer reasons about the
traced program. For each family it calls the PRODUCTION staging entry point
(``bucketing.cached_refine``, ``bucketing.cached_refine_many``,
``distributed.cached_layout_step``) on small representative graphs, then
checks:

  A1  no host round-trips: the jaxpr contains no callback / infeed /
      outfeed / device_put primitives (anywhere, including sub-jaxprs of
      while/scan/pjit/shard_map) — a hot step must stay on device.
  A2  dtype discipline: no float64/complex128 avals anywhere in the traced
      program (CPU silently eats f64; accelerators pay 2x for it).
  A3  donation: with ``donate_argnums_if_supported`` forced on (it is a
      no-op on CPU), the builder's jit donates argument 0 — the position
      buffer — so accelerators update positions in place.
  A4  padding invariance, structurally: two graphs with DIFFERENT true
      sizes in the SAME shape bucket must produce the identical cache key
      and a textually identical jaxpr — the compiled program may depend on
      the bucket only, never on the payload.

``run_audit()`` returns a JSON-ready report; any entry in a family's
``failures`` list fails the CLI (tools/gilalint/__main__.py) and CI.
Keep graphs here tiny: the audit only traces (and lowers, for A3); it
never executes a step.
"""
from __future__ import annotations

import contextlib

import numpy as np

# primitive names that imply a host round-trip or transfer inside the step
_BANNED_SUBSTRINGS = ("callback",)
_BANNED_PRIMS = {
    "infeed", "outfeed", "device_put", "copy_to_host_async",
    "host_local_array_to_global_array", "global_array_to_host_local_array",
}
_BANNED_DTYPES = {"float64", "complex128"}


# -- jaxpr walking -------------------------------------------------------------

def _sub_jaxprs(value):
    """Jaxprs hiding inside an eqn param (ClosedJaxpr, Jaxpr, or lists of
    either — e.g. cond branches)."""
    vals = value if isinstance(value, (list, tuple)) else (value,)
    for v in vals:
        inner = getattr(v, "jaxpr", v)       # ClosedJaxpr -> Jaxpr
        if hasattr(inner, "eqns"):
            yield inner


def iter_eqns(jaxpr):
    """Every eqn in ``jaxpr`` and, recursively, in its sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from iter_eqns(sub)


def primitive_names(closed) -> set:
    return {e.primitive.name for e in iter_eqns(closed.jaxpr)}


def aval_dtypes(closed) -> set:
    """Dtype names of every var flowing through the program."""
    out = set()

    def scoop(jaxpr):
        for v in list(jaxpr.invars) + list(jaxpr.constvars):
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None:
                out.add(str(dt))
        for eqn in jaxpr.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                dt = getattr(getattr(v, "aval", None), "dtype", None)
                if dt is not None:
                    out.add(str(dt))
            for p in eqn.params.values():
                for sub in _sub_jaxprs(p):
                    scoop(sub)

    scoop(closed.jaxpr)
    return out


def _check_program(family: str, closed, failures: list) -> dict:
    """A1 + A2 on one traced program; returns summary facts."""
    prims = primitive_names(closed)
    bad = sorted(
        p for p in prims
        if p in _BANNED_PRIMS or any(s in p for s in _BANNED_SUBSTRINGS))
    for p in bad:
        failures.append({
            "rule": "A1",
            "message": f"{family}: host-transfer/callback primitive "
                       f"'{p}' inside the cached step — hot steps must "
                       f"stay on device (stage inputs before the call)"})
    dts = aval_dtypes(closed)
    for dt in sorted(dts & _BANNED_DTYPES):
        failures.append({
            "rule": "A2",
            "message": f"{family}: {dt} aval in the cached step — keep "
                       f"kernels in f32 (gilalint R6 flags the source "
                       f"site)"})
    return {"n_primitives": len(prims), "dtypes": sorted(dts)}


def _donates_arg0(jitted, *args) -> bool:
    """True if tracing ``jitted`` yields a top-level pjit that donates its
    first argument (the position buffer)."""
    import jax
    closed = jax.make_jaxpr(jitted)(*args)
    for eqn in closed.jaxpr.eqns:
        if eqn.primitive.name == "pjit":
            donated = eqn.params.get("donated_invars")
            return bool(donated) and bool(donated[0])
    return False


@contextlib.contextmanager
def _donation_forced():
    """Force ``donate_argnums_if_supported`` on: on CPU it returns () (XLA
    ignores donation there), which would make A3 vacuous."""
    from repro.core import bucketing
    orig = bucketing.donate_argnums_if_supported
    bucketing.donate_argnums_if_supported = lambda *argnums: tuple(argnums)
    try:
        yield
    finally:
        bucketing.donate_argnums_if_supported = orig


# -- shared fixtures -----------------------------------------------------------

def _path_graph(n: int):
    from repro.graphs.graph import build_graph
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    return build_graph(edges, n, bucket=True)


def _sched(n: int, n_pad: int, engine: str = "gila"):
    from repro.core.schedule import make_schedule
    return make_schedule(0, 1, n, n - 1, n_pad=n_pad, engine=engine)


# -- the registered families ---------------------------------------------------

def _audit_single(engine: str = "gila") -> dict:
    """bucketing.cached_refine — the single-graph bucketed level step."""
    import jax
    import jax.numpy as jnp

    from repro.core import bucketing
    from repro.core.gila import random_init
    from repro.utils.transfer import io_boundary

    failures: list = []
    traced = []
    # two true sizes, one 256-vertex bucket — the A4 pair
    for n in (70, 90):
        g = _path_graph(n)
        sched = _sched(n, g.n_pad, engine)
        pos0 = random_init(g, 1.0, seed=0)
        with io_boundary():
            nbr_idx = jnp.zeros((g.n_pad, 1), jnp.int32)
            nbr_mask = jnp.zeros((g.n_pad, 1), bool)
        key, fn, _, args = bucketing.cached_refine(
            g, pos0, sched, nbr_idx, nbr_mask, ideal_len=1.0, rep_const=1.0)
        traced.append((n, key, jax.make_jaxpr(fn)(*args), args, sched))

    (_, key_a, jx_a, args, sched), (_, key_b, jx_b, _, _) = traced
    facts = _check_program(f"refine_single[{engine}]", jx_a, failures)
    if key_a != key_b:
        failures.append({
            "rule": "A4",
            "message": f"refine_single: same-bucket graphs produced "
                       f"different cache keys {key_a} vs {key_b}"})
    if str(jx_a) != str(jx_b):
        failures.append({
            "rule": "A4",
            "message": "refine_single: same-bucket graphs traced to "
                       "structurally different jaxprs — the step depends "
                       "on payload, not just the shape bucket"})
    with _donation_forced():
        fn2 = bucketing._build_refine(sched.mode, sched.grid_dim,
                                      sched.cell_cap, engine=engine)
        if not _donates_arg0(fn2, *args):
            failures.append({
                "rule": "A3",
                "message": "refine_single: position buffer (arg 0) is "
                           "not donated by _build_refine's jit"})
    return {"entry": "core.bucketing.cached_refine", "cache_key": repr(key_a),
            "failures": failures, **facts}


def _audit_many(engine: str = "gila") -> dict:
    """bucketing.cached_refine_many — the batched multi-graph lane step."""
    import jax
    import jax.numpy as jnp

    from repro.core import bucketing
    from repro.core.gila import random_init
    from repro.utils.transfer import io_boundary

    failures: list = []
    traced = []
    # two true sizes, one 64-vertex/512-edge lane bucket
    for n in (40, 55):
        g = _path_graph(n)
        sched = _sched(n, g.n_pad, engine)
        pos0 = random_init(g, 1.0, seed=0)
        req = bucketing.make_request(g, pos0, sched, seed=0)
        with io_boundary():
            dummy = (jnp.zeros((req.g.n_pad, 1), jnp.int32),
                     jnp.zeros((req.g.n_pad, 1), bool))
        key, fn, _, args = bucketing.cached_refine_many(
            [req], [dummy], ideal_len=1.0, rep_const=1.0)
        traced.append((key, jax.make_jaxpr(fn)(*args), args, req))

    (key_a, jx_a, args, req), (key_b, jx_b, _, _) = traced
    facts = _check_program(f"refine_many[{engine}]", jx_a, failures)
    if key_a != key_b:
        failures.append({
            "rule": "A4",
            "message": f"refine_many: same-lane-bucket graphs produced "
                       f"different cache keys {key_a} vs {key_b}"})
    if str(jx_a) != str(jx_b):
        failures.append({
            "rule": "A4",
            "message": "refine_many: same-lane-bucket graphs traced to "
                       "structurally different jaxprs"})
    with _donation_forced():
        fn2 = bucketing._build_refine_many(
            req.sched.mode, req.sched.grid_dim, req.sched.cell_cap,
            req.inc_k, engine=engine)
        if not _donates_arg0(fn2, *args):
            failures.append({
                "rule": "A3",
                "message": "refine_many: position batch (arg 0) is not "
                           "donated by _build_refine_many's jit"})
    return {"entry": "core.bucketing.cached_refine_many",
            "cache_key": repr(key_a), "failures": failures, **facts}


def _audit_dist(engine: str = "gila") -> dict:
    """distributed.cached_layout_step — the sharded level superstep.

    Traced through ShapeDtypeStructs (no allocation) on a host mesh over
    whatever devices exist — 8 forced CPU devices from the CLI, 1 in a
    bare pytest process; both shard the same program structure.
    """
    import jax

    from repro.core import bucketing, distributed
    from repro.launch.mesh import make_host_mesh

    failures: list = []
    mesh = make_host_mesh()
    vtx = distributed.vtx_axes(mesh)
    vsize = distributed._axis_size(mesh, vtx)
    msize = mesh.shape["model"]

    traced = []
    for n in (70, 90):
        g = _path_graph(n)
        n_pad = distributed._round_up(g.n_pad, vsize * msize)
        _, _, _, _, m_pad = distributed.partition_edges(
            np.asarray(g.src), np.asarray(g.dst), np.asarray(g.emask),
            np.asarray(g.ewt), n_pad, vsize, bucket=True)
        jitted, _, _ = distributed.cached_layout_step(
            mesh, n_pad, m_pad, 1, mode="exact", engine=engine)
        specs = distributed.layout_step_specs(n_pad, m_pad, 1, mode="exact",
                                              engine=engine)
        args = tuple(specs.values())
        traced.append(((n_pad, m_pad), jax.make_jaxpr(jitted)(*args), args))

    (shape_a, jx_a, args), (shape_b, jx_b, _) = traced
    facts = _check_program(f"dist_step[{engine}]", jx_a, failures)
    if shape_a != shape_b:
        failures.append({
            "rule": "A4",
            "message": f"dist_step: same-bucket graphs landed in "
                       f"different (n_pad, m_pad) {shape_a} vs {shape_b} "
                       f"— partition_edges bucketing regressed"})
    if str(jx_a) != str(jx_b):
        failures.append({
            "rule": "A4",
            "message": "dist_step: same-bucket graphs traced to "
                       "structurally different jaxprs"})
    with _donation_forced():
        step, _ = distributed.layout_train_step(
            mesh, shape_a[0], shape_a[1], 1, mode="exact", engine=engine)
        jd = jax.jit(
            step,
            donate_argnums=bucketing.donate_argnums_if_supported(0))
        if not _donates_arg0(jd, *args):
            failures.append({
                "rule": "A3",
                "message": "dist_step: position buffer (arg 0) is not "
                           "donated by cached_layout_step's jit"})
    return {"entry": "core.distributed.cached_layout_step",
            "cache_key": repr(("dist_step",) + shape_a),
            "mesh": dict(mesh.shape), "failures": failures, **facts}


def _audit_merger() -> dict:
    """solar_merger.cached_merger — the device-resident coarsening loop
    (election → growth → halting vote as one ``lax.while_loop``)."""
    import jax

    from repro.core import solar_merger
    from repro.utils.transfer import io_boundary

    failures: list = []
    traced = []
    # two true sizes, one 256-vertex bucket — the A4 pair
    for n in (70, 90):
        g = _path_graph(n)
        st = solar_merger.init_state(g)
        with io_boundary():
            rng = jax.random.PRNGKey(0)
        key, fn, _, args = solar_merger.cached_merger(
            g, st, rng, p_sun=0.35, max_rounds=96, force_every=4)
        traced.append((key, jax.make_jaxpr(fn)(*args), args))

    (key_a, jx_a, args), (key_b, jx_b, _) = traced
    facts = _check_program("merger", jx_a, failures)
    if key_a != key_b:
        failures.append({
            "rule": "A4",
            "message": f"merger: same-bucket graphs produced different "
                       f"cache keys {key_a} vs {key_b}"})
    if str(jx_a) != str(jx_b):
        failures.append({
            "rule": "A4",
            "message": "merger: same-bucket graphs traced to structurally "
                       "different jaxprs — the loop depends on payload, "
                       "not just the shape bucket"})
    with _donation_forced():
        fn2 = solar_merger._build_merger()
        if not _donates_arg0(fn2, *args):
            failures.append({
                "rule": "A3",
                "message": "merger: MergerState (arg 0) is not donated by "
                           "_build_merger's jit — the loop must update the "
                           "assignment buffers in place"})
    return {"entry": "core.solar_merger.cached_merger",
            "cache_key": repr(key_a), "failures": failures, **facts}


def _audit_coarsen() -> dict:
    """solar_merger.cached_compact + cached_assemble — the two halves of
    the on-device ``next_level`` compaction (input-bucket compaction, then
    coarse-bucket assembly around the host's true-size read)."""
    import jax

    from repro.core import solar_merger

    failures: list = []
    traced = []
    for n in (70, 90):
        g = _path_graph(n)
        st = solar_merger.init_state(g)
        key, fn, _, args = solar_merger.cached_compact(g, st)
        traced.append((key, jax.make_jaxpr(fn)(*args), args))

    (key_a, jx_a, cargs), (key_b, jx_b, _) = traced
    facts = _check_program("coarsen.compact", jx_a, failures)
    if key_a != key_b:
        failures.append({
            "rule": "A4",
            "message": f"coarsen: same-bucket graphs produced different "
                       f"compact cache keys {key_a} vs {key_b}"})
    if str(jx_a) != str(jx_b):
        failures.append({
            "rule": "A4",
            "message": "coarsen: same-bucket graphs traced to structurally "
                       "different compact jaxprs"})

    # assemble: trace at one coarse bucket decision; its key is pure shape
    # statics, so the A4 pair shares it by construction — audit A1/A2/A3
    import jax.numpy as jnp
    from repro.utils.transfer import io_boundary
    (parent_coarse, sun_of, depth, state, spi, n_coarse, cmass,
     ce_lo, ce_hi, ce_w, n_edges) = jax.eval_shape(
        lambda *a: solar_merger._build_compact()(*a), *cargs)
    with io_boundary():
        a_args = (jnp.zeros(ce_lo.shape, jnp.int32),
                  jnp.zeros(ce_hi.shape, jnp.int32),
                  jnp.zeros(ce_w.shape, jnp.float32),
                  jnp.asarray(0, jnp.int32),
                  jnp.zeros(cmass.shape, jnp.float32),
                  jnp.asarray(0, jnp.int32))
    akey, afn, _, aargs = solar_merger.cached_assemble(
        *a_args, n_pad_c=256, m_pad_c=256)
    ajx = jax.make_jaxpr(afn)(*aargs)
    _check_program("coarsen.assemble", ajx, failures)

    with _donation_forced():
        if not _donates_arg0(solar_merger._build_compact(), *cargs):
            failures.append({
                "rule": "A3",
                "message": "coarsen: MergerState (arg 0) is not donated by "
                           "_build_compact's jit"})
        if not _donates_arg0(solar_merger._build_assemble(256, 256), *aargs):
            failures.append({
                "rule": "A3",
                "message": "coarsen: edge buffer (arg 0) is not donated by "
                           "_build_assemble's jit"})
    return {"entry": "core.solar_merger.cached_compact + cached_assemble",
            "cache_key": repr((key_a, akey)), "failures": failures, **facts}


# every cached-step family in the repo; adding a CompileCache user without
# registering it here is itself a finding (A0) raised by tests/test_gilalint
FAMILIES = (
    ("refine_single", _audit_single),
    ("refine_many", _audit_many),
    ("dist_step", _audit_dist),
    ("merger", _audit_merger),
    ("coarsen", _audit_coarsen),
    # the stress engine's step family: same staging entry points, engine id
    # widened into the cache key (see core/engine.py)
    ("refine_single_stress", lambda: _audit_single("stress")),
    ("refine_many_stress", lambda: _audit_many("stress")),
    ("dist_step_stress", lambda: _audit_dist("stress")),
)


def run_audit() -> dict:
    """Trace + audit every family. Harness errors become A0 failures so a
    broken audit fails CI loudly instead of passing vacuously."""
    families = {}
    for name, fn in FAMILIES:
        try:
            families[name] = fn()
        except Exception as exc:          # noqa: BLE001 - report, don't mask
            families[name] = {
                "entry": None,
                "failures": [{"rule": "A0",
                              "message": f"{name}: audit harness error: "
                                         f"{exc!r}"}],
            }
    return {"families": families}
