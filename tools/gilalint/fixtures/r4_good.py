"""R4 negative: data-dependent selection on device, structure from statics."""
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("truncate",))
def step(x, *, truncate):
    y = jnp.where(x > 0, x, -x)            # select, don't branch
    if truncate:                           # static argument — fine
        y = y[:128]
    return y


def host_driver(x_np):
    if x_np.shape[0] > 128:                # untraced host code may branch
        return x_np[:128]
    return x_np
