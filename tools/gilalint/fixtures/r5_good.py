"""R5 negative: one spec per parameter, declared axes only."""
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map


def local(pos, w, params):
    return pos


def make(mesh):
    return shard_map(local, mesh=mesh,
                     in_specs=(P("data", None), P("data"), P()),
                     out_specs=P("data", None))
