"""R3 positives: host-sync hazards inside a jitted step."""
import jax
import numpy as np


@jax.jit
def step(x):
    total = x.sum()
    host = np.asarray(x)                   # pulls the traced value to host
    print("total so far:", total)          # trace-time (or callback) print
    return float(total) + host.mean()      # host sync inside the step
