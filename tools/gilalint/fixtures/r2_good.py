"""R2 negative: every builder input — including the ambient backend read —
appears in the cache key."""
import os

from repro.core.bucketing import CompileCache

CACHE = CompileCache()


def backend():
    return os.environ.get("REPRO_PALLAS", "auto")


def build(mode, cell_cap):
    def fn(x):
        return x[:cell_cap] if mode == "exact" and backend() else x
    return fn


def cached(n_pad, mode, cell_cap):
    key = ("step", n_pad, mode, cell_cap, backend())
    fn, fresh = CACHE.get(key, lambda: build(mode, cell_cap))
    return fn, fresh
