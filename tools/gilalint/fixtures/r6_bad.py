"""R6 positives: float64 creep in trace-reachable code."""
import jax
import jax.numpy as jnp


@jax.jit
def kernel(x):
    y = x.astype(float)                    # python float is float64
    z = jnp.zeros((4,), dtype=jnp.float64)
    return y + z
