"""R5 positives: shard_map arity mismatch + undeclared mesh axis."""
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map


def local(pos, w, params):
    return pos


def make(mesh):
    return shard_map(local, mesh=mesh,
                     in_specs=(P("data"), P("rows")),   # 2 specs, 3 params;
                     out_specs=P("data"))               # 'rows' undeclared
