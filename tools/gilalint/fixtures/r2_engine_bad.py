"""R2 positive: cache key without the engine id.

The builder dispatches on ``engine`` (the refinement-engine registry id,
core/engine.py) but the key tuple carries only the shape/mode statics — a
warm pass under a different engine would reuse the wrong compiled program.
"""
import os

from repro.core.bucketing import CompileCache

CACHE = CompileCache()


def backend():
    return os.environ.get("REPRO_PALLAS", "auto")


def build(mode, engine):
    def fn(x):
        return x * 2 if engine == "stress" and mode and backend() else x
    return fn


def cached(n_pad, mode, engine):
    key = ("refine", n_pad, mode, backend())
    fn, fresh = CACHE.get(key, lambda: build(mode, engine))
    return fn, fresh
