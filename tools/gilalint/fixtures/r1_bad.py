"""R1 positive: host-stateful randomness inside a jitted step."""
import jax
import numpy as np


@jax.jit
def step(x):
    noise = np.random.normal(size=3)       # nondeterministic at trace time
    return x + noise.sum()
