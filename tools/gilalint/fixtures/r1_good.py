"""R1 negative: randomness routed through explicit fold-in streams."""
import jax
import numpy as np


@jax.jit
def step(key, x):
    noise = jax.random.normal(key, x.shape)
    return x + noise


def host_setup(seed):
    # host-side, never traced: stateful numpy RNG is fine here
    return np.random.default_rng(seed).normal(size=3)
