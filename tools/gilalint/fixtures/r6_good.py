"""R6 negative: explicit float32 end-to-end."""
import jax
import jax.numpy as jnp


@jax.jit
def kernel(x):
    y = x.astype(jnp.float32)
    z = jnp.zeros((4,), dtype=jnp.float32)
    return y + z
