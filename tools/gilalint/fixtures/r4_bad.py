"""R4 positives: recompile / trace-error hazards."""
import jax


@jax.jit
def step(x):
    if x.sum() > 0:                        # Python branch on traced value
        x = -x
    return x


@jax.jit
def step_shape(x):
    if x.shape[0] > 128:                   # forks structure within a bucket
        return x[:128]
    return x


@jax.jit
def step_fmt(x):
    label = f"val={x}"                     # traced value has no concrete repr
    return x, label
