"""R2 positives: under-keyed compile-cache entries.

``cached_ambient``: the builder's trace reads ambient config (os.environ)
but the key has no backend component. ``cached_free``: the builder closes
over a static that never reaches the key tuple.
"""
import os

from repro.core.bucketing import CompileCache

CACHE = CompileCache()


def backend():
    return os.environ.get("REPRO_PALLAS", "auto")


def build(mode):
    def fn(x):
        return x if mode == "exact" and backend() else x
    return fn


def build2(mode, cell_cap):
    def fn(x):
        return x[:cell_cap] if mode else x
    return fn


def cached_ambient(n_pad, mode):
    key = ("step", n_pad, mode)
    fn, fresh = CACHE.get(key, lambda: build(mode))
    return fn, fresh


def cached_free(n_pad, mode, cell_cap):
    key = ("step2", n_pad, mode, backend())
    fn, fresh = CACHE.get(key, lambda: build2(mode, cell_cap))
    return fn, fresh
