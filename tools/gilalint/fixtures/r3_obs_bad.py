"""R3 positives: observability hooks reaching INSIDE a jitted step.

Feeding a metric or a span argument from a traced value forces a
device→host sync (or a trace error) in the middle of the compiled step —
instrumentation must ride the driver's EXISTING sync points
(block_until_ready / io_boundary), never the step function itself.
"""
import jax
import numpy as np

from repro.obs import metrics, trace

STEP_VALUE = metrics.REGISTRY.histogram("toy_step_value", "bad example")


@jax.jit
def step(x):
    total = x.sum()
    STEP_VALUE.observe(float(total))        # host sync to feed a metric
    trace.instant("step.total", value=np.asarray(total))  # d2h for a span arg
    return total
