"""R3 negative: the production instrumentation pattern (DESIGN.md §12).

The span brackets the driver's existing dispatch + ``block_until_ready``
pair, and metrics are fed from the already-synced host value — tracing
adds zero host↔device transfers to the step.
"""
import jax
import jax.numpy as jnp

from repro.obs import metrics, trace

STEP_VALUE = metrics.REGISTRY.histogram("toy_step_value", "good example")


@jax.jit
def step(x):
    return jnp.sum(x * x)


def driver(x):
    with trace.span("step.dispatch", cat="device"):
        out = step(x)
        out.block_until_ready()             # the driver's existing sync
    STEP_VALUE.observe(float(out))          # host-side, after the sync
    return out
