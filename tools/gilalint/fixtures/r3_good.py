"""R3 negative: reductions stay on device; the driver syncs once."""
import jax
import jax.numpy as jnp


@jax.jit
def step(x):
    total = jnp.sum(x)
    return total / x.shape[0]              # static shape read — no sync


def driver(x):
    out = step(x)
    return float(out)                      # single host sync, outside jit
