"""R2 negative: the engine id is a key component, like every other static
the builder dispatches on (the production pattern of cached_refine /
cached_refine_many after the engine-seam refactor)."""
import os

from repro.core.bucketing import CompileCache

CACHE = CompileCache()


def backend():
    return os.environ.get("REPRO_PALLAS", "auto")


def build(mode, engine):
    def fn(x):
        return x * 2 if engine == "stress" and mode and backend() else x
    return fn


def cached(n_pad, mode, engine):
    key = ("refine", engine, n_pad, mode, backend())
    fn, fresh = CACHE.get(key, lambda: build(mode, engine))
    return fn, fresh
