"""Seeded-violation fixtures for tests/test_gilalint.py.

Each ``rN_bad.py`` contains the smallest program that must trip rule N;
each ``rN_good.py`` is the idiomatic counterpart that must stay clean.
These files are test data — they are never imported, only parsed by the
linter (and ``python -m tools.gilalint`` on a bad fixture is the CI
fail-on-seeded-violation check).
"""
