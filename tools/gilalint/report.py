"""Finding type, baseline handling, and report rendering for gilalint."""
from __future__ import annotations

import dataclasses
import json
import pathlib


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint/audit finding, pointing at file:line with a fix hint."""
    file: str            # repo-relative posix path ("" for audit findings)
    line: int
    col: int
    rule: str            # "R1".."R6" (AST lint) or "A1".."A4" (jaxpr audit)
    message: str
    hint: str = ""

    @property
    def fingerprint(self) -> str:
        """Line-number-insensitive identity used for baseline matching, so
        unrelated edits above a (baselined) finding do not resurface it."""
        return f"{self.rule}:{self.file}:{self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        loc = f"{self.file}:{self.line}:{self.col}" if self.file else "<audit>"
        out = f"{loc}: {self.rule}: {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


def load_baseline(path: pathlib.Path | str | None) -> set[str]:
    """Fingerprints of accepted findings. The checked-in baseline ships —
    and must stay — EMPTY (tests/test_gilalint.py regression-tests this);
    the mechanism exists so a future emergency suppression is explicit,
    reviewed, and line-move-proof rather than an inline comment."""
    if path is None:
        return set()
    p = pathlib.Path(path)
    if not p.is_file():
        return set()
    entries = json.loads(p.read_text(encoding="utf-8"))
    out = set()
    for e in entries:
        out.add(e if isinstance(e, str)
                else f"{e['rule']}:{e['file']}:{e['message']}")
    return out


def render_text(findings) -> str:
    return "\n".join(f.render() for f in findings)
