"""CLI: ``python -m tools.gilalint src/repro [--json out.json] [--no-audit]``.

Exit code 0 ⟺ zero non-baselined AST findings and a clean jaxpr audit.
The checked-in baseline (tools/gilalint/baseline.json) ships empty and a
regression test keeps it that way — the CI gate therefore fails on ANY
finding.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="gilalint", description=__doc__)
    ap.add_argument("paths", nargs="+", help="files/directories to lint")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the full JSON report here")
    ap.add_argument("--no-audit", action="store_true",
                    help="skip the jaxpr audit (AST lint only, no jax)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: the checked-in one)")
    args = ap.parse_args(argv)

    here = pathlib.Path(__file__).resolve().parent
    repo_root = here.parent.parent
    baseline_path = pathlib.Path(args.baseline) if args.baseline \
        else here / "baseline.json"

    from tools.gilalint.report import load_baseline, render_text
    from tools.gilalint.rules import lint_paths

    findings = lint_paths(args.paths, repo_root=repo_root)
    baseline = load_baseline(baseline_path)
    fresh = [f for f in findings if f.fingerprint not in baseline]

    report = {
        "paths": [str(p) for p in args.paths],
        "findings": [f.to_dict() for f in fresh],
        "baselined": len(findings) - len(fresh),
        "audit": None,
    }

    audit_failures = []
    if not args.no_audit:
        # the distributed family shards over every visible device; give the
        # in-process CPU a few before jax initializes
        os.environ.setdefault(
            "XLA_FLAGS",
            "--xla_force_host_platform_device_count=8")
        from tools.gilalint.jaxpr_audit import run_audit
        audit = run_audit()
        report["audit"] = audit
        audit_failures = [f for fam in audit["families"].values()
                          for f in fam["failures"]]

    if args.json_out:
        pathlib.Path(args.json_out).write_text(
            json.dumps(report, indent=2, sort_keys=True) + "\n",
            encoding="utf-8")

    if fresh:
        print(render_text(fresh))
    if audit_failures:
        for f in audit_failures:
            print(f"<jaxpr audit> {f['rule']}: {f['message']}")
    n_fam = len(report["audit"]["families"]) if report["audit"] else 0
    print(f"gilalint: {len(fresh)} finding(s), "
          f"{report['baselined']} baselined, "
          f"{len(audit_failures)} audit failure(s) "
          f"across {n_fam} cached-step families")
    return 1 if (fresh or audit_failures) else 0


if __name__ == "__main__":
    sys.exit(main())
