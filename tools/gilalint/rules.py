"""Layer 1: AST lint over a source tree — no execution, no imports.

Repo-specific rules (DESIGN.md §10):

  R1  nondeterminism (np.random / random / time / datetime / uuid / secrets)
      reachable from a traced step function — randomness must route through
      utils/prng fold-in streams;
  R2  compile-cache key completeness — every per-call-varying input a
      ``CompileCache`` builder closes over must appear in the cache key,
      including AMBIENT config (os.environ reads like REPRO_PALLAS) read at
      trace time anywhere in the builder's call graph;
  R3  host-sync hazards inside traced functions — ``.item()``,
      ``float(x)``/``int(x)``/``bool(x)``, ``np.asarray``/``np.array`` on
      traced values, ``print``;
  R4  recompile / trace-break hazards — Python branches on traced values or
      on ``.shape`` of traced args, f-strings / ``str()`` of traced values;
  R5  shard_map ``in_specs`` arity vs. callee parameters; PartitionSpec /
      collective axis names checked against the axes declared in
      launch/mesh.py;
  R6  dtype discipline — float64/complex128 upcasts reachable from traced
      code or anywhere under kernels/.

The engine builds a cross-module index (imports, defs, aliases), marks
traced contexts (jit-decorated / jit-wrapped / loop-body / shard_map'd /
nested therein), and propagates a "traced-reach" relation along resolved
calls and function references — so a kernel helper five modules away from
the ``jax.jit`` call site is still checked.
"""
from __future__ import annotations

import ast
import builtins
import pathlib

from tools.gilalint.report import Finding

# function-position argument sinks that trace their callable at jit time
TRACE_HOFS = {
    "fori_loop", "scan", "while_loop", "cond", "switch", "map", "vmap",
    "pmap", "shard_map", "pallas_call", "associative_scan", "checkpoint",
    "remat", "grad", "value_and_grad", "custom_jvp", "custom_vjp",
}
# attributes whose access on a traced value is static (no host sync)
STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "aval", "sharding"}
NONDET_TIME = {"time", "time_ns", "perf_counter", "perf_counter_ns",
               "monotonic", "monotonic_ns", "process_time", "clock"}
NONDET_DATETIME = {"now", "utcnow", "today"}
COLLECTIVES = {"psum", "pmax", "pmin", "pmean", "all_gather", "ppermute",
               "all_to_all", "axis_index", "psum_scatter", "pshuffle",
               "axis_size", "pbroadcast", "pvary"}
F64_ATTRS = {"float64", "double", "longdouble", "complex128", "float128"}
BUILTIN_NAMES = set(dir(builtins))


def _terminal(node):
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _dotted(node):
    """'a.b.c' for a pure Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _names_in(node) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


class FuncInfo:
    """One function/lambda scope with its local bindings and trace flags."""

    def __init__(self, node, module, parent):
        self.node = node
        self.module = module
        self.parent = parent                 # FuncInfo | None
        self.name = getattr(node, "name", "<lambda>")
        self.children: list[FuncInfo] = []
        self.traced = False                  # directly traced (or nested in)
        self.traced_reach = False            # referenced from traced code
        self.imports: dict[str, tuple] = {}  # function-level imports
        args = node.args
        params = [a.arg for a in args.posonlyargs + args.args
                  + args.kwonlyargs]
        for extra in (args.vararg, args.kwarg):
            if extra is not None:
                params.append(extra.arg)
        self.params_order = params
        self.params = set(params)
        self.bound = set(params)
        self.static_params: set[str] = set()   # jit static_argnames/nums

    def scope_chain(self):
        f = self
        while f is not None:
            yield f
            f = f.parent


class ModuleInfo:
    def __init__(self, path: pathlib.Path, rel: str, dotted: str | None):
        self.path = path
        self.rel = rel                       # display path
        self.dotted = dotted                 # e.g. "repro.core.bucketing"
        self.tree = ast.parse(path.read_text(encoding="utf-8"), filename=rel)
        self.imports: dict[str, tuple] = {}  # alias -> ("mod", dotted) |
        #                                      ("from", pkg, name)
        self.top_funcs: dict[str, FuncInfo] = {}
        self.aliases: dict[str, str] = {}    # backend_mode = _mode
        self.module_names: set[str] = set()  # every module-level binding
        self.functions: list[FuncInfo] = []  # all FuncInfos, any depth


def _collect_imports(body_walker, into: dict):
    for node in body_walker:
        if isinstance(node, ast.Import):
            for a in node.names:
                into[a.asname or a.name.split(".")[0]] = (
                    "mod", a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                into[a.asname or a.name] = ("from", node.module, a.name)


class _ScopeBuilder(ast.NodeVisitor):
    """Populates ModuleInfo: FuncInfo tree, imports, bound names."""

    def __init__(self, mi: ModuleInfo):
        self.mi = mi
        self.stack: list[FuncInfo] = []

    def _bind(self, name: str):
        if self.stack:
            self.stack[-1].bound.add(name)
        else:
            self.mi.module_names.add(name)

    def _enter(self, node):
        fi = FuncInfo(node, self.mi, self.stack[-1] if self.stack else None)
        if self.stack:
            self.stack[-1].children.append(fi)
            self.stack[-1].bound.add(fi.name)
        else:
            self.mi.top_funcs.setdefault(fi.name, fi)
            self.mi.module_names.add(fi.name)
        self.mi.functions.append(fi)
        node._gila_func = fi
        self.stack.append(fi)
        for child in ast.iter_child_nodes(node):
            self.visit(child)
        self.stack.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = visit_Lambda = _enter

    def visit_ClassDef(self, node):
        self._bind(node.name)
        self.generic_visit(node)

    def visit_Import(self, node):
        _collect_imports([node],
                         self.stack[-1].imports if self.stack
                         else self.mi.imports)
        for a in node.names:
            self._bind(a.asname or a.name.split(".")[0])

    def visit_ImportFrom(self, node):
        _collect_imports([node],
                         self.stack[-1].imports if self.stack
                         else self.mi.imports)
        for a in node.names:
            self._bind(a.asname or a.name)

    def visit_Assign(self, node):
        for t in node.targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name):
                    self._bind(n.id)
        if (not self.stack and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Name)):
            self.mi.aliases[node.targets[0].id] = node.value.id
        self.generic_visit(node)

    def visit_AnnAssign(self, node):
        if isinstance(node.target, ast.Name):
            self._bind(node.target.id)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        if isinstance(node.target, ast.Name):
            self._bind(node.target.id)
        self.generic_visit(node)

    def visit_For(self, node):
        for n in ast.walk(node.target):
            if isinstance(n, ast.Name):
                self._bind(n.id)
        self.generic_visit(node)

    def visit_With(self, node):
        for item in node.items:
            if item.optional_vars is not None:
                for n in ast.walk(item.optional_vars):
                    if isinstance(n, ast.Name):
                        self._bind(n.id)
        self.generic_visit(node)

    def visit_comprehension(self, node):
        for n in ast.walk(node.target):
            if isinstance(n, ast.Name):
                self._bind(n.id)
        self.generic_visit(node)


def _own_nodes(fi: FuncInfo):
    """Walk a function's own body, not descending into nested functions."""
    stack = list(ast.iter_child_nodes(fi.node))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


class Index:
    """Cross-module name resolution + call/reference graph."""

    def __init__(self, modules: list[ModuleInfo]):
        self.modules = modules
        self.by_dotted = {m.dotted: m for m in modules if m.dotted}

    def module_func(self, mi: ModuleInfo, name: str) -> FuncInfo | None:
        seen = set()
        while name in mi.aliases and name not in mi.top_funcs \
                and name not in seen:
            seen.add(name)
            name = mi.aliases[name]
        return mi.top_funcs.get(name)

    def _import_target(self, entry) -> tuple:
        """('module', ModuleInfo) | ('func', FuncInfo) | ('ext', dotted)."""
        kind = entry[0]
        if kind == "mod":
            m = self.by_dotted.get(entry[1])
            return ("module", m) if m else ("ext", entry[1])
        _, pkg, name = entry
        m = self.by_dotted.get(f"{pkg}.{name}")
        if m:
            return ("module", m)
        src = self.by_dotted.get(pkg)
        if src:
            f = self.module_func(src, name)
            if f:
                return ("func", f)
            return ("none", None)
        return ("ext", f"{pkg}.{name}")

    def lookup(self, name: str, fi: FuncInfo | None, mi: ModuleInfo):
        """Resolve a bare name to ('func', FuncInfo) / ('module', ModuleInfo)
        / ('ext', dotted) / ('none', None) through the scope chain."""
        chain = list(fi.scope_chain()) if fi else []
        for f in chain:
            for child in f.children:
                if child.name == name:
                    return ("func", child)
            if name in f.imports:
                return self._import_target(f.imports[name])
            if name in f.bound:
                return ("none", None)       # plain local binding
        if name in mi.top_funcs or name in mi.aliases:
            f = self.module_func(mi, name)
            if f:
                return ("func", f)
        if name in mi.imports:
            return self._import_target(mi.imports[name])
        return ("none", None)

    def resolve_ref(self, node, fi: FuncInfo | None,
                    mi: ModuleInfo) -> FuncInfo | None:
        """FuncInfo a Name/Attribute load refers to, if resolvable."""
        if isinstance(node, ast.Name):
            kind, tgt = self.lookup(node.id, fi, mi)
            return tgt if kind == "func" else None
        if isinstance(node, ast.Attribute) and isinstance(node.value,
                                                          ast.Name):
            kind, tgt = self.lookup(node.value.id, fi, mi)
            if kind == "module":
                return self.module_func(tgt, node.attr)
        return None

    # -- external dotted name of a reference (for numpy/time checks) ---------

    def external_dotted(self, node, fi: FuncInfo | None,
                        mi: ModuleInfo) -> str | None:
        """Canonical external dotted path ('numpy.random.rand') of a
        Name/Attribute chain whose root is an imported external name."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        kind, tgt = self.lookup(node.id, fi, mi)
        if kind != "ext":
            return None
        parts.append(tgt)
        return ".".join(reversed(parts))


def _jit_statics(call: ast.Call, params_order: list[str]) -> set[str]:
    """Param names declared static via static_argnames/static_argnums."""
    out = set()
    for k in call.keywords:
        vals = []
        if isinstance(k.value, ast.Constant):
            vals = [k.value.value]
        elif isinstance(k.value, (ast.Tuple, ast.List)):
            vals = [e.value for e in k.value.elts
                    if isinstance(e, ast.Constant)]
        if k.arg == "static_argnames":
            out |= {v for v in vals if isinstance(v, str)}
        elif k.arg == "static_argnums":
            for v in vals:
                if isinstance(v, int) and 0 <= v < len(params_order):
                    out.add(params_order[v])
    return out


def _mark_traced(index: Index):
    """Mark directly-traced functions, then propagate reachability."""
    def is_jit_expr(node):
        t = _terminal(node)
        if t == "jit":
            return True
        if isinstance(node, ast.Call) and _terminal(node.func) == "partial":
            return any(_terminal(a) == "jit" for a in node.args)
        if isinstance(node, ast.Call):
            return is_jit_expr(node.func)
        return False

    for mi in index.modules:
        for fi in mi.functions:
            if isinstance(fi.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for d in fi.node.decorator_list:
                    if is_jit_expr(d):
                        fi.traced = True
                        if isinstance(d, ast.Call):
                            fi.static_params |= _jit_statics(
                                d, fi.params_order)
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            fi = _enclosing(node, mi)
            t = _terminal(node.func)
            cands = []
            if t == "jit" and node.args:
                cands = [node.args[0]]
            elif t in TRACE_HOFS:
                # builtin map() / jax.tree.map are NOT tracing contexts
                if t == "map":
                    d = _dotted(node.func)
                    if not (d and d.endswith("lax.map")):
                        continue
                cands = list(node.args) + [k.value for k in node.keywords]
            for arg in cands:
                tgt = arg._gila_func if isinstance(arg, ast.Lambda) \
                    else index.resolve_ref(arg, fi, mi)
                if tgt is not None:
                    tgt.traced = True
                    if t == "jit":
                        tgt.static_params |= _jit_statics(
                            node, tgt.params_order)

    # nested functions of a traced function run at trace time too
    def mark_down(fi):
        for c in fi.children:
            if not c.traced:
                c.traced = True
                mark_down(c)
    for mi in index.modules:
        for fi in mi.functions:
            if fi.traced:
                mark_down(fi)

    # propagate traced-reach along resolved calls and function references
    edges: dict[int, list[FuncInfo]] = {}
    for mi in index.modules:
        for fi in mi.functions:
            outs = []
            for node in _own_nodes(fi):
                if isinstance(node, (ast.Name, ast.Attribute)):
                    tgt = index.resolve_ref(node, fi, mi)
                    if tgt is not None:
                        outs.append(tgt)
            outs.extend(fi.children)
            edges[id(fi)] = outs
    work = [fi for mi in index.modules for fi in mi.functions if fi.traced]
    for fi in work:
        fi.traced_reach = True
    while work:
        fi = work.pop()
        for tgt in edges.get(id(fi), ()):
            if not tgt.traced_reach:
                tgt.traced_reach = True
                work.append(tgt)
    return edges


def _enclosing(node, mi: ModuleInfo) -> FuncInfo | None:
    """FuncInfo whose body contains the node (via parent annotations)."""
    return getattr(node, "_gila_enclosing", None)


def _annotate_enclosing(mi: ModuleInfo):
    def visit(node, fi):
        node._gila_enclosing = fi
        child_fi = getattr(node, "_gila_func", fi)
        for c in ast.iter_child_nodes(node):
            visit(c, child_fi)
    visit(mi.tree, None)


# -- taint: names derived from a traced function's parameters -----------------

def _tainted_names(fi: FuncInfo) -> set[str]:
    tainted = set(fi.params) - fi.static_params
    f = fi.parent
    while f is not None:
        if f.traced:
            tainted |= f.params - f.static_params
        f = f.parent
    changed = True
    while changed:
        changed = False
        for node in _own_nodes(fi):
            # _naked_taint (not raw name intersection): a local derived
            # only through .shape/.dtype/len is static, not traced
            if isinstance(node, ast.Assign):
                if _naked_taint(node.value, tainted):
                    for t in node.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name) \
                                    and n.id not in tainted:
                                tainted.add(n.id)
                                changed = True
            elif isinstance(node, ast.For):
                if _naked_taint(node.iter, tainted):
                    for n in ast.walk(node.target):
                        if isinstance(n, ast.Name) and n.id not in tainted:
                            tainted.add(n.id)
                            changed = True
    return tainted


def _naked_taint(node, tainted: set[str]) -> bool:
    """A tainted name used for its VALUE (not via static .shape/.dtype/len,
    and not via identity tests, which never call __bool__ on a tracer)."""
    if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
        return False
    if isinstance(node, ast.Call) and _terminal(node.func) in (
            "len", "isinstance", "hasattr", "type", "id"):
        return False
    if isinstance(node, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
        return False
    if isinstance(node, ast.Name):
        return node.id in tainted
    return any(_naked_taint(c, tainted) for c in ast.iter_child_nodes(node))


# -- the linter ---------------------------------------------------------------

class Linter:
    def __init__(self, index: Index, mesh_axes: set[str] | None):
        self.index = index
        self.mesh_axes = mesh_axes
        self.findings: list[Finding] = []
        for mi in index.modules:
            _annotate_enclosing(mi)
        self.edges = _mark_traced(index)
        self.ambient_reach = self._ambient_reach()

    def add(self, mi, node, rule, message, hint=""):
        self.findings.append(Finding(
            file=mi.rel, line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0), rule=rule,
            message=message, hint=hint))

    # ambient config: functions whose call graph reads os.environ ------------

    def _ambient_reach(self) -> dict[int, str]:
        """id(FuncInfo) -> dotted path of an os.environ reader it reaches."""
        reach: dict[int, str] = {}
        work = []
        for mi in self.index.modules:
            for fi in mi.functions:
                for node in _own_nodes(fi):
                    dotted = None
                    if isinstance(node, (ast.Attribute, ast.Name)):
                        dotted = self.index.external_dotted(node, fi, mi)
                    if dotted in ("os.environ", "os.getenv"):
                        reach[id(fi)] = f"{mi.rel}:{fi.name}"
                        work.append(fi)
                        break
        # reverse edges: who references an ambient reader?
        rev: dict[int, list[FuncInfo]] = {}
        for mi in self.index.modules:
            for fi in mi.functions:
                for tgt in self.edges.get(id(fi), ()):
                    rev.setdefault(id(tgt), []).append(fi)
        while work:
            fi = work.pop()
            for src in rev.get(id(fi), ()):
                if id(src) not in reach:
                    reach[id(src)] = reach[id(fi)]
                    work.append(src)
        return reach

    # R1 ---------------------------------------------------------------------

    def check_r1(self, mi: ModuleInfo):
        for fi in mi.functions:
            if not fi.traced_reach:
                continue
            for node in _own_nodes(fi):
                if not isinstance(node, (ast.Attribute, ast.Name)):
                    continue
                d = self.index.external_dotted(node, fi, mi)
                if d is None:
                    continue
                parts = d.split(".")
                bad = None
                if parts[0] == "numpy" and len(parts) >= 2 \
                        and parts[1] == "random":
                    bad = "np.random is host-stateful"
                elif parts[0] == "random":
                    bad = "the random module is host-stateful"
                elif parts[0] == "time" and len(parts) == 2 \
                        and parts[1] in NONDET_TIME:
                    bad = "wall-clock reads are nondeterministic"
                elif parts[0] == "datetime" and parts[-1] in NONDET_DATETIME:
                    bad = "date/time reads are nondeterministic"
                elif parts[0] in ("secrets", "uuid") and len(parts) > 1:
                    bad = f"{parts[0]} is nondeterministic"
                elif d == "os.urandom":
                    bad = "os.urandom is nondeterministic"
                if bad:
                    self.add(mi, node, "R1",
                             f"nondeterministic '{d}' reachable from a "
                             f"traced step function ({fi.name}): {bad}",
                             "route randomness through utils/prng fold-in "
                             "streams (value at i depends only on (key, i))")

    # R2 ---------------------------------------------------------------------

    def _cache_names(self, mi: ModuleInfo) -> set[str]:
        """Module-level names bound to CompileCache() instances (local
        assignment or import of such a name)."""
        out = set()
        for node in mi.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call) \
                    and _terminal(node.value.func) == "CompileCache":
                out.add(node.targets[0].id)
        return out

    def _is_cache_get(self, node: ast.Call, fi, mi) -> bool:
        f = node.func
        if not (isinstance(f, ast.Attribute) and f.attr == "get"):
            return False
        base = f.value
        if isinstance(base, ast.Name):
            if base.id in self._cache_names(mi):
                return True
            # from-import of a cache instance defined elsewhere
            entry = None
            for scope in (list(fi.scope_chain()) if fi else []):
                if base.id in scope.imports:
                    entry = scope.imports[base.id]
                    break
            entry = entry or mi.imports.get(base.id)
            if entry and entry[0] == "from":
                src = self.index.by_dotted.get(entry[1])
                if src and entry[2] in self._cache_names(src):
                    return True
            return False
        if isinstance(base, ast.Attribute) and isinstance(base.value,
                                                          ast.Name):
            kind, tgt = self.index.lookup(base.value.id, fi, mi)
            if kind == "module" and base.attr in self._cache_names(tgt):
                return True
        return False

    def _assignments(self, fi: FuncInfo) -> list[tuple[set[str], ast.AST]]:
        out = []
        for node in _own_nodes(fi):
            if isinstance(node, ast.Assign):
                tgts = set()
                for t in node.targets:
                    tgts |= _names_in(t)
                out.append((tgts, node.value))
        return out

    def _expand(self, names: set[str], assigns) -> set[str]:
        """Closure of names under local 'x = expr' definitions."""
        seen = set(names)
        changed = True
        while changed:
            changed = False
            for tgts, rhs in assigns:
                if tgts & seen:
                    new = _names_in(rhs) - seen
                    if new:
                        seen |= new
                        changed = True
        return seen

    def check_r2(self, mi: ModuleInfo):
        for node in ast.walk(mi.tree):
            if not (isinstance(node, ast.Call) and len(node.args) >= 2):
                continue
            fi = _enclosing(node, mi)
            if not self._is_cache_get(node, fi, mi):
                continue
            key_expr, builder_expr = node.args[0], node.args[1]
            if isinstance(builder_expr, ast.Lambda):
                builder = builder_expr._gila_func
            else:
                builder = self.index.resolve_ref(builder_expr, fi, mi)
            if builder is None:
                continue
            assigns = self._assignments(fi) if fi else []
            key_closure = self._expand(_names_in(key_expr), assigns)
            # the key expression plus every local definition feeding it
            key_exprs = [key_expr] + [rhs for tgts, rhs in assigns
                                      if tgts & key_closure]

            # 1) every free name of the builder must be derivable from the key
            module_level = (mi.module_names | set(mi.imports)
                            | BUILTIN_NAMES)
            for f in sorted(self._free_names(builder) - module_level):
                kind, _ = self.index.lookup(f, builder, mi)
                if kind in ("func", "module", "ext"):
                    continue                # static callables/modules
                if self._expand({f}, assigns) & key_closure:
                    continue
                self.add(mi, node, "R2",
                         f"compile-cache builder closes over '{f}' which "
                         "does not appear in the cache key",
                         "add it to the key tuple (or derive it from a "
                         "keyed value) — a stale entry would otherwise be "
                         "served when it changes")

            # 2) ambient config read at trace time must be keyed
            amb = self.ambient_reach.get(id(builder))
            if amb is not None and not self._key_covers_ambient(
                    key_exprs, fi, mi):
                self.add(mi, node, "R2",
                         "builder's trace reads ambient config "
                         f"(os.environ via {amb}) but the cache key has no "
                         "backend component",
                         "include bucketing.kernel_backend() (or the "
                         "relevant env reader) in the key tuple")

    def _free_names(self, fi: FuncInfo) -> set[str]:
        free = set()
        for n in _own_nodes(fi):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
                free.add(n.id)
        for c in fi.children:
            free |= self._free_names(c)
        return free - fi.bound

    def _key_covers_ambient(self, key_exprs, fi, mi) -> bool:
        """Does the key evaluate an ambient-reading function (directly or in
        a local definition that feeds the key)?"""
        for e in key_exprs:
            for n in ast.walk(e):
                if isinstance(n, (ast.Name, ast.Attribute)):
                    f = self.index.resolve_ref(n, fi, mi)
                    if f is not None and id(f) in self.ambient_reach:
                        return True
        return False

    # R3 / R4 ----------------------------------------------------------------

    def check_r3_r4(self, mi: ModuleInfo):
        np_like = {"asarray", "array", "ascontiguousarray"}
        for fi in mi.functions:
            if not fi.traced:
                continue
            tainted = _tainted_names(fi)
            for node in _own_nodes(fi):
                if isinstance(node, ast.Call):
                    t = _terminal(node.func)
                    if t == "item" and isinstance(node.func, ast.Attribute) \
                            and _naked_taint(node.func.value, tainted):
                        self.add(mi, node, "R3",
                                 ".item() on a traced value blocks on "
                                 "device→host sync inside the step",
                                 "keep reductions on device; sync once at "
                                 "the driver's io_boundary")
                    elif isinstance(node.func, ast.Name) \
                            and node.func.id in ("float", "int", "bool") \
                            and node.args \
                            and _naked_taint(node.args[0], tainted):
                        self.add(mi, node, "R3",
                                 f"{node.func.id}() on a traced value "
                                 "forces a host sync (or a trace error)",
                                 "use jnp ops / keep the value on device")
                    elif isinstance(node.func, ast.Attribute) \
                            and node.func.attr in np_like \
                            and node.args \
                            and _naked_taint(node.args[0], tainted):
                        d = self.index.external_dotted(node.func, fi, mi)
                        if d and d.startswith("numpy."):
                            self.add(mi, node, "R3",
                                     f"np.{node.func.attr}() on a traced "
                                     "value pulls it to host inside the "
                                     "step",
                                     "use jnp.asarray / keep staging at "
                                     "the driver's io_boundary")
                    elif isinstance(node.func, ast.Name) \
                            and node.func.id == "print":
                        self.add(mi, node, "R3",
                                 "print() inside a traced function — runs "
                                 "at trace time (or not at all), and as a "
                                 "callback it breaks the no-host-transfer "
                                 "audit",
                                 "use jax.debug.print outside cached "
                                 "steps, or log from the driver")
                    elif isinstance(node.func, ast.Name) \
                            and node.func.id == "str" and node.args \
                            and _naked_taint(node.args[0], tainted):
                        self.add(mi, node, "R4",
                                 "str() of a traced value at trace time",
                                 "derive strings from static config, not "
                                 "traced arrays")
                elif isinstance(node, (ast.If, ast.While, ast.IfExp)):
                    test = node.test
                    shape_hit = any(
                        isinstance(n, ast.Attribute) and n.attr == "shape"
                        and isinstance(n.value, ast.Name)
                        and n.value.id in tainted
                        for n in ast.walk(test))
                    if shape_hit:
                        self.add(mi, node, "R4",
                                 "Python branch on .shape of a traced arg "
                                 "forks program structure within a shape "
                                 "bucket",
                                 "derive structure from the cache key / "
                                 "static args so the padding-invariance "
                                 "audit holds")
                    elif _naked_taint(test, tainted):
                        self.add(mi, node, "R4",
                                 "Python branch on a traced value — trace "
                                 "error or silent specialization",
                                 "use jnp.where / lax.cond")
                elif isinstance(node, ast.JoinedStr):
                    if any(isinstance(v, ast.FormattedValue)
                           and _naked_taint(v.value, tainted)
                           for v in node.values):
                        self.add(mi, node, "R4",
                                 "f-string interpolates a traced value at "
                                 "trace time",
                                 "format static config only; traced values "
                                 "have no concrete repr")

    # R5 ---------------------------------------------------------------------

    def check_r5(self, mi: ModuleInfo):
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            fi = _enclosing(node, mi)
            t = _terminal(node.func)
            if t == "shard_map":
                self._check_shard_map(mi, node, fi)
            elif t in COLLECTIVES and self.mesh_axes is not None:
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(arg, ast.Constant) \
                            and isinstance(arg.value, str) \
                            and arg.value not in self.mesh_axes:
                        self.add(mi, arg, "R5",
                                 f"collective '{t}' names axis "
                                 f"'{arg.value}' not declared in "
                                 "launch/mesh.py",
                                 f"declared axes: "
                                 f"{sorted(self.mesh_axes)}")

    def _check_shard_map(self, mi, node: ast.Call, fi):
        kw = {k.arg: k.value for k in node.keywords}
        target = node.args[0] if node.args else kw.get("f")
        in_specs = kw.get("in_specs")
        if len(node.args) >= 3:
            in_specs = node.args[2]
        callee = None
        if isinstance(target, ast.Lambda):
            callee = target._gila_func
        elif target is not None:
            callee = self.index.resolve_ref(target, fi, mi)
        if callee is not None and isinstance(in_specs, ast.Tuple):
            nparams = len(callee.params)
            if len(in_specs.elts) != nparams:
                self.add(mi, node, "R5",
                         f"shard_map in_specs has {len(in_specs.elts)} "
                         f"entries but '{callee.name}' takes {nparams} "
                         "parameters",
                         "one spec per positional parameter")
        if self.mesh_axes is None:
            return
        for spec_src in (in_specs, kw.get("out_specs")):
            if spec_src is None:
                continue
            for n in ast.walk(spec_src):
                if isinstance(n, ast.Call) and _terminal(n.func) in (
                        "P", "PartitionSpec"):
                    for a in n.args:
                        vals = [a.value] if isinstance(a, ast.Constant) \
                            else [e.value for e in a.elts
                                  if isinstance(e, ast.Constant)] \
                            if isinstance(a, ast.Tuple) else []
                        for v in vals:
                            if isinstance(v, str) \
                                    and v not in self.mesh_axes:
                                self.add(mi, a, "R5",
                                         f"PartitionSpec axis '{v}' not "
                                         "declared in launch/mesh.py",
                                         f"declared axes: "
                                         f"{sorted(self.mesh_axes)}")

    # R6 ---------------------------------------------------------------------

    def check_r6(self, mi: ModuleInfo):
        in_kernels = "/kernels/" in mi.rel.replace("\\", "/")
        for fi in mi.functions:
            if not (fi.traced_reach or in_kernels):
                continue
            for node in _own_nodes(fi):
                self._r6_node(mi, fi, node)
        if in_kernels:
            for node in mi.tree.body:
                for n in ast.walk(node):
                    if getattr(n, "_gila_enclosing", None) is None:
                        self._r6_node(mi, None, n)

    def _r6_node(self, mi, fi, node):
        if isinstance(node, ast.Attribute) and node.attr in F64_ATTRS:
            d = self.index.external_dotted(node, fi, mi)
            if d and d.split(".")[0] in ("numpy", "jax"):
                self.add(mi, node, "R6",
                         f"64-bit dtype '{d.split('.')[0]}."
                         f"{node.attr}' in trace-reachable/kernel code",
                         "the layout pipeline is float32 end-to-end; f64 "
                         "either upcasts silently or errors under "
                         "jax_enable_x64=False")
        elif isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype" and node.args \
                    and isinstance(node.args[0], ast.Name) \
                    and node.args[0].id == "float":
                self.add(mi, node, "R6",
                         "astype(float) is float64",
                         "use jnp.float32 explicitly")
            for k in node.keywords:
                if k.arg == "dtype" and isinstance(k.value, ast.Name) \
                        and k.value.id == "float":
                    self.add(mi, node, "R6",
                             "dtype=float is float64",
                             "use jnp.float32 explicitly")


# -- entry point --------------------------------------------------------------

def _collect_files(paths) -> list[tuple[pathlib.Path, str | None]]:
    """(file, dotted-module-path) pairs. A directory argument is treated as
    a package root (namespace packages included: 'src/repro' without an
    __init__.py still maps to 'repro.…'), so cross-module import
    resolution works over the scanned tree."""
    out = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            for q in sorted(p.rglob("*.py")):
                if "__pycache__" in q.parts:
                    continue
                rel = q.relative_to(p)
                parts = [p.name] + list(rel.parts[:-1])
                if q.stem != "__init__":
                    parts.append(q.stem)
                out.append((q, ".".join(parts)))
        elif p.suffix == ".py":
            out.append((p, _dotted_for(p)))
    return out


def _dotted_for(path: pathlib.Path) -> str | None:
    """Dotted module path by walking up through __init__.py packages."""
    if not (path.parent / "__init__.py").exists():
        return None
    parts = [path.stem] if path.stem != "__init__" else []
    d = path.parent
    while (d / "__init__.py").exists():
        parts.insert(0, d.name)
        d = d.parent
    return ".".join(parts) if parts else None


def declared_mesh_axes(modules) -> set[str] | None:
    """Axis-name universe: every all-string tuple literal in a module whose
    path ends in launch/mesh.py (plus None, always legal in a spec)."""
    axes = set()
    found = False
    for mi in modules:
        rel = mi.rel.replace("\\", "/")
        if not rel.endswith("launch/mesh.py"):
            continue
        found = True
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.Tuple) and node.elts and all(
                    isinstance(e, ast.Constant) and isinstance(e.value, str)
                    for e in node.elts):
                axes |= {e.value for e in node.elts}
    return axes if found else None


def lint_paths(paths, *, repo_root=None, mesh_axes=None) -> list[Finding]:
    """Lint every .py under ``paths``; returns findings sorted by location.

    ``mesh_axes``: explicit axis-name universe for R5 (defaults to the
    tuples declared in any scanned launch/mesh.py; if neither is present,
    axis-name checks are skipped — arity checks still run)."""
    repo_root = pathlib.Path(repo_root) if repo_root else pathlib.Path.cwd()
    modules = []
    for f, dotted in _collect_files(paths):
        try:
            rel = str(f.resolve().relative_to(repo_root.resolve()))
        except ValueError:
            rel = str(f)
        modules.append(ModuleInfo(f, rel.replace("\\", "/"), dotted))
    for mi in modules:
        _ScopeBuilder(mi).visit(mi.tree)
    index = Index(modules)
    axes = set(mesh_axes) if mesh_axes is not None \
        else declared_mesh_axes(modules)
    linter = Linter(index, axes)
    for mi in modules:
        linter.check_r1(mi)
        linter.check_r2(mi)
        linter.check_r3_r4(mi)
        linter.check_r5(mi)
        linter.check_r6(mi)
    return sorted(linter.findings,
                  key=lambda f: (f.file, f.line, f.col, f.rule))
