"""gilalint — JAX-aware static analysis enforcing the repo's compile,
padding, and RNG invariants (DESIGN.md §10).

Two layers:

  * ``rules``       — AST lint over source trees (R1–R6), no execution;
  * ``jaxpr_audit`` — abstract-tracing audit of every registered cached
                      step family (single / distributed / many): no host
                      callbacks, no f64, donation applied to the position
                      buffer, padding-invariant cache keys + jaxprs.

Run as a CI gate::

    python -m tools.gilalint src/repro

Exit code 0 ⟺ zero findings beyond the checked-in baseline (which ships —
and must stay — empty: real findings get fixed, not suppressed) and a clean
jaxpr audit.
"""
from tools.gilalint.report import Finding, load_baseline, render_text
from tools.gilalint.rules import lint_paths

__all__ = ["Finding", "lint_paths", "load_baseline", "render_text"]
