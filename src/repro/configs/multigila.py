"""Paper-side presets: Multi-GiLA layout experiment configurations.

These mirror the paper's three benchmarks (REGULARGRAPHS quality set,
REALGRAPHS/BIGGRAPHS scalability sets, scaled to this container) plus the
production-mesh dry-run sizes (10M-edge class, as in BigGraphs)."""
from __future__ import annotations

import dataclasses

from repro.core.multilevel import LayoutConfig


@dataclasses.dataclass(frozen=True)
class LayoutExperiment:
    name: str
    generator: str          # generators.py function name
    args: tuple
    cfg: LayoutConfig = LayoutConfig()


# Quality benchmark (paper Table 1 families)
REGULAR = "regulargraphs_suite"

# Scalability stand-ins (paper Tables 2–3 families, CPU-scaled)
REAL_GRAPHS = [
    LayoutExperiment("asic_like", "scale_free", (30_000, 4, 11)),
    LayoutExperiment("amazon_like", "scale_free", (50_000, 3, 12)),
    LayoutExperiment("road_like", "road_like", (260, 200, 0.25, 13)),
]

# Production-mesh dry-run sizes (BigGraphs class: ~10M edges). The `coarse`
# entry stands for a mid-hierarchy level where exact N-body applies.
BIG_GRAPH_DRYRUN = dict(
    hugetric_like=dict(n_pad=8 << 20, m_pad=32 << 20, cap=32),   # ~8.4M vtx
    delaunay_like=dict(n_pad=4 << 20, m_pad=32 << 20, cap=32),
    coarse_level=dict(n_pad=1 << 16, m_pad=1 << 19, cap=64),     # exact mode
)
