"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256, RoPE, tied embeddings. [arXiv:2403.08295; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="gemma-2b", family="dense", n_layers=18, d_model=2048,
        n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab=256000,
        activation="geglu", norm="rmsnorm", tie_embeddings=True,
        notes="MQA (kv=1): KV projections replicated under TP; q heads (8) "
              "not divisible by model=16 → attention computed replicated "
              "(≈8%% of layer FLOPs), FFN/vocab TP-sharded."),
    smoke=ArchConfig(
        name="gemma-2b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab=512,
        activation="geglu", norm="rmsnorm", tie_embeddings=True),
)
