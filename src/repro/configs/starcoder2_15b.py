"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GELU MLP, LayerNorm, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=4, d_ff=24576, vocab=49152,
        activation="gelu", norm="layernorm",
        notes="48 q heads TP-sharded over model=16 (3/device); kv=4 "
              "replicated."),
    smoke=ArchConfig(
        name="starcoder2-15b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        activation="gelu", norm="layernorm"),
)
