"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) d_ff=1408/expert,
vocab=102400, 2 shared + 64 routed top-6 (fine-grained), layer 0 dense FFN
(width 10944). SwiGLU, RMSNorm, RoPE. [arXiv:2401.06066; hf]"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
        activation="swiglu", norm="rmsnorm",
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                      first_dense_ff=10944),
        notes="64 routed experts EP-sharded over model=16 (4/device); "
              "2 shared experts TP-sharded."),
    smoke=ArchConfig(
        name="deepseek-moe-16b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=32, vocab=512,
        activation="swiglu", norm="rmsnorm",
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=32,
                      first_dense_ff=128, capacity_factor=4.0)),
)
