"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE 40e top-8. SwiGLU, RMSNorm, RoPE.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Note: the structured spec says "MoE 40e top-8"; the prose note says "32
experts top-8". We follow the structured spec (40 experts) — see DESIGN.md.
40 experts do not divide the 16-way model axis, so expert FFNs are
TP-sharded inside each expert instead of EP-sharded (d_expert=512 → 32
cols/device)."""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
        n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155,
        activation="swiglu", norm="rmsnorm",
        moe=MoEConfig(n_experts=40, top_k=8, n_shared=0, d_expert=512),
        notes="vocab padded 49155→49168; 24 q heads not divisible by 16 → "
              "attention replicated in the baseline."),
    smoke=ArchConfig(
        name="granite-moe-3b-a800m-smoke", family="moe", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=32, vocab=512,
        activation="swiglu", norm="rmsnorm",
        moe=MoEConfig(n_experts=5, top_k=2, n_shared=0, d_expert=32,
                      capacity_factor=4.0)),
)
