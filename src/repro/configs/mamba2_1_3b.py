"""mamba2-1.3b [ssm] — 48L d_model=2048, attention-free SSD (state-space
duality), ssm_state=128, vocab=50280. Sub-quadratic: runs long_500k.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
        norm="rmsnorm", tie_embeddings=True,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1,
                      chunk=256),
        subquadratic=True,
        notes="vocab padded 50280→50288; SSD inner dim 4096 → 64 SSD heads, "
              "TP-sharded over model=16 (4/device); O(1)-state decode."),
    smoke=ArchConfig(
        name="mamba2-1.3b-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=512, norm="rmsnorm",
        tie_embeddings=True,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, n_groups=1, chunk=32),
        subquadratic=True),
)
