"""Config registry: one module per assigned architecture (+ paper presets).

``get_config(name)`` returns the exact published config; ``get_smoke_config``
returns the reduced same-family config used by CPU smoke tests.
"""
from repro.configs.base import (ArchConfig, MoEConfig, SSMConfig, ShapeCell,
                                SHAPES, cells_for, get_config,
                                get_smoke_config, list_archs)

# importing the modules populates the registry
from repro.configs import (gemma_2b, starcoder2_15b, internlm2_1_8b,
                           starcoder2_7b, seamless_m4t_medium, internvl2_76b,
                           mamba2_1_3b, deepseek_moe_16b,
                           granite_moe_3b_a800m, jamba_v0_1_52b)
from repro.configs import multigila as multigila_presets
