"""seamless-m4t-medium [audio] — enc-dec, 12L(+12L enc) d_model=1024 16H
(MHA kv=16) d_ff=4096 vocab=256206. Modality frontend is a STUB: the
encoder consumes precomputed audio-frame embeddings from input_specs().
[arXiv:2308.11596; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="seamless-m4t-medium", family="encdec", n_layers=12,
        enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
        vocab=256206, activation="gelu", norm="layernorm", modality="audio",
        notes="vocab 256206 padded to 256208 for 16-way TP; shape cells "
              "split seq_len as S/2 encoder frames + S/2 decoder tokens."),
    smoke=ArchConfig(
        name="seamless-m4t-medium-smoke", family="encdec", n_layers=2,
        enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, activation="gelu", norm="layernorm", modality="audio"),
)
