"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, GELU MLP, LayerNorm, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
        n_heads=36, n_kv_heads=4, d_ff=18432, vocab=49152,
        activation="gelu", norm="layernorm",
        notes="36 q heads not divisible by model=16 → attention replicated "
              "in the baseline (≈22%% of layer FLOPs); §Perf hillclimbs this."),
    smoke=ArchConfig(
        name="starcoder2-7b-smoke", family="dense", n_layers=2, d_model=72,
        n_heads=6, n_kv_heads=2, d_ff=144, vocab=512,
        activation="gelu", norm="layernorm"),
)
