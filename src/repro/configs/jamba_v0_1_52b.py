"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
vocab=65536, Mamba+attention 1:7 interleave, MoE 16e top-2 every other
layer. SwiGLU, RMSNorm. Sub-quadratic (mostly SSM): runs long_500k.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

# period-8 block: attention at position 4 (1:7 attn:mamba), MoE on odd layers
_PATTERN = ("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm")

CONFIG = register(
    ArchConfig(
        name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536,
        activation="swiglu", norm="rmsnorm", pattern=_PATTERN,
        moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=14336,
                      every=2),
        ssm=SSMConfig(d_state=16, expand=2, head_dim=64, n_groups=1,
                      chunk=256),
        subquadratic=True,
        notes="Stack scans 4 period-8 blocks; 16 experts EP-sharded "
              "(1/device); only 4 attention layers hold KV caches, so "
              "long_500k decode is dominated by SSM state updates."),
    smoke=ArchConfig(
        name="jamba-v0.1-52b-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=512,
        activation="swiglu", norm="rmsnorm", pattern=("ssm", "attn"),
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_expert=64, every=2,
                      capacity_factor=4.0),
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, n_groups=1, chunk=16),
        subquadratic=True),
)
