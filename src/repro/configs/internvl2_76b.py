"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 (LLM backbone only; InternViT frontend is a STUB providing
patch embeddings). SwiGLU, RMSNorm, RoPE. [arXiv:2404.16821; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="internvl2-76b", family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256,
        activation="swiglu", norm="rmsnorm", modality="vlm",
        notes="Largest assigned arch (~76B params); patch embeddings occupy "
              "the first 256 positions of each sequence."),
    smoke=ArchConfig(
        name="internvl2-76b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        activation="swiglu", norm="rmsnorm", modality="vlm"),
)
