"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig``; the per-arch files in this
package instantiate the exact published configs and a reduced smoke config
of the same family. Input-shape cells (train_4k / prefill_32k / decode_32k /
long_500k) are defined here once and paired with every arch.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # shared (always-on) experts
    d_expert: int = 0            # per-expert FFN hidden size
    capacity_factor: float = 1.25
    every: int = 1               # MoE every `every`-th layer (jamba: 2)
    first_dense_ff: int = 0      # deepseek: layer 0 dense FFN width


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256             # SSD chunk length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | encdec | ssm | moe | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads
    activation: str = "swiglu"   # swiglu | geglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid: layer pattern within one period, scanned n_layers/len(pattern)
    # times; entries: "attn" | "ssm". Empty → all "attn" (or all "ssm").
    pattern: tuple = ()
    subquadratic: bool = False   # supports long_500k decode
    modality: str = "text"       # text | audio | vlm — non-text get stub frontends
    enc_layers: int = 0          # encdec only
    notes: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 16 for TP sharding."""
        return pad_to(self.vocab, 16)

    def layer_pattern(self) -> tuple:
        if self.pattern:
            return self.pattern
        return ("ssm",) if self.family == "ssm" else ("attn",)

    @property
    def n_layer_groups(self) -> int:
        return self.n_layers // len(self.layer_pattern())

    def param_count(self) -> int:
        """Analytic parameter count (embedding + stack), used for 6·N·D."""
        D, hd = self.d_model, self.hd
        emb = self.vocab_padded * D * (1 if self.tie_embeddings else 2)
        per_attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd \
            + self.n_heads * hd * D
        gated = self.activation in ("swiglu", "geglu")
        def ffn(width): return D * width * (3 if gated else 2)
        per_ssm = 0
        if self.ssm is not None:
            di = self.ssm.expand * D
            nh = di // self.ssm.head_dim
            gn = self.ssm.n_groups * self.ssm.d_state
            per_ssm = D * (2 * di + 2 * gn + nh) + di * D + 2 * nh \
                + self.ssm.conv_width * (di + 2 * gn)
        total = emb
        pat = self.layer_pattern()
        for li in range(self.n_layers):
            kind = pat[li % len(pat)]
            total += per_attn if kind == "attn" else per_ssm
            # FFN / MoE part
            if self.moe is not None:
                if li == 0 and self.moe.first_dense_ff:
                    total += ffn(self.moe.first_dense_ff)
                elif (li % self.moe.every) == self.moe.every - 1:
                    total += self.moe.n_experts * ffn(self.moe.d_expert) \
                        + self.moe.n_shared * ffn(self.moe.d_expert) \
                        + D * self.moe.n_experts  # router
                elif self.d_ff:
                    total += ffn(self.d_ff)
            elif self.d_ff:
                total += ffn(self.d_ff)
            total += 2 * D  # norms
        if self.enc_layers:  # encoder stack + cross-attention
            total += self.enc_layers * (per_attn + ffn(self.d_ff) + 2 * D)
            total += self.n_layers * (per_attn + D)  # cross-attn in decoder
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        gated = self.activation in ("swiglu", "geglu")
        D = self.d_model
        def ffn(width): return D * width * (3 if gated else 2)
        n_moe_layers = sum(
            1 for li in range(self.n_layers)
            if (li % self.moe.every) == self.moe.every - 1
            and not (li == 0 and self.moe.first_dense_ff))
        inactive = n_moe_layers * (self.moe.n_experts - self.moe.top_k) \
            * ffn(self.moe.d_expert)
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cells_for(cfg: ArchConfig) -> list[ShapeCell]:
    """The runnable shape cells for an arch (long_500k needs sub-quadratic
    attention — skipped for pure full-attention archs, per assignment)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        out.append(SHAPES["long_500k"])
    return out


_REGISTRY: dict[str, "ArchConfig"] = {}
_SMOKE: dict[str, "ArchConfig"] = {}


def register(cfg: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ArchConfig:
    from repro import configs as _  # ensure per-arch modules imported
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ArchConfig:
    from repro import configs as _
    return _SMOKE[name]


def list_archs() -> list[str]:
    from repro import configs as _
    return sorted(_REGISTRY)
