from repro.ckpt.checkpoint import (CheckpointManager, save_checkpoint,
                                   restore_checkpoint, latest_step,
                                   save_npz, load_npz, array_digest)
