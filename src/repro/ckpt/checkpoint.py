"""Fault-tolerant sharded checkpointing with elastic restore.

Design (scaled-down but structurally faithful to a multi-host deployment):

* every checkpoint is a directory ``step_<n>/`` containing one ``.npy`` per
  pytree leaf (mesh-INDEPENDENT full-array layout — at real scale each host
  writes only the slices it owns plus an index; the manifest format below
  already carries the per-leaf shapes needed to stitch), plus a
  ``manifest.json`` with the tree structure and a content digest;
* writes are atomic: ``step_<n>.tmp`` → fsync → rename, so a killed writer
  never leaves a checkpoint that ``latest_step`` would pick up;
* ``CheckpointManager`` owns an async writer thread (training never blocks
  on I/O), keeps the newest K checkpoints, and validates digests on restore
  — corrupt/partial checkpoints are skipped (node-failure recovery path);
* restore is ELASTIC: arrays are re-`device_put` with the *current* mesh's
  shardings, so a run checkpointed on one mesh shape resumes on another.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _digest(arrays: dict[str, np.ndarray]) -> str:
    # dtype-NAME agnostic: ml_dtypes (bfloat16) round-trip .npy as raw V2,
    # so hash shape + itemsize + raw bytes only.
    h = hashlib.sha256()
    for name in sorted(arrays):
        h.update(name.encode())
        a = arrays[name]
        h.update(str(a.shape).encode())
        h.update(str(a.dtype.itemsize).encode())
        h.update(a.tobytes()[: 1 << 16])  # prefix digest: cheap + catches truncation
    return h.hexdigest()


def array_digest(arrays: dict[str, np.ndarray]) -> str:
    """Public prefix-digest over a named array dict (shared by checkpoints
    and the serving tile store's shard manifests)."""
    return _digest(arrays)


def save_npz(path: str, arrays: dict[str, np.ndarray]) -> None:
    """Atomic uncompressed npz shard write: tmp → fsync → rename, same
    torn-write guarantee as checkpoint directories."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_npz(path: str) -> dict[str, np.ndarray]:
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {p: np.asarray(l) for p, l in zip(paths, leaves)}
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    for name, arr in arrays.items():
        fn = os.path.join(tmp, name.replace("/", "__") + ".npy")
        np.save(fn, arr)
    manifest = {"step": step, "paths": paths,
                "digest": _digest(arrays)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    """Newest step with a VALID manifest (partial .tmp dirs are ignored)."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                try:
                    steps.append(int(d.split("_")[1]))
                except ValueError:
                    pass
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, tree_like,
                       shardings=None, *, validate: bool = True):
    """Restore into the structure of ``tree_like``; optionally re-shard.

    ``shardings`` — matching pytree of NamedShardings (or None) for elastic
    placement onto the current mesh.
    """
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(tree_like)
    arrays = {}
    for p in paths:
        fn = os.path.join(d, p.replace("/", "__") + ".npy")
        arrays[p] = np.load(fn)
    if validate and _digest(arrays) != manifest["digest"]:
        raise IOError(f"checkpoint {d} failed digest validation")
    new_leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    for p, ref, sh in zip(paths, leaves, shard_leaves):
        arr = arrays[p]
        if hasattr(ref, "dtype"):
            want = np.dtype(ref.dtype)
            if arr.dtype != want:
                if arr.dtype.itemsize == want.itemsize and arr.dtype.kind == "V":
                    arr = arr.view(want)   # bf16 came back as raw V2 bytes
                else:
                    arr = arr.astype(want)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class CheckpointManager:
    """Async checkpointing with retention and corrupt-skip restore."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._error = None

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save_checkpoint(self.directory, step, tree)
                self._gc()
            except Exception as e:  # surfaced on next save/close
                self._error = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def save_async(self, step: int, tree):
        if self._error:
            raise self._error
        # snapshot to host first so training can mutate device buffers
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._error:
            raise self._error

    def restore_latest(self, tree_like, shardings=None):
        """Restore newest valid checkpoint, skipping corrupt ones."""
        while True:
            step = latest_step(self.directory)
            if step is None:
                return None, None
            try:
                tree = restore_checkpoint(self.directory, step, tree_like,
                                          shardings)
                return step, tree
            except Exception:
                shutil.rmtree(os.path.join(self.directory, f"step_{step}"),
                              ignore_errors=True)

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=30)
