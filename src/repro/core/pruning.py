"""Degree-1 pruning and reinsertion (paper §3.1).

Pruning removes every degree-1 vertex in one pass; its host vertex's mass is
incremented so the coarsening sees the pruned weight. Reinsertion places each
pruned vertex in the widest angular gap around its host at half the host's
mean incident edge length — the paper's "ad-hoc technique avoiding additional
edge crossings" (a leaf placed inside the widest empty sector of its host
cannot cross the host's incident edges near the host).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import to_csr


@dataclasses.dataclass
class PruneResult:
    edges: np.ndarray       # pruned unique edge list (renumbered)
    n: int                  # vertices after pruning
    mass: np.ndarray        # float32[n] — 1 + #pruned leaves per host
    old_of_new: np.ndarray  # int64[n] — original index per kept vertex
    leaves: np.ndarray      # int64[k] — original indices of pruned leaves
    leaf_host: np.ndarray   # int64[k] — original index of each leaf's host
    n_orig: int
    ewt: np.ndarray | None = None  # float32[len(edges)] — surviving weights


def prune_degree_one(edges: np.ndarray, n: int,
                     weights: np.ndarray | None = None) -> PruneResult:
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if weights is not None:
        weights = np.asarray(weights, np.float32).reshape(-1)
    deg = np.zeros(n, dtype=np.int64)
    np.add.at(deg, edges[:, 0], 1)
    np.add.at(deg, edges[:, 1], 1)
    leaf = deg == 1
    # never prune both endpoints of an isolated K2: keep the smaller index
    both = leaf[edges[:, 0]] & leaf[edges[:, 1]]
    if both.any():
        keep = np.minimum(edges[both, 0], edges[both, 1])
        leaf[keep] = False

    e_leaf = leaf[edges[:, 0]] | leaf[edges[:, 1]]
    leaves_e = edges[e_leaf]
    l_is_0 = leaf[leaves_e[:, 0]]
    leaves = np.where(l_is_0, leaves_e[:, 0], leaves_e[:, 1])
    hosts = np.where(l_is_0, leaves_e[:, 1], leaves_e[:, 0])

    kept = ~leaf
    old_of_new = np.nonzero(kept)[0]
    new_of_old = np.full(n, -1, dtype=np.int64)
    new_of_old[old_of_new] = np.arange(old_of_new.size)
    e2 = edges[~e_leaf]
    e2 = np.stack([new_of_old[e2[:, 0]], new_of_old[e2[:, 1]]], axis=1)
    mass = np.ones(old_of_new.size, dtype=np.float32)
    np.add.at(mass, new_of_old[hosts], 1.0)
    return PruneResult(edges=e2, n=int(old_of_new.size), mass=mass,
                       old_of_new=old_of_new, leaves=leaves, leaf_host=hosts,
                       n_orig=n,
                       ewt=weights[~e_leaf] if weights is not None else None)


def reinsert(pr: PruneResult, pos_kept: np.ndarray,
             pruned_edges: np.ndarray) -> np.ndarray:
    """Return positions for ALL original vertices given the kept layout."""
    pos = np.zeros((pr.n_orig, 2), dtype=np.float32)
    pos[pr.old_of_new] = np.asarray(pos_kept)[: pr.n]
    if pr.leaves.size == 0:
        return pos

    row_ptr, col = to_csr(pruned_edges, pr.n) if pruned_edges.size else (
        np.zeros(pr.n + 1, np.int64), np.zeros(0, np.int32))
    new_of_old = np.full(pr.n_orig, -1, dtype=np.int64)
    new_of_old[pr.old_of_new] = np.arange(pr.n)

    # group leaves per host so multiple leaves fan out inside the gap
    order = np.argsort(pr.leaf_host, kind="stable")
    leaves = pr.leaves[order]
    hosts = pr.leaf_host[order]
    i = 0
    while i < len(leaves):
        j = i
        while j < len(leaves) and hosts[j] == hosts[i]:
            j += 1
        h_old = hosts[i]
        h = new_of_old[h_old]
        ph = pos[h_old]
        nb = col[row_ptr[h]:row_ptr[h + 1]] if h >= 0 else np.zeros(0, np.int64)
        if len(nb):
            vecs = np.asarray(pos_kept)[nb] - ph
            lens = np.linalg.norm(vecs, axis=1)
            radius = 0.5 * float(lens.mean()) if lens.size else 1.0
            ang = np.sort(np.arctan2(vecs[:, 1], vecs[:, 0]))
            gaps = np.diff(np.concatenate([ang, ang[:1] + 2 * np.pi]))
            gi = int(np.argmax(gaps))
            start, width = ang[gi], gaps[gi]
        else:  # isolated host (its only edges went to leaves)
            start, width, radius = 0.0, 2 * np.pi, 1.0
        cnt = j - i
        for t in range(cnt):
            a = start + width * (t + 1) / (cnt + 1)
            pos[leaves[i + t]] = ph + radius * np.array([np.cos(a), np.sin(a)])
        i = j
    return pos
