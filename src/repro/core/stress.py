"""Maxent-stress refinement engine (PAPERS.md: Meyerhenke/Nöllenburg/Schulz,
*Drawing Large Graphs by Multilevel Maxent-Stress Optimization*).

The stress model places every vertex at the weighted barycenter of the
*targets* its edges prescribe: edge e = (j → i) wants i at distance
ℓ_e = max(ewt_e, 1e-6)·L from j, so it votes for the point on the j→i ray
at that distance, with weight w_e = 1/ℓ_e². Minimizing pure stress over
only the known (edge) distances collapses non-neighbors; the maxent
regularizer counters with a repulsive entropy term whose strength α anneals
from ``ALPHA0`` by a total factor ``ALPHA_SHRINK`` over the level's
iterations. The local
(Jacobi) iteration per vertex i:

    x_i ← ( Σ_e w_e · tgt_e  +  α · r_i ) / ρ_i ,    ρ_i = Σ_e w_e

with r_i the repulsion evaluated through the SAME exact / neighbor / grid
kernels GiLA uses (``gila._repulsion_*``), passing α·C in the kernels'
repulsion-constant slot — the entropy term reuses the k-hop sampling and
the grid/neighbor kernels rather than growing kernels of its own. Vertices
with ρ_i = 0 (padding, isolated) keep their position; the displacement is
clamped by the cooling temperature exactly like GiLA's update, which keeps
the update padding-invariant and bit-stable across shape buckets.

Because the hierarchy compounds edge weights level-to-level
(``solar_merger.next_level`` sums path weights into the coarse ``ewt``),
the weighted target distances come from the hierarchy for free: a coarse
edge's ℓ_e is the accumulated fine-path length, which is exactly the
distance estimate the multilevel maxent-stress paper computes.

``StressEngine`` plugs this into the engine seam (core/engine.py): the
compile-cached builders mirror ``GilaEngine``'s flat-index batched
lowering, and the per-lane schedule vector is
(temp0, temp_decay, alpha0, alpha_decay) — ``sched_k = 4``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs.graph import PaddedGraph, edge_gather
from repro.core import gila
from repro.core import engine as engine_mod

#: entropy-term annealing: α starts at ALPHA0 and decays geometrically by a
#: TOTAL factor of ALPHA_SHRINK over the level's iteration budget. The pair
#: was picked by a mesh-suite scan (grid/tri_mesh/delaunay/torus, see
#: EXPERIMENTS.md §Stress): 0.05 keeps enough repulsion to untangle the
#: placement init without drowning the stress term; larger α₀ degrades NELD
#: toward plain FR, smaller collapses non-neighbor separation (CRE blowup).
ALPHA0 = 0.05
ALPHA_SHRINK = 0.008


def alpha_schedule(iters: int) -> tuple[float, float]:
    """(α₀, per-iteration multiplicative decay) reaching α₀·ALPHA_SHRINK at
    the level's last iteration — host-computed so the sequential and batched
    steps anneal with the identical f32 factor."""
    return ALPHA0, float(ALPHA_SHRINK ** (1.0 / max(int(iters), 1)))


def stress_terms(g: PaddedGraph, L):
    """Position-independent per-edge terms, hoisted out of the iteration
    loop: target lengths ℓ_e, weights w_e = 1/ℓ_e² (0 on padding), and the
    per-vertex weight sum ρ."""
    ell = jnp.maximum(g.ewt, 1e-6) * L
    we = jnp.where(g.emask, 1.0 / (ell * ell), 0.0)
    rho = jax.ops.segment_sum(we, g.dst, num_segments=g.n_pad + 1)[:g.n_pad]
    return ell, we, rho


def stress_iteration(g: PaddedGraph, pos, nbr_idx, nbr_mask, ell, we, rho,
                     params_arr, temp, alpha, *, mode: str, grid_dim: int = 0,
                     cell_cap: int = 0):
    """One maxent-stress Jacobi iteration (shared by ``stress_layout`` and
    the cached builders' per-lane arithmetic contract)."""
    C, L, md = params_arr[0], params_arr[1], params_arr[2]
    n_pad = g.n_pad
    ps = edge_gather(g, pos)                        # source endpoint per edge
    pd = pos[jnp.clip(g.dst, 0, n_pad - 1)]
    delta = pd - ps
    dist = jnp.sqrt(jnp.sum(delta * delta, axis=1) + md ** 2)
    tgt = ps + delta / dist[:, None] * ell[:, None]
    vec = jnp.where(g.emask[:, None], we[:, None] * tgt, 0.0)
    num = jax.ops.segment_sum(vec, g.dst, num_segments=n_pad + 1)[:n_pad]
    ca = alpha * C                                  # entropy strength α·C
    if mode == "exact":
        rep = gila._repulsion_exact(pos, g.mass, g.vmask, ca, L, md)
    elif mode == "grid":
        rep = gila._repulsion_grid(pos, g.mass, g.vmask, ca, L, md,
                                   grid_dim, cell_cap)
    else:
        rep = gila._repulsion_neighbors(pos, g.mass, nbr_idx, nbr_mask,
                                        g.vmask, ca, L, md)
    new = (num + rep) / jnp.maximum(rho, 1e-12)[:, None]
    new = jnp.where(rho[:, None] > 0, new, pos)     # no edges → stay put
    d = new - pos
    norm = jnp.sqrt(jnp.sum(d * d, axis=1) + 1e-12)
    step = jnp.minimum(norm, temp)                  # GiLA's cooling clamp
    pos = pos + d / norm[:, None] * step[:, None]
    return jnp.where(g.vmask[:, None], pos, 0.0)


@partial(jax.jit, static_argnames=("mode", "iters", "grid_dim", "cell_cap"))
def stress_layout(g: PaddedGraph, pos0, nbr_idx, nbr_mask, *, mode: str,
                  iters: int, temp0: float, temp_decay: float,
                  alpha0: float, alpha_decay: float, ideal_len: float,
                  rep_const: float, min_dist: float = 1e-3,
                  grid_dim: int = 0, cell_cap: int = 0):
    """Exact-shape maxent-stress loop — the ``gila.gila_layout`` analogue
    used when ``LayoutConfig.bucketing=False`` (every level retraces); the
    multilevel driver uses the compile-cached builders below otherwise."""
    params_arr = jnp.asarray([rep_const, ideal_len, min_dist], jnp.float32)
    ell, we, rho = stress_terms(g, params_arr[1])

    def body(i, carry):
        pos, temp, al = carry
        pos = stress_iteration(g, pos, nbr_idx, nbr_mask, ell, we, rho,
                               params_arr, temp, al, mode=mode,
                               grid_dim=grid_dim, cell_cap=cell_cap)
        return pos, temp * temp_decay, al * alpha_decay

    pos, _, _ = jax.lax.fori_loop(
        0, iters, body, (pos0, jnp.asarray(temp0, jnp.float32),
                         jnp.asarray(alpha0, jnp.float32)))
    return pos


class StressEngine(engine_mod.RefinementEngine):
    """Multilevel maxent-stress as a drop-in refinement engine."""

    name = "stress"
    sched_k = 4                 # (temp0, temp_decay, alpha0, alpha_decay)

    def lane_schedule(self, sched) -> tuple:
        a0, ad = alpha_schedule(sched.iters)
        return (sched.temp0, sched.temp_decay, a0, ad)

    def build_refine(self, mode: str, grid_dim: int, cell_cap: int):
        """Compile-cached per-level stress loop: iteration count and the
        4-scalar annealing vector are traced, ℓ/w/ρ are hoisted once per
        level, pos0 is donated."""
        from repro.core import bucketing

        def refine(pos0, src, dst, vmask, emask, mass, ewt, nbr_idx,
                   nbr_mask, iters, sparams, params):
            g = PaddedGraph(src=src, dst=dst, vmask=vmask, emask=emask,
                            mass=mass, ewt=ewt, n=0, m=0)
            ell, we, rho = stress_terms(g, params[1])

            def body(i, carry):
                pos, temp, al = carry
                pos = stress_iteration(g, pos, nbr_idx, nbr_mask, ell, we,
                                       rho, params, temp, al, mode=mode,
                                       grid_dim=grid_dim, cell_cap=cell_cap)
                return pos, temp * sparams[1], al * sparams[3]

            pos, _, _ = jax.lax.fori_loop(
                0, iters, body, (pos0, sparams[0], sparams[2]))
            return pos

        return jax.jit(
            refine,
            donate_argnums=bucketing.donate_argnums_if_supported(0))

    def build_refine_many(self, mode: str, grid_dim: int, cell_cap: int,
                          inc_k: int):
        """Batched stress over ``[B, n_pad]`` lanes, mirroring
        ``GilaEngine.build_refine_many``'s flat-index lowering: per-lane
        arithmetic is element-for-element ``stress_iteration`` (same op
        order, same accumulation order for the edge aggregations — the
        incidence-gather adds reproduce ``segment_sum``'s ascending-slot
        scatter order), so each lane is bit-identical to the same level
        refined alone. Dead/finished lanes carry (pos, temp, α) through
        the remaining trips unchanged.
        """
        from repro.core import bucketing
        from repro.kernels.nbody import ops as nbody_ops

        def refine_many(pos0, src, dst, vmask, emask, mass, ewt, nbr_idx,
                        nbr_mask, inc, iters, sparams, params, max_iters):
            B, n_pad = pos0.shape[0], pos0.shape[1]
            m_pad = src.shape[1]
            C, L, md = params[0], params[1], params[2]
            temp_decay, alpha_dec = sparams[:, 1], sparams[:, 3]
            w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)  # [B, n_pad]
            offs = (jnp.arange(B, dtype=jnp.int32) * (n_pad + 1))[:, None]
            flat_dst = (dst + offs).reshape(-1)
            flat_src = src + offs
            flat_dst_clip = jnp.clip(dst, 0, n_pad - 1) + offs
            ell = jnp.maximum(ewt, 1e-6) * L                     # [B, m_pad]
            we = jnp.where(emask, 1.0 / (ell * ell), 0.0)
            flat_inc = inc + (jnp.arange(B, dtype=jnp.int32)
                              * (m_pad + 1))[:, None, None]

            def flat_pos(pos):
                posp = jnp.concatenate(
                    [pos, jnp.zeros((B, 1, 2), pos.dtype)], axis=1)
                return posp.reshape(B * (n_pad + 1), 2)

            def agg_edges(x):
                """Per-vertex sum of a per-edge quantity ([B, m_pad, ...]),
                in the sequential step's segment_sum accumulation order."""
                if inc_k > 0:
                    xf = jnp.concatenate(
                        [x, jnp.zeros((B, 1) + x.shape[2:], x.dtype)],
                        axis=1).reshape((B * (m_pad + 1),) + x.shape[2:])
                    acc = jnp.zeros((B, n_pad) + x.shape[2:], x.dtype)
                    for k in range(inc_k):    # left-assoc: scatter order
                        acc = acc + xf[flat_inc[:, :, k]]
                    return acc
                out = jax.ops.segment_sum(
                    x.reshape((B * m_pad,) + x.shape[2:]), flat_dst,
                    num_segments=B * (n_pad + 1))
                return out.reshape((B, n_pad + 1) + x.shape[2:])[:, :n_pad]

            rho = agg_edges(we)                                  # [B, n_pad]

            def stress_num(pos):
                flat = flat_pos(pos)
                ps = flat[flat_src]                              # [B, m_pad, 2]
                pd = flat[flat_dst_clip]
                delta = pd - ps
                dist = jnp.sqrt(jnp.sum(delta * delta, axis=2) + md ** 2)
                tgt = ps + delta / dist[..., None] * ell[..., None]
                vec = jnp.where(emask[..., None], we[..., None] * tgt, 0.0)
                return agg_edges(vec)

            if mode == "exact":
                def repulsion(pos, ca):
                    return jax.vmap(nbody_ops.nbody_repulsion,
                                    in_axes=(0, 0, 0, 0, None, None))(
                        pos, mass, vmask, ca, L, md)
            elif mode == "neighbor":
                flat_nbr = nbr_idx + offs[:, :, None]            # [B, n_pad, K]

                def repulsion(pos, ca):
                    flat = flat_pos(pos)
                    wp = jnp.concatenate(
                        [w, jnp.zeros((B, 1), w.dtype)], axis=1).reshape(-1)
                    npos = flat[flat_nbr]
                    nw = jnp.where(nbr_mask, wp[flat_nbr], 0.0)
                    delta = pos[:, :, None, :] - npos
                    d2 = jnp.sum(delta * delta, axis=-1) + md ** 2
                    inv = (ca[:, None, None] * L * L) * nw / d2
                    f = jnp.sum(delta * inv[..., None], axis=2)
                    return jnp.where(vmask[..., None], f, 0.0)
            else:
                from repro.kernels.grid_force import ops as grid_ops

                def repulsion(pos, ca):
                    return jax.vmap(
                        lambda p, m_, v_, c_: grid_ops.grid_repulsion(
                            p, m_, v_, c_, L, md,
                            grid_dim=grid_dim, cell_cap=cell_cap))(
                        pos, mass, vmask, ca)

            def body(i, carry):
                pos, temp, al = carry
                num = stress_num(pos)
                rep = repulsion(pos, al * C)
                new = (num + rep) / jnp.maximum(rho, 1e-12)[..., None]
                new = jnp.where(rho[..., None] > 0, new, pos)
                d = new - pos
                norm = jnp.sqrt(jnp.sum(d * d, axis=2) + 1e-12)
                step = jnp.minimum(norm, temp[:, None])
                new = pos + d / norm[..., None] * step[..., None]
                new = jnp.where(vmask[..., None], new, 0.0)
                live = i < iters
                return (jnp.where(live[:, None, None], new, pos),
                        jnp.where(live, temp * temp_decay, temp),
                        jnp.where(live, al * alpha_dec, al))

            pos, _, _ = jax.lax.fori_loop(
                0, max_iters, body, (pos0, sparams[:, 0], sparams[:, 2]))
            return pos

        return jax.jit(
            refine_many,
            donate_argnums=bucketing.donate_argnums_if_supported(0))


engine_mod.register(StressEngine())
