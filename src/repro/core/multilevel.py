"""Multi-GiLA — the full multilevel pipeline (paper §3.1).

pruning → (partitioning) → coarsening* → coarsest layout → [placement →
single-level refinement]* → reinsertion, applied per connected component,
components packed on a shelf grid at the end.

The same driver powers four engines:
  * ``multigila``   — the paper's algorithm (distributed-semantics supersteps);
  * ``multigila_dist`` — identical algorithm, but every level's refinement
                      runs through the *actually sharded* superstep
                      (core/distributed.py:run_layout_level) on a device
                      mesh: exact / neighbor / grid repulsion per the same
                      schedule, SPMD over (data, model);
  * ``centralized`` — FM³ stand-in baseline: identical hierarchy, exact
                      all-pairs forces and full iteration budget everywhere;
  * ``flat``        — single-level GiLA baseline (the paper's predecessor [5]).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.graph import PaddedGraph, build_graph, unique_edges
from repro.core.solar_merger import run_merger, next_level, LevelInfo
from repro.core.solar_placer import solar_placer
from repro.core import gila
from repro.core.schedule import make_schedule, LevelSchedule
from repro.core.pruning import prune_degree_one, reinsert


@dataclasses.dataclass(frozen=True)
class LayoutConfig:
    coarsest_threshold: int = 50     # halt coarsening below this many vertices
    max_levels: int = 24
    min_shrink: float = 0.96         # stop if a level shrinks less than this
    p_sun: float = 0.35
    exact_threshold: int = 2048      # exact N-body below this size
    grid_threshold: int = 32768      # grid-approx repulsion above this size
    coarsest_iters: int = 300
    finest_iters: int = 50
    ideal_len: float = 1.0
    rep_const: float = 1.0
    seed: int = 0
    engine: str = "multigila"   # multigila | multigila_dist | centralized | flat
    # multigila_dist (data, model) mesh; None → one mesh over all local devices
    mesh_shape: tuple | None = None
    prune: bool = True


@dataclasses.dataclass
class LayoutStats:
    levels: int = 0
    level_sizes: tuple = ()
    merger_rounds_total: int = 0
    supersteps: int = 0


def connected_components(edges: np.ndarray, n: int) -> np.ndarray:
    """Union-find component labels (host)."""
    parent = np.arange(n, dtype=np.int64)

    def find(x):
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for u, v in np.asarray(edges, dtype=np.int64):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(i) for i in range(n)], dtype=np.int64)


def build_hierarchy(g0: PaddedGraph, cfg: LayoutConfig
                    ) -> tuple[list[PaddedGraph], list[LevelInfo]]:
    """Coarsening loop: repeated Distributed Solar Merger applications."""
    graphs, infos = [g0], []
    g = g0
    for lvl in range(cfg.max_levels):
        if g.n <= cfg.coarsest_threshold:
            break
        st = run_merger(g, p_sun=cfg.p_sun, seed=cfg.seed + 101 * lvl)
        cg, info = next_level(g, st)
        if cg.n >= g.n * cfg.min_shrink or cg.n < 1:
            break
        graphs.append(cg)
        infos.append(info)
        g = cg
    return graphs, infos


def _layout_one_level(g: PaddedGraph, pos0, sched: LevelSchedule,
                      cfg: LayoutConfig, seed: int):
    if cfg.engine == "multigila_dist":
        from repro.core.distributed import run_layout_level
        from repro.launch.mesh import make_compat_mesh, make_host_mesh
        mesh = (make_compat_mesh(tuple(cfg.mesh_shape), ("data", "model"))
                if cfg.mesh_shape else make_host_mesh())
        return run_layout_level(mesh, g, pos0, sched,
                                ideal_len=cfg.ideal_len,
                                rep_const=cfg.rep_const, seed=seed)
    if sched.mode == "neighbor":
        nbr_idx, nbr_mask = gila.build_level_neighbors(g, sched.k, sched.cap,
                                                       seed=seed)
    else:
        # exact and grid modes need no neighbor lists (grid rebins inside
        # the iteration loop)
        nbr_idx = jnp.zeros((g.n_pad, 1), jnp.int32)
        nbr_mask = jnp.zeros((g.n_pad, 1), bool)
    return gila.gila_layout(
        g, pos0, nbr_idx, nbr_mask, mode=sched.mode, iters=sched.iters,
        temp0=sched.temp0, temp_decay=sched.temp_decay,
        ideal_len=cfg.ideal_len, rep_const=cfg.rep_const,
        grid_dim=sched.grid_dim, cell_cap=sched.cell_cap)


def layout_component(edges: np.ndarray, n: int, cfg: LayoutConfig
                     ) -> tuple[np.ndarray, LayoutStats]:
    """Multi-GiLA on one connected component; returns positions [n,2]."""
    stats = LayoutStats()
    if n == 1:
        return np.zeros((1, 2), np.float32), stats
    if cfg.prune and cfg.engine != "flat":
        pr = prune_degree_one(edges, n)
    else:
        pr = None

    work_edges = pr.edges if pr is not None else edges
    work_n = pr.n if pr is not None else n
    mass = pr.mass if pr is not None else None
    if work_n == 0 or len(work_edges) == 0:
        # star graphs collapse entirely under pruning: lay out leaves only
        pos = reinsert(pr, np.zeros((max(work_n, 1), 2), np.float32), work_edges) \
            if pr is not None else np.zeros((n, 2), np.float32)
        return pos, stats
    g0 = build_graph(work_edges, work_n, mass=mass)

    if cfg.engine == "flat":
        sched = make_schedule(0, 1, g0.n, g0.m,
                              exact_threshold=cfg.exact_threshold,
                              grid_threshold=cfg.grid_threshold,
                              coarsest_iters=cfg.coarsest_iters,
                              ideal_len=cfg.ideal_len)
        pos = gila.random_init(g0, cfg.ideal_len * max(g0.n, 4) ** 0.5,
                               cfg.seed)
        pos = _layout_one_level(g0, pos, sched, cfg, cfg.seed)
        stats.levels = 1
        stats.level_sizes = ((g0.n, g0.m),)
        return np.asarray(pos)[:n], stats

    graphs, infos = build_hierarchy(g0, cfg)
    L = len(graphs)
    stats.levels = L
    stats.level_sizes = tuple((g.n, g.m) for g in graphs)

    exact_thr = (10 ** 9) if cfg.engine == "centralized" else cfg.exact_threshold

    # coarsest level: random init + layout
    gk = graphs[-1]
    sched = make_schedule(L - 1, L, gk.n, gk.m, exact_threshold=exact_thr,
                          grid_threshold=cfg.grid_threshold,
                          coarsest_iters=cfg.coarsest_iters,
                          finest_iters=cfg.finest_iters,
                          ideal_len=cfg.ideal_len)
    pos = gila.random_init(gk, cfg.ideal_len * max(gk.n, 4) ** 0.5, cfg.seed)
    pos = _layout_one_level(gk, pos, sched, cfg, cfg.seed + L)

    # walk the hierarchy back down: place, then refine
    for i in range(L - 2, -1, -1):
        gi = graphs[i]
        pos = solar_placer(gi, infos[i], pos, seed=cfg.seed + i,
                           scatter_scale=0.5 * cfg.ideal_len)
        sched = make_schedule(i, L, gi.n, gi.m, exact_threshold=exact_thr,
                              grid_threshold=cfg.grid_threshold,
                              coarsest_iters=cfg.coarsest_iters,
                              finest_iters=cfg.finest_iters,
                              ideal_len=cfg.ideal_len)
        pos = _layout_one_level(gi, pos, sched, cfg, cfg.seed + i)

    pos = np.asarray(pos, np.float32)[: g0.n]
    if pr is not None:
        pos = reinsert(pr, pos, work_edges)
    return pos[:n] if pr is None else pos, stats


def _pack_components(layouts: list[np.ndarray], pad: float = 2.0) -> np.ndarray:
    """Shelf-pack component bounding boxes into a near-square arrangement."""
    boxes = []
    for P in layouts:
        lo = P.min(axis=0) if len(P) else np.zeros(2)
        hi = P.max(axis=0) if len(P) else np.zeros(2)
        boxes.append((P - lo, hi - lo + pad))
    order = np.argsort([-(b[1][0] * b[1][1]) for b in boxes])
    total_area = sum(float(b[1][0] * b[1][1]) for b in boxes)
    shelf_w = max(total_area ** 0.5, max(float(b[1][0]) for b in boxes))
    out = [None] * len(boxes)
    x = y = shelf_h = 0.0
    for oi in order:
        P, wh = boxes[oi]
        if x + wh[0] > shelf_w and x > 0:
            y += shelf_h
            x = shelf_h = 0.0
        out[oi] = P + np.array([x, y], np.float32)
        x += float(wh[0])
        shelf_h = max(shelf_h, float(wh[1]))
    return out


def multigila_layout(edges: np.ndarray, n: int,
                     cfg: LayoutConfig | None = None
                     ) -> tuple[np.ndarray, LayoutStats]:
    """Full pipeline on a possibly-disconnected graph. Returns pos[n,2]."""
    cfg = cfg or LayoutConfig()
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    labels = connected_components(edges, n)
    comps = np.unique(labels)
    stats = LayoutStats()
    if len(comps) == 1:
        pos, stats = layout_component(edges, n, cfg)
        return pos, stats

    layouts, index_maps = [], []
    for c in comps:
        vs = np.nonzero(labels == c)[0]
        remap = np.full(n, -1, np.int64)
        remap[vs] = np.arange(vs.size)
        emask = labels[edges[:, 0]] == c
        ce = np.stack([remap[edges[emask, 0]], remap[edges[emask, 1]]], 1)
        p, s = layout_component(ce, vs.size, cfg)
        stats.levels = max(stats.levels, s.levels)
        layouts.append(np.asarray(p))
        index_maps.append(vs)
    packed = _pack_components(layouts)
    pos = np.zeros((n, 2), np.float32)
    for vs, P in zip(index_maps, packed):
        pos[vs] = P
    return pos, stats
