"""Multi-GiLA — the full multilevel pipeline (paper §3.1).

pruning → (partitioning) → coarsening* → coarsest layout → [placement →
single-level refinement]* → reinsertion, applied per connected component,
components packed on a shelf grid at the end.

The same pipeline powers four DRIVERS (``LayoutConfig.driver``):
  * ``multigila``   — the paper's algorithm (distributed-semantics supersteps);
  * ``multigila_dist`` — identical algorithm, but every level's refinement
                      runs through the *actually sharded* superstep
                      (core/distributed.py:run_layout_level) on a device
                      mesh: exact / neighbor / grid repulsion per the same
                      schedule, SPMD over (data, model);
  * ``centralized`` — FM³ stand-in baseline: identical hierarchy, exact
                      all-pairs forces and full iteration budget everywhere;
  * ``flat``        — single-level GiLA baseline (the paper's predecessor [5]).

Orthogonally, ``LayoutConfig.engine`` selects the per-level refinement
ENGINE (core/engine.py): ``"gila"`` — Fruchterman–Reingold forces — or
``"stress"`` — multilevel maxent-stress local iterations (core/stress.py).
Every driver threads the engine id through its schedules, so hierarchy,
placement, bucketing and wave grouping are engine-agnostic.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.graph import PaddedGraph, build_graph, unique_edges
from repro.core.solar_merger import run_merger, next_level, LevelInfo
from repro.core.solar_placer import solar_placer
from repro.core import gila, bucketing
from repro.core.bucketing import PHASES
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.clock import Clock, SystemClock
from repro.utils.timing import StepTimer
from repro.utils.transfer import io_boundary
from repro.core.schedule import make_schedule, LevelSchedule
from repro.core.pruning import prune_degree_one, reinsert


@dataclasses.dataclass(frozen=True)
class LayoutConfig:
    coarsest_threshold: int = 50     # halt coarsening below this many vertices
    max_levels: int = 24
    min_shrink: float = 0.96         # stop if a level shrinks less than this
    p_sun: float = 0.35
    exact_threshold: int = 2048      # exact N-body below this size
    grid_threshold: int = 32768      # grid-approx repulsion above this size
    coarsest_iters: int = 300
    finest_iters: int = 50
    ideal_len: float = 1.0
    rep_const: float = 1.0
    seed: int = 0
    driver: str = "multigila"   # multigila | multigila_dist | centralized | flat
    engine: str = "gila"        # per-level refinement engine: gila | stress
    # multigila_dist (data, model) mesh; None → one mesh over all local devices
    mesh_shape: tuple | None = None
    prune: bool = True
    # pow2 shape buckets + process-wide compile cache (core/bucketing.py);
    # False = the exact-shape legacy path (retraces per level), kept for
    # the parity test and as the pre-refactor benchmark baseline
    bucketing: bool = True

    def __post_init__(self):
        # back-compat shim: ``engine=`` used to name the DRIVER. Constructor
        # calls passing a driver name there keep working; the per-level
        # force model then stays the default. (frozen dataclass — rebind
        # via object.__setattr__; dataclasses.replace re-runs this no-op.)
        if self.engine in ("multigila", "multigila_dist", "centralized",
                           "flat"):
            object.__setattr__(self, "driver", self.engine)
            object.__setattr__(self, "engine", "gila")


@dataclasses.dataclass
class LayoutStats:
    levels: int = 0
    level_sizes: tuple = ()
    merger_rounds_total: int = 0
    supersteps: int = 0


@dataclasses.dataclass
class LevelExport:
    """One level of the hierarchy, as the serving layer consumes it.

    Level 0 is the FULL input graph (pruned leaves reinserted); levels
    1..L-1 are the solar-merger coarse graphs. ``parent[v]`` is v's vertex
    in the next coarser level (None at the coarsest); ``rep[v]`` is the
    level-0 vertex id of the system sun v collapses to, chained down the
    hierarchy — coarse vertices stay addressable in input-graph terms.
    """
    n: int
    edges: np.ndarray            # int64[m, 2] — unique undirected, level-local
    parent: np.ndarray | None    # int32[n] — index into the next coarser level
    rep: np.ndarray              # int64[n] — representative level-0 vertex id


@dataclasses.dataclass
class HierarchyExport:
    """Per-level structure of a finished layout (serve/tiles.py input).

    ``pos`` holds final positions for level 0 only; coarse-level positions
    are *derived* (mass-weighted member centroids) so every zoom band of the
    tile pyramid agrees with the drawing the user actually gets — the
    interior-level positions computed mid-refinement do not (fine refinement
    moves vertices after the coarse level is abandoned).
    """
    levels: list            # list[LevelExport], levels[0] = finest
    pos: np.ndarray         # float32[levels[0].n, 2]


def connected_components(edges: np.ndarray, n: int) -> np.ndarray:
    """Component labels, label = minimum vertex id in the component.

    Vectorized: ``scipy.sparse.csgraph`` when available (one C-level BFS
    sweep), else numpy pointer-jumping (hook each vertex to its minimum
    neighbor label, then ``label[label]`` doubling — O(m log n) array ops).
    Either path replaces the per-edge Python union-find loop whose
    interpreter time alone dominated ingest on million-edge graphs.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if n <= 0:
        return np.zeros((0,), dtype=np.int64)
    if len(edges) == 0:
        return np.arange(n, dtype=np.int64)
    try:
        from scipy.sparse import coo_matrix
        from scipy.sparse.csgraph import connected_components as _cc
    except ImportError:                  # pragma: no cover - scipy is baked in
        return _components_pointer_jumping(edges, n)
    a = coo_matrix((np.ones(len(edges), np.int8),
                    (edges[:, 0], edges[:, 1])), shape=(n, n))
    _, comp = _cc(a, directed=False)
    # csgraph labels are arbitrary ints — remap to the contract (min vertex
    # id per component) so callers can rely on stable, seed-free labels
    first = np.full(int(comp.max()) + 1, n, dtype=np.int64)
    np.minimum.at(first, comp, np.arange(n, dtype=np.int64))
    return first[comp]


def _components_pointer_jumping(edges: np.ndarray, n: int) -> np.ndarray:
    """Scipy-free fallback: min-neighbor hooking + pointer doubling."""
    label = np.arange(n, dtype=np.int64)
    u, v = edges[:, 0], edges[:, 1]
    while True:
        lu, lv = label[u], label[v]
        # hook: every endpoint's label drops to the min over its edges
        np.minimum.at(label, u, lv)
        np.minimum.at(label, v, lu)
        # shortcut: pointer doubling until labels are roots
        while True:
            nxt = label[label]
            if np.array_equal(nxt, label):
                break
            label = nxt
        if np.array_equal(label[u], label[v]):
            return label


def build_hierarchy(g0: PaddedGraph, cfg: LayoutConfig
                    ) -> tuple[list[PaddedGraph], list[LevelInfo]]:
    """Coarsening loop: repeated Distributed Solar Merger applications.

    When the shrink-ratio break fires, the final merger's coarse graph AND
    its ``LevelInfo`` are both discarded together (the placer consumes
    ``infos[i]`` to go from ``graphs[i+1]`` back to ``graphs[i]``, so a
    dangling info with no coarse graph would desynchronize the walk-down).
    The returned lists always satisfy ``len(graphs) == len(infos) + 1``.
    """
    graphs, infos = [g0], []
    g = g0
    for lvl in range(cfg.max_levels):
        if g.n <= cfg.coarsest_threshold:
            break
        st = run_merger(g, p_sun=cfg.p_sun, seed=cfg.seed + 101 * lvl)
        cg, info = next_level(g, st, bucket=cfg.bucketing)
        if cg.n >= g.n * cfg.min_shrink or cg.n < 1:
            break
        graphs.append(cg)
        infos.append(info)
        g = cg
    assert len(graphs) == len(infos) + 1, (len(graphs), len(infos))
    return graphs, infos


def _layout_one_level(g: PaddedGraph, pos0, sched: LevelSchedule,
                      cfg: LayoutConfig, seed: int):
    if cfg.driver == "multigila_dist":
        from repro.core.distributed import run_layout_level
        from repro.launch.mesh import make_compat_mesh, make_host_mesh
        mesh = (make_compat_mesh(tuple(cfg.mesh_shape), ("data", "model"))
                if cfg.mesh_shape else make_host_mesh())
        return run_layout_level(mesh, g, pos0, sched,
                                ideal_len=cfg.ideal_len,
                                rep_const=cfg.rep_const, seed=seed,
                                bucket=cfg.bucketing)
    if cfg.bucketing:
        # bucketed path: cached compiled step per shape bucket, iteration
        # count and cooling schedule traced (core/bucketing.py)
        return bucketing.refine_level(g, pos0, sched,
                                      ideal_len=cfg.ideal_len,
                                      rep_const=cfg.rep_const, seed=seed)
    # exact/grid modes need no neighbor lists (grid rebins inside the
    # iteration loop); the engine's init_state builds k-hop lists otherwise
    from repro.core.engine import get_engine
    nbr_idx, nbr_mask = get_engine(sched.engine).init_state(g, sched, seed)
    # exact-shape path: compile time is inseparable here, and the jit call
    # stages its python-scalar schedule knobs h2d at dispatch (the bucketed
    # path stages them explicitly in cached_refine instead)
    with PHASES.phase("refine"), io_boundary():
        if sched.engine == "stress":
            from repro.core import stress
            a0, ad = stress.alpha_schedule(sched.iters)
            pos = stress.stress_layout(
                g, pos0, nbr_idx, nbr_mask, mode=sched.mode,
                iters=sched.iters, temp0=sched.temp0,
                temp_decay=sched.temp_decay, alpha0=a0, alpha_decay=ad,
                ideal_len=cfg.ideal_len, rep_const=cfg.rep_const,
                grid_dim=sched.grid_dim, cell_cap=sched.cell_cap)
        else:
            pos = gila.gila_layout(
                g, pos0, nbr_idx, nbr_mask, mode=sched.mode,
                iters=sched.iters, temp0=sched.temp0,
                temp_decay=sched.temp_decay, ideal_len=cfg.ideal_len,
                rep_const=cfg.rep_const, grid_dim=sched.grid_dim,
                cell_cap=sched.cell_cap)
        pos.block_until_ready()             # keep device time in-phase
    return pos


def _single_level_export(edges: np.ndarray, n: int, pos: np.ndarray
                         ) -> HierarchyExport:
    lvl = LevelExport(n=n, edges=np.asarray(edges, np.int64).reshape(-1, 2),
                      parent=None, rep=np.arange(n, dtype=np.int64))
    return HierarchyExport(levels=[lvl], pos=np.asarray(pos, np.float32))


def _input_to_work(pr, n: int) -> np.ndarray:
    """int64[n]: input vertex → work-graph (pruned) vertex. Leaf hosts are
    always kept (a host had degree ≥ 2, or is the kept end of a K2), so one
    indirection suffices."""
    if pr is None:
        return np.arange(n, dtype=np.int64)
    m = np.full(n, -1, np.int64)
    m[pr.old_of_new] = np.arange(pr.n)
    m[pr.leaves] = m[pr.leaf_host]
    return m


def _build_export(edges, n, pr, graphs, infos, pos_full) -> HierarchyExport:
    """Assemble the per-level export of one component (see HierarchyExport)."""
    L = len(graphs)
    if L <= 1:
        return _single_level_export(edges, n, pos_full)
    w_of_in = _input_to_work(pr, n)
    work_parent = np.asarray(infos[0].parent_coarse)[: graphs[0].n]
    rep_work = (pr.old_of_new if pr is not None
                else np.arange(n, dtype=np.int64))
    levels = [LevelExport(n=n, edges=np.asarray(edges, np.int64).reshape(-1, 2),
                          parent=work_parent[w_of_in].astype(np.int32),
                          rep=np.arange(n, dtype=np.int64))]
    rep = rep_work
    for i in range(1, L):
        gi = graphs[i]
        rep = rep[np.asarray(infos[i - 1].sun_pos_index)]
        parent = (np.asarray(infos[i].parent_coarse)[: gi.n].astype(np.int32)
                  if i < L - 1 else None)
        levels.append(LevelExport(n=gi.n, edges=unique_edges(gi),
                                  parent=parent, rep=rep.astype(np.int64)))
    return HierarchyExport(levels=levels, pos=np.asarray(pos_full, np.float32))


def layout_component(edges: np.ndarray, n: int, cfg: LayoutConfig,
                     *, export: bool = False, weights=None):
    """Multi-GiLA on one connected component; returns positions [n,2] (and,
    with ``export=True``, the HierarchyExport the serving layer consumes).

    ``weights`` (float[m], optional) are per-edge weights: the attraction
    term's ideal length ℓ_e = w_e·L, and the stress engine's target
    distances. They thread prune → build_graph → hierarchy (the solar
    merger compounds them into coarse ``ewt``)."""
    stats = LayoutStats()

    def ret(pos, stats, graphs=None, infos=None, pr=None):
        if not export:
            return pos, stats
        exp = (_build_export(edges, n, pr, graphs, infos, pos)
               if graphs is not None else _single_level_export(edges, n, pos))
        return pos, stats, exp

    if n == 1:
        return ret(np.zeros((1, 2), np.float32), stats)
    if cfg.prune and cfg.driver != "flat":
        pr = prune_degree_one(edges, n, weights=weights)
    else:
        pr = None

    work_edges = pr.edges if pr is not None else edges
    work_n = pr.n if pr is not None else n
    mass = pr.mass if pr is not None else None
    work_ewt = pr.ewt if pr is not None else weights
    if work_n == 0 or len(work_edges) == 0:
        # star graphs collapse entirely under pruning: lay out leaves only
        pos = reinsert(pr, np.zeros((max(work_n, 1), 2), np.float32), work_edges) \
            if pr is not None else np.zeros((n, 2), np.float32)
        return ret(pos, stats)
    g0 = build_graph(work_edges, work_n, mass=mass, ewt=work_ewt,
                     bucket=cfg.bucketing)

    if cfg.driver == "flat":
        sched = make_schedule(0, 1, g0.n, g0.m,
                              exact_threshold=cfg.exact_threshold,
                              grid_threshold=cfg.grid_threshold,
                              coarsest_iters=cfg.coarsest_iters,
                              ideal_len=cfg.ideal_len, n_pad=g0.n_pad,
                              engine=cfg.engine)
        pos = gila.random_init(g0, cfg.ideal_len * max(g0.n, 4) ** 0.5,
                               cfg.seed)
        pos = _layout_one_level(g0, pos, sched, cfg, cfg.seed)
        stats.levels = 1
        stats.level_sizes = ((g0.n, g0.m),)
        return ret(np.asarray(pos)[:n], stats)

    with PHASES.phase("coarsen"), obs_trace.span("coarsen", cat="host",
                                                 n=g0.n, m=g0.m):
        graphs, infos = build_hierarchy(g0, cfg)
    L = len(graphs)
    stats.levels = L
    stats.level_sizes = tuple((g.n, g.m) for g in graphs)

    exact_thr = (10 ** 9) if cfg.driver == "centralized" else cfg.exact_threshold

    # coarsest level: random init + layout
    gk = graphs[-1]
    sched = make_schedule(L - 1, L, gk.n, gk.m, exact_threshold=exact_thr,
                          grid_threshold=cfg.grid_threshold,
                          coarsest_iters=cfg.coarsest_iters,
                          finest_iters=cfg.finest_iters,
                          ideal_len=cfg.ideal_len, n_pad=gk.n_pad,
                          engine=cfg.engine)
    pos = gila.random_init(gk, cfg.ideal_len * max(gk.n, 4) ** 0.5, cfg.seed)
    with obs_trace.span("refine.level", level=L - 1, n=gk.n):
        pos = _layout_one_level(gk, pos, sched, cfg, cfg.seed + L)

    # walk the hierarchy back down: place, then refine
    for i in range(L - 2, -1, -1):
        gi = graphs[i]
        with PHASES.phase("place"), obs_trace.span("place", cat="host",
                                                   level=i):
            pos = solar_placer(gi, infos[i], pos, seed=cfg.seed + i,
                               scatter_scale=0.5 * cfg.ideal_len)
            pos.block_until_ready()         # keep device time in-phase
        sched = make_schedule(i, L, gi.n, gi.m, exact_threshold=exact_thr,
                              grid_threshold=cfg.grid_threshold,
                              coarsest_iters=cfg.coarsest_iters,
                              finest_iters=cfg.finest_iters,
                              ideal_len=cfg.ideal_len, n_pad=gi.n_pad,
                              engine=cfg.engine)
        with obs_trace.span("refine.level", level=i, n=gi.n):
            pos = _layout_one_level(gi, pos, sched, cfg, cfg.seed + i)

    pos = np.asarray(pos, np.float32)[: g0.n]
    if pr is not None:
        pos = reinsert(pr, pos, work_edges)
    pos = pos[:n] if pr is None else pos
    return ret(pos, stats, graphs=graphs, infos=infos, pr=pr)


def _pack_components(layouts: list[np.ndarray], pad: float = 2.0) -> np.ndarray:
    """Shelf-pack component bounding boxes into a near-square arrangement."""
    boxes = []
    for P in layouts:
        lo = P.min(axis=0) if len(P) else np.zeros(2)
        hi = P.max(axis=0) if len(P) else np.zeros(2)
        boxes.append((P - lo, hi - lo + pad))
    order = np.argsort([-(b[1][0] * b[1][1]) for b in boxes])
    total_area = sum(float(b[1][0] * b[1][1]) for b in boxes)
    shelf_w = max(total_area ** 0.5, max(float(b[1][0]) for b in boxes))
    out = [None] * len(boxes)
    x = y = shelf_h = 0.0
    for oi in order:
        P, wh = boxes[oi]
        if x + wh[0] > shelf_w and x > 0:
            y += shelf_h
            x = shelf_h = 0.0
        out[oi] = P + np.array([x, y], np.float32)
        x += float(wh[0])
        shelf_h = max(shelf_h, float(wh[1]))
    return out


def _merge_exports(exports: list, index_maps: list, edges: np.ndarray,
                   n: int, pos: np.ndarray) -> HierarchyExport:
    """Merge per-component hierarchies into global zoom bands.

    Band 0 keeps the ORIGINAL global vertex ids (level-0 positions are the
    final packed drawing). Band b unions, from every component, its level
    ``min(b, L_c-1)`` — a component whose hierarchy is shallower than b
    keeps contributing its coarsest level with an identity parent map, so
    every band is a complete drawing of the whole graph.
    """
    n_bands = max(len(e.levels) for e in exports)
    if n_bands == 1:
        return _single_level_export(edges, n, pos)

    # per (band, component) offsets of the merged index space (band 0 is the
    # identity on global ids, so offsets start at band 1)
    offs = []
    for b in range(1, n_bands):
        sizes = [e.levels[min(b, len(e.levels) - 1)].n for e in exports]
        offs.append(np.concatenate([[0], np.cumsum(sizes[:-1])]).astype(np.int64))

    def off(b, ci):  # band-b merged index offset of component ci
        return int(offs[b - 1][ci])

    levels = []
    # band 0: global ids, parent composed per component
    parent0 = np.zeros(n, np.int32)
    for ci, (e, vs) in enumerate(zip(exports, index_maps)):
        l0 = e.levels[0]
        # a single-level component repeats identically in band 1 → identity
        p = (l0.parent if l0.parent is not None
             else np.arange(l0.n, dtype=np.int32))
        parent0[vs] = p + off(1, ci)
    levels.append(LevelExport(n=n, edges=np.asarray(edges, np.int64),
                              parent=parent0,
                              rep=np.arange(n, dtype=np.int64)))
    for b in range(1, n_bands):
        es, reps, parents = [], [], []
        nb = 0
        for ci, (e, vs) in enumerate(zip(exports, index_maps)):
            lvl = e.levels[min(b, len(e.levels) - 1)]
            es.append(lvl.edges + off(b, ci))
            reps.append(vs[lvl.rep])             # component-local → global id
            if b < n_bands - 1:
                if b + 1 < len(e.levels):
                    parents.append(lvl.parent + off(b + 1, ci))
                else:  # saturated: same level repeats in the next band
                    parents.append(np.arange(lvl.n, dtype=np.int32)
                                   + off(b + 1, ci))
            nb += lvl.n
        levels.append(LevelExport(
            n=nb,
            edges=(np.concatenate(es) if es else np.zeros((0, 2), np.int64)),
            parent=(np.concatenate(parents).astype(np.int32)
                    if b < n_bands - 1 else None),
            rep=np.concatenate(reps).astype(np.int64)))
    return HierarchyExport(levels=levels, pos=np.asarray(pos, np.float32))


class _ComponentTask:
    """Refinement state machine of one connected component, for the batched
    multi-graph driver (``multigila_layout_many``).

    Construction runs everything UP TO refinement exactly as
    ``layout_component`` does (pruning → hierarchy → schedules); the driver
    then pulls one ``RefineRequest`` per wave (coarsest level first, the
    placer invoked in between) and feeds the refined positions back.
    Per-level randomness, seeds and schedules match ``layout_component``
    line for line — with padding invariance (graphs/packing.py) that makes
    every fed-back position bit-identical to the sequential driver's.
    """

    def __init__(self, edges: np.ndarray, n: int, cfg: LayoutConfig,
                 lane: object = None, weights=None):
        self.cfg = cfg
        self.stats = LayoutStats()
        self.n = n
        self.lane = lane             # observability label: "<job_uid>.<comp>"
        self.final: np.ndarray | None = None
        self.pr = None
        if n == 1:
            self.final = np.zeros((1, 2), np.float32)
            return
        if cfg.prune:
            self.pr = prune_degree_one(edges, n, weights=weights)
        self.work_edges = self.pr.edges if self.pr is not None else edges
        work_n = self.pr.n if self.pr is not None else n
        mass = self.pr.mass if self.pr is not None else None
        work_ewt = self.pr.ewt if self.pr is not None else weights
        if work_n == 0 or len(self.work_edges) == 0:
            # star graphs collapse entirely under pruning (layout_component)
            self.final = (reinsert(self.pr,
                                   np.zeros((max(work_n, 1), 2), np.float32),
                                   self.work_edges)
                          if self.pr is not None
                          else np.zeros((n, 2), np.float32))
            return
        self.g0 = build_graph(self.work_edges, work_n, mass=mass,
                              ewt=work_ewt, bucket=True)
        with PHASES.phase("coarsen"), obs_trace.span(
                "coarsen", cat="host", lane=lane, n=self.g0.n, m=self.g0.m):
            self.graphs, self.infos = build_hierarchy(self.g0, cfg)
        L = len(self.graphs)
        self.stats.levels = L
        self.stats.level_sizes = tuple((g.n, g.m) for g in self.graphs)
        self._level = L - 1          # next level to refine (coarsest first)
        self._pos = None             # refined positions of the level above

    @property
    def done(self) -> bool:
        return self.final is not None

    def _sched(self, i: int) -> LevelSchedule:
        cfg, gi, L = self.cfg, self.graphs[i], len(self.graphs)
        return make_schedule(i, L, gi.n, gi.m,
                             exact_threshold=cfg.exact_threshold,
                             grid_threshold=cfg.grid_threshold,
                             coarsest_iters=cfg.coarsest_iters,
                             finest_iters=cfg.finest_iters,
                             ideal_len=cfg.ideal_len, n_pad=gi.n_pad,
                             engine=cfg.engine)

    def next_request(self) -> bucketing.RefineRequest:
        """Placement (when walking down) + the level's refine request,
        re-padded to its lane bucket."""
        assert not self.done
        cfg, i, L = self.cfg, self._level, len(self.graphs)
        gi = self.graphs[i]
        if i == L - 1:
            pos0 = gila.random_init(gi, cfg.ideal_len * max(gi.n, 4) ** 0.5,
                                    cfg.seed)
            seed = cfg.seed + L
        else:
            with PHASES.phase("place"), obs_trace.span(
                    "place", cat="host", level=i, lane=self.lane):
                pos0 = solar_placer(gi, self.infos[i], self._pos,
                                    seed=cfg.seed + i,
                                    scatter_scale=0.5 * cfg.ideal_len)
                pos0.block_until_ready()
            seed = cfg.seed + i
        return bucketing.make_request(gi, pos0, self._sched(i), seed,
                                      level=i, lane=self.lane)

    def feed(self, pos) -> None:
        """Accept the refined positions of the current level; finalize
        (reinsert pruned leaves) after the finest level."""
        self._pos = pos
        self._level -= 1
        if self._level >= 0:
            return
        p = np.asarray(pos, np.float32)[: self.g0.n]
        if self.pr is not None:
            self.final = reinsert(self.pr, p, self.work_edges)
        else:
            self.final = p[: self.n]


class GraphJob:
    """One submitted graph in a ``WaveScheduler``'s mutable lane set.

    Admission splits the (possibly disconnected) graph into per-component
    ``_ComponentTask`` lanes — each one the same pruning → hierarchy →
    placement state machine the sequential driver walks — and ``result()``
    reassembles them (component shelf-packing as in ``multigila_layout``)
    once every lane has finished its finest level. ``cancelled`` jobs keep
    their tasks but are skipped by the scheduler; their lanes are freed
    without touching any sibling lane's floats.
    """

    def __init__(self, edges: np.ndarray, n: int, cfg: LayoutConfig, *,
                 uid: int = -1, weights=None):
        self.cfg = cfg
        self.n = int(n)
        self.uid = int(uid)          # scheduler-local admission rank: lane
        self.cancelled = False       # labels stay deterministic across runs
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if weights is not None:
            weights = np.asarray(weights, np.float32).reshape(-1)
        labels = connected_components(edges, self.n)
        self.tasks, self.index_maps = [], []
        for k, c in enumerate(np.unique(labels)):
            vs = np.nonzero(labels == c)[0]
            remap = np.full(self.n, -1, np.int64)
            remap[vs] = np.arange(vs.size)
            emask = labels[edges[:, 0]] == c
            ce = np.stack([remap[edges[emask, 0]], remap[edges[emask, 1]]], 1)
            cw = weights[emask] if weights is not None else None
            self.tasks.append(_ComponentTask(ce, vs.size, cfg,
                                             lane=f"{self.uid}.{k}",
                                             weights=cw))
            self.index_maps.append(vs)

    @property
    def lanes(self) -> int:
        """Live (unfinished) lanes this job still occupies."""
        return 0 if self.cancelled else sum(not t.done for t in self.tasks)

    @property
    def done(self) -> bool:
        return self.cancelled or all(t.done for t in self.tasks)

    def result(self):
        """(pos[n, 2], LayoutStats) — identical to ``multigila_layout``."""
        assert self.done and not self.cancelled
        if len(self.tasks) == 1:
            return self.tasks[0].final, self.tasks[0].stats
        stats = LayoutStats()
        layouts = []
        for t in self.tasks:
            stats.levels = max(stats.levels, t.stats.levels)
            layouts.append(np.asarray(t.final))
        packed = _pack_components(layouts)
        pos = np.zeros((self.n, 2), np.float32)
        for vs, P in zip(self.index_maps, packed):
            pos[vs] = P
        return pos, stats


# wave-composition metrics (DESIGN.md §12): counted at dispatch so both
# the one-shot batched driver and the continuous engine feed them
WAVES_TOTAL = obs_metrics.REGISTRY.counter(
    "gila_waves_total", "Dispatched waves (>= 1 lane)")
LANE_DISPATCHES_TOTAL = obs_metrics.REGISTRY.counter(
    "gila_lane_dispatches_total", "Per-level lane refinements dispatched")
PREEMPTED_LANES_TOTAL = obs_metrics.REGISTRY.counter(
    "gila_preempted_lanes_total",
    "Lanes held past a wave because the wave cap was full")
STRAGGLER_WAVES_TOTAL = obs_metrics.REGISTRY.counter(
    "gila_straggler_waves_total",
    "Waves slower than the StepTimer EWMA threshold")
WAVE_GROUPS_HIST = obs_metrics.REGISTRY.histogram(
    "gila_wave_groups", "Shape-bucket groups per dispatched wave",
    buckets=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32))
GROUP_LANES_HIST = obs_metrics.REGISTRY.histogram(
    "gila_group_lanes", "Member lanes per dispatched shape-bucket group",
    buckets=(1, 2, 4, 8, 16, 32, 64, 128))


class WaveScheduler:
    """Long-lived wave scheduler with a mutable lane set (DESIGN.md §11).

    The inversion that makes continuous batching possible: instead of a
    closed-over batch driven to completion (``multigila_layout_many``'s old
    wave loop), the scheduler exposes ``admit`` / ``step`` / ``drain``.
    Jobs join (and leave, via ``remove``) at any wave boundary; each
    ``step()`` dispatches ONE wave — every selected lane's next per-level
    refinement, grouped by shape bucket and run as single cached batched
    device programs (``bucketing.refine_level_many``). A mid-flight join
    simply appears in the next wave's grouping: lane counts re-bucket to
    pow2 (floor 8, capped by ``lanes_cap``), so a warm engine compiles
    nothing for it. Lanes are arithmetically independent — wave membership
    never changes any lane's floats — so every job's result is
    bit-identical to a dedicated ``multigila_layout`` call with the same
    seed regardless of when it joined or which siblings rode along
    (tests/test_service.py).

    ``step(order=...)`` sorts jobs by the given key before picking lanes
    and ``max_lanes`` truncates the wave to the most urgent ones — the
    hook serve/engine.py uses to honor per-request priorities and
    deadlines (lanes past the cap are *preempted*: they simply do not ride
    until capacity frees). Pending ``RefineRequest``s are staged once per
    level and cached across preempted waves, so placement never reruns.
    """

    def __init__(self, cfg: LayoutConfig | None = None, *,
                 lanes_cap: int | None = None, dispatch=None,
                 tracer: "obs_trace.Tracer | None" = None,
                 clock: Clock | None = None):
        cfg = cfg or LayoutConfig()
        if cfg.driver != "multigila":
            raise ValueError("WaveScheduler supports driver='multigila' "
                             f"only, got {cfg.driver!r}")
        if not cfg.bucketing:
            raise ValueError("WaveScheduler requires cfg.bucketing=True")
        self.cfg = cfg
        self.lanes_cap = lanes_cap
        # tracer/clock seam: the engine passes ITS clock so wave spans and
        # straggler timing share the sim's virtual frame (a VirtualClock
        # never advances inside step(), so sim wave dt is exactly 0 and
        # straggler detection can never fire nondeterministically)
        self.tracer = tracer if tracer is not None else obs_trace.get_tracer()
        self.clock = clock or SystemClock()
        self._wave_timer = StepTimer()
        self._dispatch = dispatch or (lambda reqs: bucketing.refine_level_many(
            reqs, ideal_len=cfg.ideal_len, rep_const=cfg.rep_const,
            lanes_cap=lanes_cap))
        self._jobs: list[GraphJob] = []
        self._staged: dict = {}       # _ComponentTask -> RefineRequest
        self._next_uid = 0
        self.waves = 0
        self.lane_dispatches = 0
        self.straggler_waves = 0

    def admit(self, edges, n: int, *, seed: int | None = None,
              engine: str | None = None, weights=None) -> GraphJob:
        """Add one graph to the lane set (legal at any wave boundary).

        ``engine`` overrides the scheduler config's refinement engine for
        THIS job only: a wave may mix engines — grouping is by
        ``bucketing.group_key``, which leads with the engine id, so mixed
        waves dispatch one batched program per (engine, shape bucket) and
        lanes stay bit-identical to dedicated runs. ``weights`` are the
        job's per-edge weights."""
        cfg = self.cfg
        if seed is not None:
            cfg = dataclasses.replace(cfg, seed=int(seed))
        if engine is not None:
            cfg = dataclasses.replace(cfg, engine=engine)
        # lane labels derive from the scheduler-local admission rank, not
        # any global counter — two fresh runs of the same script produce
        # identical labels (trace replay determinism, tests/test_obs.py)
        job = GraphJob(edges, n, cfg, uid=self._next_uid, weights=weights)
        self._next_uid += 1
        self._jobs.append(job)
        return job

    def remove(self, job: GraphJob) -> None:
        """Cancel a job: free its lanes and drop its staged requests.
        Sibling lanes are untouched (their results stay bit-identical)."""
        job.cancelled = True
        for t in job.tasks:
            self._staged.pop(t, None)
        if job in self._jobs:
            self._jobs.remove(job)

    @property
    def active(self) -> bool:
        return any(not j.done for j in self._jobs)

    def lanes_live(self) -> int:
        return sum(j.lanes for j in self._jobs)

    def step(self, *, order=None, max_lanes: int | None = None) -> dict:
        """Dispatch one wave; returns ``{"lanes", "groups", "preempted"}``
        where ``groups`` lists ``(group_key, member_count)`` in dispatch
        order and ``preempted`` counts lanes held past this wave by the cap.

        ``order``: job sort key (ascending; stable, so admit order breaks
        ties). ``max_lanes``: only the first that-many lanes ride."""
        self._jobs = [j for j in self._jobs if not j.done]
        jobs = (sorted(self._jobs, key=order) if order is not None
                else list(self._jobs))
        pend = []
        for j in jobs:
            for t in j.tasks:
                if t.done:
                    continue
                r = self._staged.get(t)
                if r is None:
                    r = self._staged[t] = t.next_request()
                pend.append((t, r))
        preempted = 0
        if max_lanes is not None:
            preempted = max(0, len(pend) - max_lanes)
            pend = pend[:max_lanes]
        groups: dict = {}
        for t, r in pend:
            groups.setdefault(bucketing.group_key(r), []).append((t, r))
        tw0 = self.clock.now()
        ginfo = []
        for key, members in groups.items():
            tg0 = self.clock.now()
            outs = self._dispatch([r for _, r in members])
            tg1 = self.clock.now()
            for (t, r), pos in zip(members, outs):
                del self._staged[t]
                t.feed(pos)
                # per-lane share of the fused group dispatch: same bounds
                # as the group span, annotated with level/lane so phase
                # sums and host/device overlap are computable per lane
                self.tracer.complete("refine", tg0, tg1, cat="wave",
                                     level=r.level, lane=r.lane)
            self.tracer.complete("refine.group", tg0, tg1, cat="wave",
                                 bucket=key, lanes=len(members))
            GROUP_LANES_HIST.observe(len(members))
            ginfo.append((key, len(members)))
        if pend:
            tw1 = self.clock.now()
            self.waves += 1
            self.lane_dispatches += len(pend)
            WAVES_TOTAL.inc()
            LANE_DISPATCHES_TOTAL.inc(len(pend))
            WAVE_GROUPS_HIST.observe(len(ginfo))
            if preempted:
                PREEMPTED_LANES_TOTAL.inc(preempted)
            self.tracer.complete("wave", tw0, tw1, cat="wave",
                                 lanes=len(pend), groups=ginfo,
                                 preempted=preempted)
            if self._wave_timer.record(tw1 - tw0):
                self.straggler_waves += 1
                STRAGGLER_WAVES_TOTAL.inc()
                self.tracer.instant("wave.straggler", ts=tw1, cat="wave",
                                    dur=tw1 - tw0, ewma=self._wave_timer.ewma)
        return {"lanes": len(pend), "groups": ginfo, "preempted": preempted}

    def drain(self) -> None:
        """Step until every admitted job has finished."""
        while self.step()["lanes"]:
            pass


def multigila_layout_many(graphs: list, cfg: LayoutConfig | None = None,
                          *, seeds: list | None = None,
                          engines: list | None = None,
                          weights: list | None = None) -> list:
    """Batched multi-graph Multi-GiLA: lay out B graphs through grouped,
    vmapped per-level refinement steps (one device program per level wave).

    ``graphs`` is a list of ``(edges, n)`` pairs; ``seeds`` / ``engines`` /
    ``weights`` optionally override ``cfg.seed`` / ``cfg.engine`` / the
    per-edge weights per graph (mixed-engine batches group by engine in
    the bucket key). Returns ``[(pos[n, 2], LayoutStats)]``
    in input order. Coarsening and placement run per component (they are
    host-synchronized and cheap); every wave of per-level refinements is
    grouped by shape bucket (core/bucketing.py:group_key) and dispatched as
    ONE vmapped cached step, so a warm-bucket request compiles nothing and
    each per-graph result is bit-identical to ``multigila_layout`` run one
    graph at a time (tests/test_many.py, benchmarks/many_bench.py).

    This is the one-shot convenience wrapper over ``WaveScheduler``: admit
    everything, drain, collect. The continuous-batching layout service
    (serve/engine.py) drives the same scheduler with mid-flight admission.
    """
    cfg = cfg or LayoutConfig()
    for name, lst in (("seeds", seeds), ("engines", engines),
                      ("weights", weights)):
        if lst is not None and len(lst) != len(graphs):
            raise ValueError(f"{name} must match graphs in length")
    sched = WaveScheduler(cfg)     # validates driver/bucketing
    jobs = [sched.admit(edges, n,
                        seed=None if seeds is None else int(seeds[k]),
                        engine=None if engines is None else engines[k],
                        weights=None if weights is None else weights[k])
            for k, (edges, n) in enumerate(graphs)]
    sched.drain()
    return [job.result() for job in jobs]


def multigila_layout(edges: np.ndarray, n: int,
                     cfg: LayoutConfig | None = None, *,
                     export: bool = False, weights=None):
    """Full pipeline on a possibly-disconnected graph. Returns pos[n,2] (and
    the merged HierarchyExport when ``export=True`` — the serving layer's
    input, see serve/tiles.py). ``weights`` (float[m], optional) are the
    per-edge weights (see ``layout_component``)."""
    cfg = cfg or LayoutConfig()
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if weights is not None:
        weights = np.asarray(weights, np.float32).reshape(-1)
    labels = connected_components(edges, n)
    comps = np.unique(labels)
    stats = LayoutStats()
    if len(comps) == 1:
        return layout_component(edges, n, cfg, export=export,
                                weights=weights)

    layouts, index_maps, exports = [], [], []
    for c in comps:
        vs = np.nonzero(labels == c)[0]
        remap = np.full(n, -1, np.int64)
        remap[vs] = np.arange(vs.size)
        emask = labels[edges[:, 0]] == c
        ce = np.stack([remap[edges[emask, 0]], remap[edges[emask, 1]]], 1)
        cw = weights[emask] if weights is not None else None
        out = layout_component(ce, vs.size, cfg, export=export, weights=cw)
        p, s = out[0], out[1]
        if export:
            exports.append(out[2])
        stats.levels = max(stats.levels, s.levels)
        layouts.append(np.asarray(p))
        index_maps.append(vs)
    packed = _pack_components(layouts)
    pos = np.zeros((n, 2), np.float32)
    for vs, P in zip(index_maps, packed):
        pos[vs] = P
    if not export:
        return pos, stats
    return pos, stats, _merge_exports(exports, index_maps, edges, n, pos)
