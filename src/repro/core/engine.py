"""The refinement-engine seam: per-level force models as pluggable steps.

The multilevel driver (coarsen → place → refine, core/multilevel.py) fixes
the hierarchy but treats the per-level refinement as a black box — ROADMAP
item 4's claim is that a new force model is "a new step function, not a new
driver". This module is that seam. A ``RefinementEngine`` supplies:

  * ``init_state``   — per-level setup (the k-hop neighbor lists for
                       ``mode="neighbor"``, zero dummies otherwise);
  * ``build_refine`` / ``build_refine_many`` — the builders for the
    compile-cached single-graph and batched step programs that
    core/bucketing.py keys by shape bucket AND engine id;
  * ``lane_schedule`` — the per-lane traced schedule vector (length
    ``sched_k``): the scalars the step anneals each iteration. GiLA needs
    (temp0, temp_decay); maxent-stress adds (alpha0, alpha_decay). Keeping
    the vector per-engine (instead of a union of every engine's scalars)
    keeps dead lanes/args out of the traced programs;
  * ``tune``         — an engine hook over the freshly built per-level
    ``LevelSchedule`` (iteration budgets, mode thresholds).

Engines register themselves in ``ENGINES`` by name; ``get_engine`` lazily
imports ``core/stress.py`` so the GiLA-only path never pays for it.

The cached step signature every engine's builders must honor (staged by
``bucketing.cached_refine`` / ``cached_refine_many``):

    refine(pos0, src, dst, vmask, emask, mass, ewt, nbr_idx, nbr_mask,
           iters, sparams, params)                       # single graph
    refine_many(..., inc, iters, sparams, params, max_iters)   # batched

with ``sparams`` the ``lane_schedule`` vector — shape ``[sched_k]``
(single) or ``[lanes, sched_k]`` (batched, per-lane) — and
``params = [rep_const, ideal_len, min_dist]`` shared by all engines.

NOTE builders must resolve ``bucketing.donate_argnums_if_supported`` at
build time through the module object (not import it at module top): the
gilalint jaxpr audit monkeypatches it to force donation on CPU, and
``bucketing`` imports this module — a top-level back-import would cycle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graphs.graph import PaddedGraph
from repro.core import gila
from repro.utils.transfer import io_boundary


class RefinementEngine:
    """One per-level refinement force model (see module docstring)."""

    #: registry id; also the cache-key / ``LevelSchedule.engine`` value
    name: str = "?"
    #: length of the ``lane_schedule`` vector
    sched_k: int = 2

    def lane_schedule(self, sched) -> tuple:
        """The per-lane annealing scalars for one level, length ``sched_k``."""
        raise NotImplementedError

    def tune(self, sched):
        """Hook over a freshly built ``LevelSchedule``; default: unchanged."""
        return sched

    def init_state(self, g: PaddedGraph, sched, seed: int):
        """Per-level (nbr_idx, nbr_mask): the k-hop lists for neighbor mode
        (host build, shared sampling across engines so forces are comparable
        on identical lists), zero dummies for the dense modes."""
        if sched.mode == "neighbor":
            return gila.build_level_neighbors(g, sched.k, sched.cap,
                                              seed=seed)
        with io_boundary():
            return (jnp.zeros((g.n_pad, 1), jnp.int32),
                    jnp.zeros((g.n_pad, 1), bool))

    def build_refine(self, mode: str, grid_dim: int, cell_cap: int):
        raise NotImplementedError

    def build_refine_many(self, mode: str, grid_dim: int, cell_cap: int,
                          inc_k: int):
        raise NotImplementedError


class GilaEngine(RefinementEngine):
    """Fruchterman–Reingold with k-hop-restricted repulsion (paper §3.4) —
    the per-iteration math lives in ``gila.layout_iteration``; the builders
    here are the compile-cached loop wrappers around it."""

    name = "gila"
    sched_k = 2                     # (temp0, temp_decay)

    def lane_schedule(self, sched) -> tuple:
        return (sched.temp0, sched.temp_decay)

    def build_refine(self, mode: str, grid_dim: int, cell_cap: int):
        """Jitted per-level refinement with TRACED iteration count and
        cooling schedule: one compile covers every level (and every graph)
        whose arrays land in the same shape bucket. pos0 is donated."""
        from repro.core import bucketing

        def refine(pos0, src, dst, vmask, emask, mass, ewt, nbr_idx,
                   nbr_mask, iters, sparams, params):
            g = PaddedGraph(src=src, dst=dst, vmask=vmask, emask=emask,
                            mass=mass, ewt=ewt, n=0, m=0)

            def body(i, carry):
                pos, temp = carry
                pos = gila.layout_iteration(g, pos, nbr_idx, nbr_mask,
                                            params, temp, mode=mode,
                                            grid_dim=grid_dim,
                                            cell_cap=cell_cap)
                return pos, temp * sparams[1]

            pos, _ = jax.lax.fori_loop(0, iters, body, (pos0, sparams[0]))
            return pos

        return jax.jit(
            refine,
            donate_argnums=bucketing.donate_argnums_if_supported(0))

    def build_refine_many(self, mode: str, grid_dim: int, cell_cap: int,
                          inc_k: int):
        """Jitted batched refinement over ``[B, n_pad]`` lanes.

        Per-lane arithmetic is element-for-element the computation of
        ``build_refine`` (gila.layout_iteration), so every lane is
        bit-identical to the same level refined alone; the per-lane traced
        iteration budget is masked against the group's shared trip count.

        The *lowering* differs from a naive ``vmap`` in one deliberate way:
        aggregation/gather with per-lane indices lowers to batched
        scatter/gather HLO that XLA CPU executes an order of magnitude
        slower than the flat single-graph form. So the lanes are flattened
        into ONE index space — lane b's slot v lives at
        ``b * (n_pad + 1) + v``, a per-lane zero sentinel row coming along
        at slot n_pad — and the attraction aggregation runs, for
        ``inc_k > 0``, as ``inc_k`` unrolled gathered adds over the
        incidence table (``packing.incidence_table``): each vertex
        accumulates its incoming edge vectors in ascending slot order,
        which is byte-for-byte the accumulation order of the sequential
        step's ``segment_sum`` scatter — so the float sums stay
        bit-identical while costing ~15× less than a batched scatter.
        Hub-heavy lanes (``inc_k == 0``) fall back to one flat
        ``segment_sum`` over the fused index space. Dense per-lane math
        (exact/grid repulsion, cooling clamp) vmaps efficiently and stays
        vmapped — in grid mode that includes ``bin_vertices``, so spatial
        binning stays per-graph.
        """
        from repro.core import bucketing
        from repro.kernels.nbody import ops as nbody_ops

        def refine_many(pos0, src, dst, vmask, emask, mass, ewt, nbr_idx,
                        nbr_mask, inc, iters, sparams, params, max_iters):
            B, n_pad = pos0.shape[0], pos0.shape[1]
            m_pad = src.shape[1]
            C, L, md = params[0], params[1], params[2]
            temp_decay = sparams[:, 1]
            w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)  # [B, n_pad]
            offs = (jnp.arange(B, dtype=jnp.int32) * (n_pad + 1))[:, None]
            flat_dst = (dst + offs).reshape(-1)
            flat_src = src + offs
            flat_dst_clip = jnp.clip(dst, 0, n_pad - 1) + offs
            ell = jnp.maximum(ewt, 1e-6) * L                     # [B, m_pad]
            # incidence slots in the fused per-lane edge index space
            flat_inc = inc + (jnp.arange(B, dtype=jnp.int32)
                              * (m_pad + 1))[:, None, None]

            def flat_pos(pos):
                """[B, n_pad, 2] → [B*(n_pad+1), 2] with a zero sentinel
                row per lane (the dense-array 'empty inbox')."""
                posp = jnp.concatenate(
                    [pos, jnp.zeros((B, 1, 2), pos.dtype)], axis=1)
                return posp.reshape(B * (n_pad + 1), 2)

            def attraction(pos):
                flat = flat_pos(pos)
                pos_src = flat[flat_src]                         # [B, m_pad, 2]
                pos_dst = flat[flat_dst_clip]
                delta = pos_src - pos_dst
                dist = jnp.sqrt(jnp.sum(delta * delta, axis=2) + md ** 2)
                f = (dist * dist) / ell
                vec = delta / dist[..., None] * f[..., None]
                vec = jnp.where(emask[..., None], vec, 0.0)
                if inc_k > 0:
                    vflat = jnp.concatenate(
                        [vec, jnp.zeros((B, 1, 2), vec.dtype)],
                        axis=1).reshape(B * (m_pad + 1), 2)
                    acc = jnp.zeros((B, n_pad, 2), vec.dtype)
                    for k in range(inc_k):    # left-assoc: scatter order
                        acc = acc + vflat[flat_inc[:, :, k]]
                    return acc
                out = jax.ops.segment_sum(vec.reshape(-1, 2), flat_dst,
                                          num_segments=B * (n_pad + 1))
                return out.reshape(B, n_pad + 1, 2)[:, :n_pad]

            if mode == "exact":
                def repulsion(pos):
                    return jax.vmap(nbody_ops.nbody_repulsion,
                                    in_axes=(0, 0, 0, None, None, None))(
                        pos, mass, vmask, C, L, md)
            elif mode == "neighbor":
                flat_nbr = nbr_idx + offs[:, :, None]            # [B, n_pad, K]

                def repulsion(pos):
                    flat = flat_pos(pos)
                    wp = jnp.concatenate(
                        [w, jnp.zeros((B, 1), w.dtype)], axis=1).reshape(-1)
                    npos = flat[flat_nbr]                        # [B, n_pad, K, 2]
                    nw = jnp.where(nbr_mask, wp[flat_nbr], 0.0)
                    delta = pos[:, :, None, :] - npos
                    d2 = jnp.sum(delta * delta, axis=-1) + md ** 2
                    inv = (C * L * L) * nw / d2
                    f = jnp.sum(delta * inv[..., None], axis=2)
                    return jnp.where(vmask[..., None], f, 0.0)
            else:
                from repro.kernels.grid_force import ops as grid_ops

                def repulsion(pos):
                    return jax.vmap(lambda p, m_, v_: grid_ops.grid_repulsion(
                        p, m_, v_, C, L, md,
                        grid_dim=grid_dim, cell_cap=cell_cap))(
                        pos, mass, vmask)

            def body(i, carry):
                pos, temp = carry
                f = repulsion(pos) + attraction(pos)
                norm = jnp.sqrt(jnp.sum(f * f, axis=2) + 1e-12)
                step = jnp.minimum(norm, temp[:, None])
                new = pos + f / norm[..., None] * step[..., None]
                new = jnp.where(vmask[..., None], new, 0.0)
                live = i < iters
                return (jnp.where(live[:, None, None], new, pos),
                        jnp.where(live, temp * temp_decay, temp))

            pos, _ = jax.lax.fori_loop(0, max_iters, body,
                                       (pos0, sparams[:, 0]))
            return pos

        return jax.jit(
            refine_many,
            donate_argnums=bucketing.donate_argnums_if_supported(0))


# -- registry -----------------------------------------------------------------

ENGINES: dict[str, RefinementEngine] = {}


def register(eng: RefinementEngine) -> RefinementEngine:
    ENGINES[eng.name] = eng
    return eng


def get_engine(name: str) -> RefinementEngine:
    """Engine by registry id; 'stress' loads core/stress.py on first use."""
    if name not in ENGINES and name == "stress":
        import repro.core.stress  # noqa: F401  — registers itself on import
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(f"unknown refinement engine {name!r}; "
                         f"known: {sorted(ENGINES)}") from None


register(GilaEngine())
