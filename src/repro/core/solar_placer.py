"""Distributed Solar Placer — the placement phase of Multi-GiLA (paper §3.3).

Level-(i+1) positions flow back to the level-i suns through the inter-level
edges; every planet/moon that lies on an inter-system path is placed at the
barycentric point along the segment between its own sun and the neighboring
system's sun (fraction = its depth over the path length); members with no
inter-system link scatter around their sun at a radius proportional to
their depth. All steps are gather/segment supersteps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses

from repro.graphs.graph import PaddedGraph, edge_gather
from repro.core.solar_merger import LevelInfo, SUN
from repro.utils.prng import uniform_per_vertex
from repro.utils.transfer import io_boundary


@jax.jit
def _place(g: PaddedGraph, sun_of: jnp.ndarray, depth: jnp.ndarray,
           sun_pos: jnp.ndarray, key: jnp.ndarray, scatter_scale: jnp.ndarray):
    """sun_pos: float32[n_pad, 2] — position of each vertex's SUN (already
    routed from the coarse drawing). Returns positions for all vertices."""
    n_pad = g.n_pad
    # per half-edge (u → v): if systems differ, v gets a barycentric
    # suggestion between pos(sun_v) and pos(sun_u).
    sun_src = edge_gather(g, sun_of[:, None])[:, 0]
    depth_src = edge_gather(g, depth[:, None])[:, 0]
    sun_dst = jnp.where(g.dst < n_pad, sun_of[jnp.clip(g.dst, 0, n_pad - 1)], n_pad)
    depth_dst = jnp.where(g.dst < n_pad, depth[jnp.clip(g.dst, 0, n_pad - 1)], 0)
    cross = g.emask & (sun_src != sun_dst) & (sun_src < n_pad) & (sun_dst < n_pad)

    pos_sun_dst = sun_pos[jnp.clip(g.dst, 0, n_pad - 1)]
    # position of the *other* system's sun: route via the src endpoint
    pos_sun_src = edge_gather(g, sun_pos)

    plen = (depth_src + 1 + depth_dst).astype(jnp.float32)
    frac = depth_dst.astype(jnp.float32) / jnp.maximum(plen, 1.0)
    suggestion = pos_sun_dst * (1.0 - frac[:, None]) + pos_sun_src * frac[:, None]
    suggestion = jnp.where(cross[:, None], suggestion, 0.0)
    cnt = jax.ops.segment_sum(cross.astype(jnp.float32), g.dst,
                              num_segments=n_pad + 1)[:n_pad]
    acc = jax.ops.segment_sum(suggestion, g.dst, num_segments=n_pad + 1)[:n_pad]

    has_sugg = cnt > 0
    mean_sugg = acc / jnp.maximum(cnt, 1.0)[:, None]
    # members without inter-system paths scatter deterministically around
    # their sun (radius ∝ depth), as FM³ does for isolated system members.
    # angles come from per-vertex streams (utils/prng.py) so re-padding to
    # a different shape bucket scatters every real vertex identically.
    ids = jnp.arange(n_pad, dtype=jnp.int32)
    ang = uniform_per_vertex(key, ids, minval=0.0, maxval=2 * np.pi)
    offs = jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=1)
    radius = scatter_scale * jnp.maximum(depth, 1).astype(jnp.float32)
    scatter = sun_pos + offs * radius[:, None]
    pos = jnp.where(has_sugg[:, None], mean_sugg, scatter)
    return pos


def solar_placer(g: PaddedGraph, info: LevelInfo, coarse_pos: np.ndarray,
                 *, scatter_scale: float = 0.5, seed: int = 0) -> jnp.ndarray:
    """Compute initial level-i positions from the coarse drawing Γ_{i+1}."""
    n_pad = g.n_pad
    # route coarse positions to suns through the inter-level edges, then to
    # every member via its system-sun pointer.
    # jnp ops throughout: LevelInfo arrays are numpy on the host compaction
    # path but device-resident on the bucketed path, and the device arrays
    # must not round-trip through the host here
    with io_boundary():                 # staging: level info → device
        coarse_pos = jnp.asarray(coarse_pos, jnp.float32)
        pc = jnp.maximum(jnp.asarray(info.parent_coarse), 0)
        member_sun_pos = coarse_pos[pc]       # [n_pad, 2] — pos of v's sun
        sun_of = jnp.asarray(info.sun_of)
        depth = jnp.maximum(jnp.asarray(info.depth), 0)
        key = jax.random.PRNGKey(seed)
        scatter = jnp.asarray(scatter_scale, jnp.float32)
        is_sun = (jnp.asarray(info.state) == SUN) & g.vmask
    # normalize the static n/m fields so _place's jit cache keys on padded
    # shapes only (one compile per shape bucket, core/bucketing.py)
    pos = _place(dataclasses.replace(g, n=0, m=0), sun_of, depth,
                 member_sun_pos, key, scatter)
    # suns sit exactly at their coarse position
    pos = jnp.where(is_sun[:, None], member_sun_pos, pos)
    return pos
