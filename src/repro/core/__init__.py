# The paper's primary contribution: Multi-GiLA, a distributed multilevel
# force-directed layout algorithm, adapted from the Giraph/TLAV paradigm to
# TPU-native JAX (dense supersteps + shard_map distribution).
from repro.core.multilevel import (LayoutConfig, LayoutStats, multigila_layout,
                                   multigila_layout_many, layout_component,
                                   build_hierarchy, connected_components,
                                   LevelExport, HierarchyExport,
                                   GraphJob, WaveScheduler)
from repro.core.solar_merger import (run_merger, next_level, init_state,
                                     MergerState, LevelInfo,
                                     UNASSIGNED, SUN, PLANET, MOON)
from repro.core.solar_placer import solar_placer
from repro.core import gila, schedule, pruning
