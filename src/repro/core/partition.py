"""Spinner-style balanced label-propagation partitioning (paper §3.1).

Vaquero et al.'s Spinner assigns vertices to P partitions by iterated label
propagation with a balance penalty; Multi-GiLA uses it so Giraph workers
exchange few cross-partition messages. Here the partition labels drive the
*vertex reordering* that makes each mesh shard own a contiguous, mostly
internal block — the TPU analogue of worker locality (fewer remote reads in
the halo-exchange variant of the distributed supersteps).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.graph import PaddedGraph, edge_gather


def _propagate(g: PaddedGraph, labels: jnp.ndarray, loads: jnp.ndarray,
               key: jnp.ndarray, capacity: jnp.ndarray):
    """One Spinner superstep: each vertex scores every label by neighbor
    frequency minus a load penalty, and adopts the argmax with prob 1/2 —
    subject to a per-label migration quota.

    Without the quota, all coin-flip winners migrate SIMULTANEOUSLY: a
    label whose load sits just under capacity can absorb an unbounded
    number of movers in one superstep and overshoot the ``slack`` balance
    promise of ``spinner_partition`` arbitrarily. Each superstep therefore
    admits at most ``capacity - load`` movers per label (ranked by vertex
    id via a stable sort — deterministic under the seed); the rest stay
    put and may retry next round. Loads are monotone bounded: a label only
    ever grows up to capacity, so max load ≤ max(initial load, capacity)
    at every step (asserted in tests/test_distributed.py).
    """
    n_pad, P = g.n_pad, loads.shape[0]
    onehot = jax.nn.one_hot(labels, P, dtype=jnp.float32)       # [n_pad, P]
    msgs = edge_gather(g, onehot)
    msgs = jnp.where(g.emask[:, None], msgs, 0.0)
    freq = jax.ops.segment_sum(msgs, g.dst, num_segments=n_pad + 1)[:n_pad]
    deg = jnp.maximum(freq.sum(axis=1, keepdims=True), 1.0)
    penalty = (loads / capacity)[None, :]                        # load fraction
    score = freq / deg - penalty
    best = jnp.argmax(score, axis=1).astype(jnp.int32)
    flip = jax.random.bernoulli(key, 0.5, (n_pad,))
    wants = flip & g.vmask & (best != labels)
    # per-label quota: rank the movers targeting each label (stable sort on
    # the target → rank = position within the label group, i.e. vertex-id
    # order) and admit only as many as the label has headroom for
    target = jnp.where(wants, best, P)                           # P = "no move"
    order = jnp.argsort(target)                                  # stable
    ts = target[order]
    rank = jnp.arange(n_pad) - jnp.searchsorted(ts, ts, side="left")
    quota = jnp.floor(jnp.maximum(capacity - loads, 0.0))        # [P]
    ok_sorted = (ts < P) & (rank < quota[jnp.clip(ts, 0, P - 1)])
    admitted = jnp.zeros((n_pad,), bool).at[order].set(ok_sorted)
    new = jnp.where(admitted, best, labels)
    new_loads = jnp.bincount(jnp.where(g.vmask, new, P), length=P + 1)[:P]
    return new, new_loads.astype(jnp.float32)


@partial(jax.jit, static_argnames=("iters",))
def _spin(g: PaddedGraph, labels: jnp.ndarray, loads: jnp.ndarray,
          capacity: jnp.ndarray, key: jnp.ndarray, iters: int):
    """All ``iters`` supersteps rolled into one ``lax.scan`` program — the
    host dispatches once per partitioning call instead of once per
    superstep. Per-step randomness comes from pre-split keys (deterministic
    in ``seed``, though a different stream than the old per-step loop)."""
    def body(carry, k):
        labels, loads = carry
        return _propagate(g, labels, loads, k, capacity), None

    keys = jax.random.split(key, iters)
    (labels, _), _ = jax.lax.scan(body, (labels, loads), keys)
    return labels


def spinner_partition(g: PaddedGraph, n_parts: int, *, iters: int = 32,
                      slack: float = 1.10, seed: int = 0) -> np.ndarray:
    """Return int32[n_pad] partition labels (balanced within ``slack``)."""
    n_pad = g.n_pad
    # initial blocked assignment (contiguous ranges)
    base = np.minimum(np.arange(n_pad) * n_parts // max(g.n, 1), n_parts - 1)
    labels = jnp.asarray(base.astype(np.int32))
    capacity = jnp.asarray(slack * max(g.n, 1) / n_parts, jnp.float32)
    loads = jnp.bincount(jnp.where(g.vmask, labels, n_parts),
                         length=n_parts + 1)[:n_parts].astype(jnp.float32)
    return np.asarray(_spin(g, labels, loads, capacity,
                            jax.random.PRNGKey(seed), iters))


def edge_cut(g: PaddedGraph, labels: np.ndarray) -> float:
    """Fraction of (half-)edges crossing partitions."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    em = np.asarray(g.emask)
    lab = np.concatenate([np.asarray(labels), [-1]])
    cross = lab[src[em]] != lab[dst[em]]
    return float(cross.mean()) if cross.size else 0.0


def partition_order(labels: np.ndarray, vmask: np.ndarray) -> np.ndarray:
    """Permutation placing same-partition vertices contiguously (valid first)."""
    n_pad = len(labels)
    key = labels.astype(np.int64) * 2 + (~np.asarray(vmask)).astype(np.int64)
    key = np.where(np.asarray(vmask), labels, labels.max() + 1)
    return np.argsort(key, kind="stable")
