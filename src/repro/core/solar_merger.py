"""Distributed Solar Merger — the coarsening phase of Multi-GiLA (paper §3.2).

Vertex-centric BSP protocol mapped to dense JAX array supersteps:

  1. *Sun generation*: unassigned vertices self-elect with probability p;
     conflicts within graph distance < 3 are resolved by ID (two max-
     propagation supersteps — a sun survives iff it is the strict 2-hop
     maximum among candidates, which guarantees pairwise sun distance ≥ 3).
  2. *Solar-system generation*: suns broadcast offers; unassigned neighbors
     become planets of the max-ID offering sun; planets forward offers;
     unassigned 2-hop vertices become moons (recording the forwarding
     planet for two-hop routing).
  3. Steps 1–2 repeat until no vertex is unassigned (every 4th round is a
     *forced* round where all unassigned vertices self-elect; if even that
     stalls, desperation mode kicks in — see ``sun_election``).
  4. *Inter-system links*: edges whose endpoints lie in different systems
     are discovered; each contributes a path of length depth(u)+1+depth(v).
  5. *Next-level generation*: systems collapse into their suns; coarse-edge
     weight = max path length over the parallel links.

The whole election→growth→halting-vote loop is DEVICE-RESIDENT
(``run_merger``): one cached jitted program per shape bucket carries the
round counter, the stall/desperation state machine, and the BSP halting
vote ("any unassigned left?") as ``lax.while_loop`` loop-carried scalars,
so the host never syncs mid-coarsening — it reads two scalars (rounds
used, leftover count) once per merger call, where the per-round Python
driver (kept as ``run_merger_host``, the bit-parity reference) paid one
blocking device→host sync every round. ``next_level`` compaction is
likewise on-device for the bucketed driver (DESIGN.md §13): segment-summed
coarse masses, masked prefix-sum sun renumbering, and sort-based
parallel-link dedup run as fixed-shape cached programs; the host reads
only the two true sizes (n_coarse, n_edges) to pick the coarse shape
bucket.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.graph import (PaddedGraph, build_graph, bucket_pad,
                                edge_gather)
from repro.core import bucketing
from repro.core.bucketing import STEP_CACHE
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.utils.prng import uniform_per_vertex
from repro.utils.transfer import io_boundary

UNASSIGNED, SUN, PLANET, MOON = 0, 1, 2, 3

MERGER_ROUNDS = obs_metrics.REGISTRY.counter(
    "gila_merger_rounds_total",
    "BSP election+growth rounds executed inside the device merger loop")
MERGER_FORCED_SUNS = obs_metrics.REGISTRY.counter(
    "gila_merger_forced_suns_total",
    "Vertices self-elected by the terminal forced round (round-budget "
    "exhaustion — the documented graceful-degradation deviation)")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MergerState:
    """Per-vertex solar-system assignment (padding rows are UNASSIGNED but
    masked out by g.vmask everywhere)."""
    state: jnp.ndarray   # int32[n_pad] — UNASSIGNED/SUN/PLANET/MOON
    sun: jnp.ndarray     # int32[n_pad] — index of the system's sun (n_pad = none)
    depth: jnp.ndarray   # int32[n_pad] — hops to the sun (0/1/2)
    parent: jnp.ndarray  # int32[n_pad] — next hop toward the sun (for 2-hop msgs)


# device-resident template per bucket: the init state is a pure function
# of n_pad and the merger program never mutates its inputs, so the same
# buffers can serve every dispatch — EXCEPT on backends where jit donation
# is active (donate_argnums_if_supported != ()), which would consume the
# cached buffers on first use; there we stage fresh ones per call.
_INIT_TEMPLATES: dict[int, MergerState] = {}


def init_state(g: PaddedGraph) -> MergerState:
    n_pad = g.n_pad
    reusable = not bucketing.donate_argnums_if_supported(0)
    if reusable:
        st = _INIT_TEMPLATES.get(n_pad)
        if st is not None:
            return st
    with io_boundary():                 # intentional host→device staging
        packed = jnp.asarray(
            np.stack([np.zeros(n_pad, np.int32),          # state
                      np.full(n_pad, n_pad, np.int32),    # sun
                      np.full(n_pad, -1, np.int32),       # depth
                      np.full(n_pad, n_pad, np.int32)]))  # parent
        st = MergerState(state=packed[0], sun=packed[1],
                         depth=packed[2], parent=packed[3])
    if reusable:
        _INIT_TEMPLATES[n_pad] = st
    return st


def _push_max(g: PaddedGraph, values: jnp.ndarray) -> jnp.ndarray:
    """Superstep: broadcast int values, combine with max (-1 = no message)."""
    msgs = edge_gather(g, values)
    msgs = jnp.where(g.emask, msgs, -1)
    out = jax.ops.segment_max(msgs, g.dst, num_segments=g.n_pad + 1,
                              indices_are_sorted=False)
    return jnp.maximum(out[: g.n_pad], -1)


@jax.jit
def sun_election(g: PaddedGraph, st: MergerState, key: jnp.ndarray,
                 p: jnp.ndarray, forced: jnp.ndarray,
                 respect_existing: jnp.ndarray) -> MergerState:
    """One sun-generation round (supersteps 1–3 of paper §3.2 step 1).

    Existing suns participate in the conflict broadcast with dominating
    priority (ID + n_pad) so fresh candidates never elect within 2 hops of
    an established system. ``respect_existing=False`` is the *desperation*
    mode used only when the BSP vote stalls: a vertex can be ≤2 hops from a
    sun yet unreachable by offers (all intermediaries owned by other
    systems), and must then be allowed to self-elect — a documented
    deviation required for guaranteed termination.
    """
    n_pad = g.n_pad
    ids = jnp.arange(n_pad, dtype=jnp.int32)
    unassigned = (st.state == UNASSIGNED) & g.vmask
    # per-vertex coin streams (utils/prng.py): vertex v's draw depends only
    # on (key, v), not on the padding bucket — re-padding the same graph
    # elects the same suns (the bucketing parity contract)
    coin = uniform_per_vertex(key, ids) < p
    cand = unassigned & (coin | forced)

    # candidates announce their ID; two forwarding supersteps compute, per
    # vertex, the maximum candidate ID within graph distance ≤ 2.
    sun_prio = jnp.where((st.state == SUN) & respect_existing, ids + n_pad, -1)
    h0 = jnp.maximum(jnp.where(cand, ids, -1), sun_prio)
    h1 = jnp.maximum(h0, _push_max(g, h0))
    h2 = jnp.maximum(h1, _push_max(g, h1))
    # a candidate survives iff no strictly greater candidate (or established
    # sun, which always dominates) is within 2 hops. Desperation mode relaxes
    # the radius to 1 hop: stuck vertices cluster behind moons (which never
    # forward offers), and pairwise non-adjacent ones must elect in parallel
    # for O(log n) convergence (Luby-MIS on the stuck set).
    h_conflict = jnp.where(respect_existing, h2, h1)
    new_sun = cand & (h_conflict <= ids)

    state = jnp.where(new_sun, SUN, st.state)
    sun = jnp.where(new_sun, ids, st.sun)
    depth = jnp.where(new_sun, 0, st.depth)
    parent = jnp.where(new_sun, ids, st.parent)
    return MergerState(state, sun, depth, parent)


@jax.jit
def system_growth(g: PaddedGraph, st: MergerState) -> MergerState:
    """One solar-system-generation round (offers → planets → moons)."""
    n_pad = g.n_pad
    ids = jnp.arange(n_pad, dtype=jnp.int32)
    unassigned = (st.state == UNASSIGNED) & g.vmask

    # Superstep A: suns broadcast offers; unassigned neighbors accept the
    # max-ID adjacent sun and become planets.
    offer1 = _push_max(g, jnp.where(st.state == SUN, ids, -1))
    becomes_planet = unassigned & (offer1 >= 0)
    state = jnp.where(becomes_planet, PLANET, st.state)
    sun = jnp.where(becomes_planet, offer1, st.sun)
    depth = jnp.where(becomes_planet, 1, st.depth)
    parent = jnp.where(becomes_planet, offer1, st.parent)  # next hop = the sun

    # Superstep B: new planets forward their sun's offer; remaining
    # unassigned vertices accept the max forwarded sun and become moons.
    planet_fwd = jnp.where(state == PLANET, sun, -1)
    offer2 = _push_max(g, planet_fwd)
    still_un = unassigned & ~becomes_planet
    becomes_moon = still_un & (offer2 >= 0)
    # pick the forwarding planet: max planet ID among in-neighbors whose sun
    # matches the accepted offer (two-hop confirmation route, paper §3.2).
    match_val = jnp.where(state == PLANET, ids, -1)
    msgs = edge_gather(g, jnp.stack([planet_fwd, match_val], axis=1))
    key_match = jnp.where(
        g.emask & (msgs[:, 0] >= 0) & (msgs[:, 0] == offer2[jnp.clip(g.dst, 0, n_pad - 1)])
        & (g.dst < n_pad),
        msgs[:, 1], -1)
    via = jax.ops.segment_max(key_match, g.dst, num_segments=n_pad + 1)[:n_pad]
    via = jnp.maximum(via, -1)

    state = jnp.where(becomes_moon, MOON, state)
    sun = jnp.where(becomes_moon, offer2, sun)
    depth = jnp.where(becomes_moon, 2, depth)
    parent = jnp.where(becomes_moon, via, parent)
    return MergerState(state, sun, depth, parent)


def round_budget(n: int, base: int = 96) -> int:
    """Merger round budget scaled with graph size.

    Election conflicts resolve in O(log n) rounds w.h.p. (Luby-MIS
    argument), so the budget grows logarithmically past the base that
    historically covered every CI-sized graph. Exhausting it no longer
    raises — the terminal forced round self-elects every leftover vertex
    (see ``run_merger``) — so the budget only bounds worst-case work.
    """
    n = max(int(n), 2)
    extra = max(0, int(np.ceil(np.log2(n / 4096))) * 8) if n > 4096 else 0
    return base + extra


def _terminal_forced(st: MergerState, vmask: jnp.ndarray,
                     ids: jnp.ndarray) -> MergerState:
    """Graceful degradation: any vertex still unassigned after the round
    budget becomes its own sun (a documented deviation, like desperation
    mode). Identity when the merger converged."""
    left = (st.state == UNASSIGNED) & vmask
    return MergerState(
        state=jnp.where(left, SUN, st.state),
        sun=jnp.where(left, ids, st.sun),
        depth=jnp.where(left, 0, st.depth),
        parent=jnp.where(left, ids, st.parent))


# Largest bucket where the single-primitive cummax lowering of the
# segmented max stays int32-exact: values sit in [-1, 2*n_pad], the
# per-segment offset is seg_id * (2*n_pad + 2), and the top segment must
# stay below 2^31 — ~2*n_pad^2, safe through n_pad = 2^14.
_CUMMAX_NPAD_MAX = 1 << 14


def _seg_max_scan(seg_start, seg_id, vals, n_pad: int):
    """Max within runs of a dst-sorted half-edge stream (−1 = neutral).

    Exact replacement for ``segment_max`` on XLA CPU, where scatter lowers
    to a sequential per-element loop (~45 ns/element) and dominates the
    merger round. Two lowerings, chosen at trace time by the static bucket:
    small buckets bias each value by ``seg_id * span`` so one ``cummax``
    does the segmentation (values ≥ −1 and span > max−min keep earlier
    segments strictly below later ones); big buckets run the classic
    segmented-scan operator on (flag, value) pairs, which has no overflow
    bound. Both are bit-exact vs the scatter (integers, max — no rounding).
    """
    if n_pad <= _CUMMAX_NPAD_MAX:
        span = jnp.asarray(2 * n_pad + 2, jnp.int32)
        adj = (vals + 1) + seg_id * span
        return jax.lax.cummax(adj) - seg_id * span - 1

    def op(a, b):
        af, av = a
        bf, bv = b
        return af | bf, jnp.where(bf, bv, jnp.maximum(av, bv))

    return jax.lax.associative_scan(op, (seg_start, vals))[1]


def _build_merger():
    """The device-resident merger loop: election → growth → on-device
    halting vote as one ``lax.while_loop``, the stall → desperation state
    machine carried as loop scalars. Replicates ``run_merger_host``'s
    control flow (and key stream: one ``jax.random.split`` per round)
    bit-for-bit — tests/test_merger_device.py holds that line.

    The supersteps here are the scan formulation of ``sun_election`` /
    ``system_growth``: messages ride the loop-invariant dst-sorted layout
    (``_merger_sort_args``) and each per-vertex max is a segmented scan +
    gather instead of a scatter ``segment_max`` — identical outputs (max
    over the same message multiset), several times faster per round on the
    CPU backend. The host-driver jits keep the scatter path, so the parity
    suite cross-checks the two formulations every run.
    """

    def merger(st, key, src, dst, emask, order, vmask, p, max_rounds,
               force_every):
        n_pad = vmask.shape[0]
        ids = jnp.arange(n_pad, dtype=jnp.int32)
        # loop-invariant dst-sorted layout, derived in-trace from the
        # host-computed permutation (XLA hoists it out of the while body):
        # O(m) gathers + one cumsum + a binary-search bound per vertex —
        # everything except the argsort itself, which stays on the host
        # where it is ~10x cheaper than an XLA CPU sort
        dst_s = dst[order]
        src_s = src[order]
        emask_s = emask[order]
        seg_start = jnp.concatenate(
            [jnp.ones((1,), bool), dst_s[1:] != dst_s[:-1]])
        seg_id = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
        left = jnp.searchsorted(dst_s, ids, side="left")
        right = jnp.searchsorted(dst_s, ids, side="right")
        seg_has = right > left
        seg_last = jnp.maximum(right - 1, 0).astype(jnp.int32)
        src_c = jnp.clip(src_s, 0, n_pad - 1)   # padding slots masked below
        dst_c = jnp.clip(dst_s, 0, n_pad - 1)

        def push(values, msg_mask=None):
            mask = emask_s if msg_mask is None else (emask_s & msg_mask)
            msgs = jnp.where(mask, values[src_c], -1)
            run = _seg_max_scan(seg_start, seg_id, msgs, n_pad)
            return jnp.where(seg_has, run[seg_last], -1)

        def election(s, sub, forced, respect):
            unassigned = (s.state == UNASSIGNED) & vmask
            coin = uniform_per_vertex(sub, ids) < p
            cand = unassigned & (coin | forced)
            sun_prio = jnp.where((s.state == SUN) & respect, ids + n_pad, -1)
            h0 = jnp.maximum(jnp.where(cand, ids, -1), sun_prio)
            h1 = jnp.maximum(h0, push(h0))
            h2 = jnp.maximum(h1, push(h1))
            h_conflict = jnp.where(respect, h2, h1)
            new_sun = cand & (h_conflict <= ids)
            return MergerState(
                state=jnp.where(new_sun, SUN, s.state),
                sun=jnp.where(new_sun, ids, s.sun),
                depth=jnp.where(new_sun, 0, s.depth),
                parent=jnp.where(new_sun, ids, s.parent))

        def growth(s):
            unassigned = (s.state == UNASSIGNED) & vmask
            offer1 = push(jnp.where(s.state == SUN, ids, -1))
            becomes_planet = unassigned & (offer1 >= 0)
            state = jnp.where(becomes_planet, PLANET, s.state)
            sun = jnp.where(becomes_planet, offer1, s.sun)
            depth = jnp.where(becomes_planet, 1, s.depth)
            parent = jnp.where(becomes_planet, offer1, s.parent)

            planet_fwd = jnp.where(state == PLANET, sun, -1)
            offer2 = push(planet_fwd)
            still_un = unassigned & ~becomes_planet
            becomes_moon = still_un & (offer2 >= 0)
            fwd_msg = planet_fwd[src_c]
            via = push(jnp.where(state == PLANET, ids, -1),
                       msg_mask=(fwd_msg >= 0) & (fwd_msg == offer2[dst_c])
                       & (dst_s < n_pad))

            return MergerState(
                state=jnp.where(becomes_moon, MOON, state),
                sun=jnp.where(becomes_moon, offer2, sun),
                depth=jnp.where(becomes_moon, 2, depth),
                parent=jnp.where(becomes_moon, via, parent))

        def remaining_of(s):
            return jnp.sum(((s.state == UNASSIGNED) & vmask)
                           .astype(jnp.int32))

        n0 = remaining_of(st)

        def cond(carry):
            _, _, r, _, _, _, remaining = carry
            return (remaining > 0) & (r < max_rounds)

        def body(carry):
            s, k, r, prev, stalls, desperate, _ = carry
            # sticky desperation: once the vote stalls twice, run
            # Luby-MIS-style rounds until convergence
            desperate = desperate | (stalls >= 2)
            k, sub = jax.random.split(k)
            forced = desperate | (r % force_every == force_every - 1)
            s = election(s, sub, forced, ~desperate)
            s = growth(s)
            rem = remaining_of(s)
            stalls = jnp.where(rem < prev, 0, stalls + 1)
            return (s, k, r + 1, rem, stalls, desperate, rem)

        init = (st, key, jnp.asarray(0, jnp.int32), n0 + 1,
                jnp.asarray(0, jnp.int32), jnp.asarray(False), n0)
        st, _, rounds, _, _, _, remaining = jax.lax.while_loop(
            cond, body, init)
        # applied unconditionally (identity when converged): no extra
        # sync, no retrace, and the round-budget path can never raise
        st = _terminal_forced(st, vmask, ids)
        return st, rounds, remaining

    return jax.jit(merger, donate_argnums=bucketing.donate_argnums_if_supported(0))


def _merger_sort_args(g: PaddedGraph):
    """The dst-sort permutation for the scan supersteps, computed on the
    host once per merger dispatch (one ``np.argsort``, ~1 ms at the 32k
    bucket vs the ~8 ms/round the scan formulation saves on device; an XLA
    CPU sort would cost ~10x more). Everything derived from it — run
    boundaries, last-slot indices — is rebuilt in-trace inside the merger
    program, loop-invariant. Sort order within a destination is irrelevant
    (every consumer is a max), so stable-vs-quicksort changes can't
    perturb results.
    """
    with io_boundary():                 # egress: graph topology (host sort)
        dst = np.asarray(g.dst)
    order = np.argsort(dst).astype(np.int32)   # unstable is fine: see above
    with io_boundary():                 # staging: permutation → device
        return jnp.asarray(order)


def cached_merger(g: PaddedGraph, st: MergerState, key: jnp.ndarray, *,
                  p_sun: float, max_rounds: int, force_every: int):
    """(cache_key, fn, fresh, args) for the device merger loop of one shape
    bucket — the single staging point, shared by ``run_merger`` and the
    gilalint jaxpr audit (A1–A4) so the audit traces exactly the program
    the driver runs."""
    cache_key = ("merger", g.n_pad, g.m_pad)
    fn, fresh = STEP_CACHE.get(cache_key, _build_merger)
    order = _merger_sort_args(g)
    with io_boundary():                 # staging: scalar knobs → device
        args = (st, key, g.src, g.dst, g.emask, order, g.vmask,
                jnp.asarray(p_sun, jnp.float32),
                jnp.asarray(max_rounds, jnp.int32),
                jnp.asarray(force_every, jnp.int32))
    return cache_key, fn, fresh, args


def run_merger(g: PaddedGraph, *, p_sun: float = 0.35, seed: int = 0,
               max_rounds: int | None = None,
               force_every: int = 4) -> MergerState:
    """Run election+growth rounds until every valid vertex is assigned.

    Device-resident: the whole round loop (including the BSP halting vote
    and the stall/desperation state machine) runs as one cached jitted
    ``lax.while_loop`` program per shape bucket; the host reads two
    scalars after the loop (rounds used, leftover count) instead of
    syncing every round. ``max_rounds=None`` scales the budget with graph
    size (``round_budget``); exhausting it degrades gracefully — the
    terminal forced round assigns every remaining vertex as its own sun —
    and never raises mid-pipeline.
    """
    if max_rounds is None:
        max_rounds = round_budget(g.n)
    st = init_state(g)
    with io_boundary():                 # staging: RNG seed → device key
        key = jax.random.PRNGKey(seed)
    cache_key, fn, fresh, args = cached_merger(
        g, st, key, p_sun=p_sun, max_rounds=max_rounds,
        force_every=force_every)
    # the span brackets the dispatch + the scalar reads that were already
    # the driver's only host syncs — no new transfer is introduced
    with obs_trace.span("merger.dispatch", cat="device", key=cache_key,
                        fresh=fresh):
        st, rounds, left = fn(*args)
        with io_boundary():             # egress: the two halting scalars
            rounds_i, left_i = int(rounds), int(left)
    MERGER_ROUNDS.inc(rounds_i)
    if left_i:
        MERGER_FORCED_SUNS.inc(left_i)
    return st


def run_merger_host(g: PaddedGraph, *, p_sun: float = 0.35, seed: int = 0,
                    max_rounds: int | None = None,
                    force_every: int = 4) -> MergerState:
    """Per-round host driver of the same protocol — one blocking
    device→host halting vote per round, as a Giraph aggregator would.

    Kept as the bit-parity reference for the device loop (identical key
    stream, identical stall → desperation transitions, identical terminal
    forced round — tests/test_merger_device.py) and as the measurable
    "host-bound path" baseline. Same graceful round-budget semantics as
    ``run_merger``: never raises.
    """
    if max_rounds is None:
        max_rounds = round_budget(g.n)
    st = init_state(g)
    # the jitted supersteps never read the static n/m fields, so normalize
    # them away: the jit caches key on padded shapes only, and every graph
    # in the same shape bucket reuses one compiled program (bucketing.py)
    gn = dataclasses.replace(g, n=0, m=0)
    with io_boundary():                 # staging: RNG seed → device key
        key = jax.random.PRNGKey(seed)
    prev_remaining = g.n + 1
    stalls = 0
    desperate = False
    for r in range(max_rounds):
        # sticky desperation: once the vote stalls twice, run Luby-MIS-style
        # rounds (all unassigned candidates, existing suns not respected)
        # until convergence — O(log n) rounds with strict progress.
        desperate = desperate or stalls >= 2
        with io_boundary():             # staging: per-round scalar knobs
            key, sub = jax.random.split(key)
            forced = jnp.asarray(desperate
                                 or r % force_every == force_every - 1)
            p = jnp.asarray(p_sun, jnp.float32)
            respect = jnp.asarray(not desperate)
        st = sun_election(gn, st, sub, p, forced, respect)
        st = system_growth(gn, st)
        # BSP halting vote (host sync, as a Giraph aggregator would)
        with io_boundary():
            remaining = int(jnp.sum((st.state == UNASSIGNED) & g.vmask))
        if remaining == 0:
            return st
        stalls = 0 if remaining < prev_remaining else stalls + 1
        prev_remaining = remaining
    # round budget exhausted: terminal forced round (same as the device
    # loop's — every leftover vertex becomes its own sun), never raise
    ids = jnp.arange(g.n_pad, dtype=jnp.int32)
    return _terminal_forced(st, g.vmask, ids)


def centralized_solar_merger(edges: np.ndarray, n: int, seed: int = 0
                             ) -> tuple[np.ndarray, int]:
    """Sequential Solar Merger reference (FM³'s greedy, Hachul 2005):
    visit vertices in random order; an unassigned vertex becomes a sun and
    absorbs its unassigned ≤2-hop neighborhood (planets then moons).
    Returns (sun_of[n], n_suns) — used for the Fig.5 level-count baseline.
    """
    from repro.graphs.graph import to_csr
    rng = np.random.default_rng(seed)
    row_ptr, col = to_csr(edges, n)
    sun_of = np.full(n, -1, dtype=np.int64)
    n_suns = 0
    for v in rng.permutation(n):
        if sun_of[v] >= 0:
            continue
        sun_of[v] = v
        n_suns += 1
        planets = [u for u in col[row_ptr[v]:row_ptr[v + 1]]
                   if sun_of[u] < 0]
        for u in planets:
            sun_of[u] = v
        for u in planets:
            for w in col[row_ptr[u]:row_ptr[u + 1]]:
                if sun_of[w] < 0:
                    sun_of[w] = v
    return sun_of, n_suns


def centralized_levels(edges: np.ndarray, n: int, *, threshold: int = 50,
                       max_levels: int = 24, seed: int = 0) -> list[int]:
    """Level sizes produced by iterating the centralized Solar Merger.

    Each level derives its own seed (``seed + 101 * lvl``, mirroring
    ``build_hierarchy``): reusing one seed across levels correlated the
    coarsening decisions of the Fig.5 baseline — a vertex surviving as a
    sun tended to stay early in every level's visiting permutation.
    """
    sizes = [n]
    cur_edges, cur_n = edges, n
    for lvl in range(max_levels):
        if cur_n <= threshold or len(cur_edges) == 0:
            break
        sun_of, n_suns = centralized_solar_merger(cur_edges, cur_n,
                                                  seed + 101 * lvl)
        if n_suns >= cur_n:
            break
        new_idx = np.full(cur_n, -1, dtype=np.int64)
        suns = np.unique(sun_of)
        new_idx[suns] = np.arange(len(suns))
        ce = new_idx[sun_of[cur_edges]]
        ce = ce[ce[:, 0] != ce[:, 1]]
        ce = np.unique(np.sort(ce, axis=1), axis=0) if len(ce) else ce
        cur_edges, cur_n = ce, len(suns)
        sizes.append(cur_n)
    return sizes


@dataclasses.dataclass
class LevelInfo:
    """Record connecting level i to level i+1 (for the placer).

    Arrays are numpy on the host compaction path (``bucket=False``) and
    device-resident on the bucketed path — consumers stage with
    ``jnp.asarray`` (solar_placer) or egress with ``np.asarray``
    (multilevel._build_export) and work with either.
    """
    parent_coarse: np.ndarray  # int32[n_pad_i] — coarse index of v's sun
    sun_of: np.ndarray         # int32[n_pad_i] — sun vertex of v (level-i idx)
    depth: np.ndarray          # int32[n_pad_i]
    state: np.ndarray          # int32[n_pad_i]
    sun_pos_index: np.ndarray  # int32[n_coarse] — level-i vertex of each coarse vertex


def next_level(g: PaddedGraph, st: MergerState, *, pad_mult: int = 256,
               bucket: bool = False) -> tuple[PaddedGraph, LevelInfo]:
    """Collapse solar systems into suns → coarse graph.

    Coarse vertices = suns (mass = Σ member masses); coarse edges = unique
    inter-system links, weighted by the longest member path
    (depth_u + 1 + depth_v) over all parallel links, times the max endpoint
    edge weight (so weights compound across levels as in FM³).

    ``bucket=True`` (the production multilevel driver) compacts ON DEVICE
    through two cached fixed-shape programs and pads the coarse graph to
    pow2 shape buckets; the host reads only the true sizes. ``bucket=False``
    keeps the original host-numpy compaction — the parity reference
    (tests/test_merger_device.py) and the exact-shape legacy path.
    """
    if bucket:
        return _next_level_device(g, st, pad_mult)
    return next_level_host(g, st, pad_mult=pad_mult, bucket=False)


def next_level_host(g: PaddedGraph, st: MergerState, *, pad_mult: int = 256,
                    bucket: bool = False) -> tuple[PaddedGraph, LevelInfo]:
    """Host-numpy compaction (the pre-device reference implementation)."""
    n_pad = g.n_pad
    state = np.asarray(st.state)
    sun = np.asarray(st.sun)
    depth = np.asarray(st.depth)
    vmask = np.asarray(g.vmask)
    mass = np.asarray(g.mass)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    emask = np.asarray(g.emask)
    ewt = np.asarray(g.ewt)

    is_sun = (state == SUN) & vmask
    n_coarse = int(is_sun.sum())
    new_idx = np.full((n_pad + 1,), -1, dtype=np.int64)
    new_idx[:n_pad][is_sun] = np.arange(n_coarse)
    sun_safe = np.where(vmask, sun, n_pad)
    parent_coarse = new_idx[sun_safe]  # -1 for padding rows

    # coarse masses
    cmass = np.zeros((n_coarse,), dtype=np.float32)
    member = vmask & (parent_coarse >= 0)
    np.add.at(cmass, parent_coarse[member], mass[member])

    # inter-system links → coarse edges
    e_ok = emask & (src < n_pad) & (dst < n_pad)
    su, sv = sun_safe[src[e_ok]], sun_safe[dst[e_ok]]
    cross = su != sv
    cu = new_idx[su[cross]]
    cv = new_idx[sv[cross]]
    plen = (depth[src[e_ok]][cross] + 1 + depth[dst[e_ok]][cross]).astype(np.float32)
    plen = plen * ewt[e_ok][cross]  # compound desired lengths across levels
    lo = np.minimum(cu, cv)
    hi = np.maximum(cu, cv)
    key = lo * (n_coarse + 1) + hi
    order = np.argsort(key)
    key_s, lo_s, hi_s, w_s = key[order], lo[order], hi[order], plen[order]
    if key_s.size:
        uniq_mask = np.concatenate([[True], key_s[1:] != key_s[:-1]])
        seg_id = np.cumsum(uniq_mask) - 1
        n_edges = int(seg_id[-1]) + 1
        w_max = np.zeros((n_edges,), np.float32)
        np.maximum.at(w_max, seg_id, w_s)
        ce = np.stack([lo_s[uniq_mask], hi_s[uniq_mask]], axis=1)
    else:
        ce = np.zeros((0, 2), np.int64)
        w_max = np.zeros((0,), np.float32)

    sun_pos_index = np.nonzero(is_sun)[0].astype(np.int32)
    cg = build_graph(ce, n_coarse, mass=cmass, ewt=w_max, pad_mult=pad_mult,
                     bucket=bucket)
    info = LevelInfo(
        parent_coarse=parent_coarse[:n_pad].astype(np.int32),
        sun_of=sun_safe[:n_pad].astype(np.int32),
        depth=depth.astype(np.int32), state=state.astype(np.int32),
        sun_pos_index=sun_pos_index)
    return cg, info


def _build_compact():
    """The on-device half of ``next_level`` that depends only on the INPUT
    bucket: sun renumbering (masked prefix sum), segment-summed coarse
    masses, and sort-based parallel-link dedup, all at fixed [n_pad]/[m_pad]
    shapes with the true sizes returned as device scalars.

    Bit-parity notes vs ``next_level_host`` (verified by
    tests/test_merger_device.py): the scatter-add of member masses applies
    updates in ascending vertex order, matching ``np.add.at``; the dedup
    sorts lexicographically by (lo, hi) via a stable ``lexsort`` — the
    host's composite-key quicksort is unstable, but ties are exact
    duplicates and the per-group weight reduce is an order-independent max,
    so the compacted edge list and weights agree element-for-element. A
    composite ``lo * (n + 1) + hi`` key would overflow int32 at large
    buckets (f64 is banned — gilalint A2), hence the two-column sort.
    """

    def compact(st, src, dst, vmask, emask, mass, ewt):
        n_pad = vmask.shape[0]
        m_pad = src.shape[0]
        ids = jnp.arange(n_pad, dtype=jnp.int32)
        eids = jnp.arange(m_pad, dtype=jnp.int32)

        is_sun = (st.state == SUN) & vmask
        n_coarse = jnp.sum(is_sun.astype(jnp.int32))
        new_idx = jnp.where(is_sun,
                            jnp.cumsum(is_sun.astype(jnp.int32)) - 1, -1)
        new_ext = jnp.concatenate(
            [new_idx, jnp.full((1,), -1, jnp.int32)])
        sun_safe = jnp.where(vmask, st.sun, n_pad)
        parent_coarse = new_ext[sun_safe]          # -1 for padding rows
        # level-i vertex of each coarse vertex (ascending sun order)
        sun_pos_index = jnp.zeros((n_pad,), jnp.int32).at[
            jnp.where(is_sun, new_idx, n_pad)].set(ids, mode="drop")

        # coarse masses: ascending-order scatter-add (== np.add.at)
        member = vmask & (parent_coarse >= 0)
        cmass = jax.ops.segment_sum(
            jnp.where(member, mass, 0.0),
            jnp.where(member, parent_coarse, n_pad),
            num_segments=n_pad + 1)[:n_pad]

        # inter-system links over every half-edge slot
        sun_ext = jnp.concatenate(
            [sun_safe, jnp.full((1,), n_pad, jnp.int32)])
        depth_ext = jnp.concatenate(
            [st.depth, jnp.zeros((1,), jnp.int32)])
        e_ok = emask & (src < n_pad) & (dst < n_pad)
        su, sv = sun_ext[src], sun_ext[dst]
        cross = e_ok & (su != sv)
        cu, cv = new_ext[jnp.clip(su, 0, n_pad)], new_ext[jnp.clip(sv, 0, n_pad)]
        plen = (depth_ext[src] + 1 + depth_ext[dst]).astype(jnp.float32) * ewt
        lo = jnp.where(cross, jnp.minimum(cu, cv), n_pad)
        hi = jnp.where(cross, jnp.maximum(cu, cv), n_pad)
        w = jnp.where(cross, plen, 0.0)

        # parallel-link dedup: sort by (lo, hi) — invalid slots
        # (n_pad, n_pad) sink to the tail — then run-boundary compaction.
        # The weight payload rides the sort; its order within a (lo, hi)
        # tie is unspecified, which is fine: ties are exact duplicates and
        # the per-run weight reduce below is an order-independent max.
        # Small buckets pack both columns into one int32 key (~20% faster
        # XLA CPU sort); (n_pad + 1)^2 must stay below 2^31 (f64 packing is
        # banned — gilalint A2), so big buckets keep the two-key sort.
        if (n_pad + 1) ** 2 < 2 ** 31:
            key_s, w_s = jax.lax.sort(
                (lo * (n_pad + 1) + hi, w), num_keys=1)
            lo_s = key_s // (n_pad + 1)
            hi_s = key_s % (n_pad + 1)
        else:
            lo_s, hi_s, w_s = jax.lax.sort((lo, hi, w), num_keys=2)
        valid_s = lo_s < n_pad
        prev_same = jnp.concatenate(
            [jnp.zeros((1,), bool),
             (lo_s[1:] == lo_s[:-1]) & (hi_s[1:] == hi_s[:-1])])
        uniq = valid_s & ~prev_same
        seg_id = jnp.cumsum(uniq.astype(jnp.int32)) - 1
        n_edges = jnp.sum(uniq.astype(jnp.int32))
        # gather-only compaction (XLA CPU scatter is a sequential loop —
        # DESIGN.md §13): coarse edge j starts at the first slot of run j
        # (binary search over the nondecreasing run ids) and its weight is
        # the segmented running max read at the run's last slot. Invalid
        # tail slots continue the last run with weight 0 ≤ any real path
        # length, so they never perturb that run's max.
        first = jnp.searchsorted(seg_id, eids, side="left")
        last = jnp.searchsorted(seg_id, eids, side="right") - 1
        first_c = jnp.clip(first, 0, m_pad - 1)
        last_c = jnp.clip(last, 0, m_pad - 1)
        in_range = eids < n_edges
        ce_lo = jnp.where(in_range, lo_s[first_c], 0)
        ce_hi = jnp.where(in_range, hi_s[first_c], 0)

        def op(a, b):
            af, av = a
            bf, bv = b
            return af | bf, jnp.where(bf, bv, jnp.maximum(av, bv))

        w_run = jax.lax.associative_scan(
            op, (uniq, jnp.where(valid_s, w_s, 0.0)))[1]
        ce_w = jnp.where(in_range, w_run[last_c], 0.0)

        return (parent_coarse, sun_safe, st.depth, st.state, sun_pos_index,
                n_coarse, cmass, ce_lo, ce_hi, ce_w, n_edges)

    return jax.jit(compact, donate_argnums=bucketing.donate_argnums_if_supported(0))


def _build_assemble(n_pad_c: int, m_pad_c: int):
    """The on-device other half: lay the compacted coarse edges out in
    ``build_graph``'s exact buffer layout (forward half-edges first, then
    reversed; padding rows (n_pad, n_pad) with weight 1.0) at the coarse
    bucket shapes the host picked from the two true sizes. The coarse
    graph's arrays never exist on the host."""

    def assemble(ce_lo, ce_hi, ce_w, n_edges, cmass, n_coarse):
        m_pad_in = ce_lo.shape[0]
        # gather-only layout (XLA CPU scatter is a sequential loop): slot k
        # holds forward half-edge k while k < n_edges, reversed half-edge
        # k - n_edges while k < 2*n_edges, padding (n_pad_c, n_pad_c, w=1)
        # past that — exactly build_graph's buffer layout.
        idx = jnp.arange(m_pad_c, dtype=jnp.int32)
        in_fwd = idx < n_edges
        in_rev = ~in_fwd & (idx < 2 * n_edges)
        k_fwd = jnp.clip(idx, 0, m_pad_in - 1)
        k_rev = jnp.clip(idx - n_edges, 0, m_pad_in - 1)
        lo_f, hi_f = ce_lo[k_fwd], ce_hi[k_fwd]
        lo_r, hi_r = ce_lo[k_rev], ce_hi[k_rev]
        src = jnp.where(in_fwd, lo_f, jnp.where(in_rev, hi_r, n_pad_c))
        dst = jnp.where(in_fwd, hi_f, jnp.where(in_rev, lo_r, n_pad_c))
        emask = in_fwd | in_rev
        ewt = jnp.where(in_fwd, ce_w[k_fwd],
                        jnp.where(in_rev, ce_w[k_rev], 1.0))
        vmask = jnp.arange(n_pad_c, dtype=jnp.int32) < n_coarse
        # compact's cmass is already zero past n_coarse; the where keeps
        # the padding contract explicit (and exact under donation reuse)
        mass = jnp.where(vmask, cmass[:n_pad_c], 0.0)
        return src, dst, vmask, emask, mass, ewt

    return jax.jit(assemble, donate_argnums=bucketing.donate_argnums_if_supported(0))


def cached_compact(g: PaddedGraph, st: MergerState):
    """(cache_key, fn, fresh, args) for the input-bucket compaction program
    — shared by ``next_level`` and the gilalint jaxpr audit."""
    cache_key = ("compact", g.n_pad, g.m_pad)
    fn, fresh = STEP_CACHE.get(cache_key, _build_compact)
    args = (st, g.src, g.dst, g.vmask, g.emask, g.mass, g.ewt)
    return cache_key, fn, fresh, args


def cached_assemble(ce_lo, ce_hi, ce_w, n_edges, cmass, n_coarse, *,
                    n_pad_c: int, m_pad_c: int):
    """(cache_key, fn, fresh, args) for the coarse-bucket assembly program
    (``n_pad_c``/``m_pad_c`` are the host's bucket decision — the only
    payload-derived statics, and both appear in the key)."""
    cache_key = ("next_level", int(ce_lo.shape[0]), n_pad_c, m_pad_c)
    fn, fresh = STEP_CACHE.get(
        cache_key, lambda: _build_assemble(n_pad_c, m_pad_c))
    args = (ce_lo, ce_hi, ce_w, n_edges, cmass, n_coarse)
    return cache_key, fn, fresh, args


def _next_level_device(g: PaddedGraph, st: MergerState, pad_mult: int
                       ) -> tuple[PaddedGraph, LevelInfo]:
    """Device-resident ``next_level``: compact at the input bucket, read
    the two true sizes (the only host sync), assemble at the coarse
    bucket. The LevelInfo arrays stay on device."""
    ck, fn, fresh, args = cached_compact(g, st)
    with obs_trace.span("coarsen.compact", cat="device", key=ck,
                        fresh=fresh):
        (parent_coarse, sun_of, depth, state, sun_pos_index, n_coarse,
         cmass, ce_lo, ce_hi, ce_w, n_edges) = fn(*args)
        with io_boundary():             # egress: the two true sizes
            n_coarse_i, n_edges_i = int(n_coarse), int(n_edges)

    # the host's whole remaining job: the coarse shape-bucket decision
    # (must match build_graph(bucket=True) so both compaction paths land
    # levels in identical buckets)
    n_pad_c = bucket_pad(n_coarse_i, pad_mult)
    m_pad_c = bucket_pad(2 * n_edges_i, pad_mult)
    ak, afn, afresh, aargs = cached_assemble(
        ce_lo, ce_hi, ce_w, n_edges, cmass, n_coarse,
        n_pad_c=n_pad_c, m_pad_c=m_pad_c)
    with obs_trace.span("coarsen.assemble", cat="device", key=ak,
                        fresh=afresh):
        src, dst, vmask, emask, mass, ewt = afn(*aargs)
    cg = PaddedGraph(src=src, dst=dst, vmask=vmask, emask=emask, mass=mass,
                     ewt=ewt, n=n_coarse_i, m=n_edges_i)
    with io_boundary():    # staging: the slice start index is a host scalar
        spi = sun_pos_index[:n_coarse_i]
    info = LevelInfo(parent_coarse=parent_coarse, sun_of=sun_of,
                     depth=depth, state=state, sun_pos_index=spi)
    return cg, info
