"""Distributed Solar Merger — the coarsening phase of Multi-GiLA (paper §3.2).

Vertex-centric BSP protocol mapped to dense JAX array supersteps:

  1. *Sun generation*: unassigned vertices self-elect with probability p;
     conflicts within graph distance < 3 are resolved by ID (two max-
     propagation supersteps — a sun survives iff it is the strict 2-hop
     maximum among candidates, which guarantees pairwise sun distance ≥ 3).
  2. *Solar-system generation*: suns broadcast offers; unassigned neighbors
     become planets of the max-ID offering sun; planets forward offers;
     unassigned 2-hop vertices become moons (recording the forwarding
     planet for two-hop routing).
  3. Steps 1–2 repeat until no vertex is unassigned (every 4th round is a
     *forced* round where all unassigned vertices self-elect, guaranteeing
     termination).
  4. *Inter-system links*: edges whose endpoints lie in different systems
     are discovered; each contributes a path of length depth(u)+1+depth(v).
  5. *Next-level generation*: systems collapse into their suns; coarse-edge
     weight = max path length over the parallel links (host compaction).

Each superstep is a jitted fixed-shape program built from gather/segment
primitives; the BSP halting vote ("no unassigned left") is the only host
synchronization, matching Giraph's aggregator semantics.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.graph import PaddedGraph, build_graph, edge_gather
from repro.utils.prng import uniform_per_vertex
from repro.utils.transfer import io_boundary

UNASSIGNED, SUN, PLANET, MOON = 0, 1, 2, 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MergerState:
    """Per-vertex solar-system assignment (padding rows are UNASSIGNED but
    masked out by g.vmask everywhere)."""
    state: jnp.ndarray   # int32[n_pad] — UNASSIGNED/SUN/PLANET/MOON
    sun: jnp.ndarray     # int32[n_pad] — index of the system's sun (n_pad = none)
    depth: jnp.ndarray   # int32[n_pad] — hops to the sun (0/1/2)
    parent: jnp.ndarray  # int32[n_pad] — next hop toward the sun (for 2-hop msgs)


def init_state(g: PaddedGraph) -> MergerState:
    n_pad = g.n_pad
    with io_boundary():                 # intentional host→device staging
        return MergerState(
            state=jnp.zeros((n_pad,), jnp.int32),
            sun=jnp.full((n_pad,), n_pad, jnp.int32),
            depth=jnp.full((n_pad,), -1, jnp.int32),
            parent=jnp.full((n_pad,), n_pad, jnp.int32),
        )


def _push_max(g: PaddedGraph, values: jnp.ndarray) -> jnp.ndarray:
    """Superstep: broadcast int values, combine with max (-1 = no message)."""
    msgs = edge_gather(g, values)
    msgs = jnp.where(g.emask, msgs, -1)
    out = jax.ops.segment_max(msgs, g.dst, num_segments=g.n_pad + 1,
                              indices_are_sorted=False)
    return jnp.maximum(out[: g.n_pad], -1)


@jax.jit
def sun_election(g: PaddedGraph, st: MergerState, key: jnp.ndarray,
                 p: jnp.ndarray, forced: jnp.ndarray,
                 respect_existing: jnp.ndarray) -> MergerState:
    """One sun-generation round (supersteps 1–3 of paper §3.2 step 1).

    Existing suns participate in the conflict broadcast with dominating
    priority (ID + n_pad) so fresh candidates never elect within 2 hops of
    an established system. ``respect_existing=False`` is the *desperation*
    mode used only when the BSP vote stalls: a vertex can be ≤2 hops from a
    sun yet unreachable by offers (all intermediaries owned by other
    systems), and must then be allowed to self-elect — a documented
    deviation required for guaranteed termination.
    """
    n_pad = g.n_pad
    ids = jnp.arange(n_pad, dtype=jnp.int32)
    unassigned = (st.state == UNASSIGNED) & g.vmask
    # per-vertex coin streams (utils/prng.py): vertex v's draw depends only
    # on (key, v), not on the padding bucket — re-padding the same graph
    # elects the same suns (the bucketing parity contract)
    coin = uniform_per_vertex(key, ids) < p
    cand = unassigned & (coin | forced)

    # candidates announce their ID; two forwarding supersteps compute, per
    # vertex, the maximum candidate ID within graph distance ≤ 2.
    sun_prio = jnp.where((st.state == SUN) & respect_existing, ids + n_pad, -1)
    h0 = jnp.maximum(jnp.where(cand, ids, -1), sun_prio)
    h1 = jnp.maximum(h0, _push_max(g, h0))
    h2 = jnp.maximum(h1, _push_max(g, h1))
    # a candidate survives iff no strictly greater candidate (or established
    # sun, which always dominates) is within 2 hops. Desperation mode relaxes
    # the radius to 1 hop: stuck vertices cluster behind moons (which never
    # forward offers), and pairwise non-adjacent ones must elect in parallel
    # for O(log n) convergence (Luby-MIS on the stuck set).
    h_conflict = jnp.where(respect_existing, h2, h1)
    new_sun = cand & (h_conflict <= ids)

    state = jnp.where(new_sun, SUN, st.state)
    sun = jnp.where(new_sun, ids, st.sun)
    depth = jnp.where(new_sun, 0, st.depth)
    parent = jnp.where(new_sun, ids, st.parent)
    return MergerState(state, sun, depth, parent)


@jax.jit
def system_growth(g: PaddedGraph, st: MergerState) -> MergerState:
    """One solar-system-generation round (offers → planets → moons)."""
    n_pad = g.n_pad
    ids = jnp.arange(n_pad, dtype=jnp.int32)
    unassigned = (st.state == UNASSIGNED) & g.vmask

    # Superstep A: suns broadcast offers; unassigned neighbors accept the
    # max-ID adjacent sun and become planets.
    offer1 = _push_max(g, jnp.where(st.state == SUN, ids, -1))
    becomes_planet = unassigned & (offer1 >= 0)
    state = jnp.where(becomes_planet, PLANET, st.state)
    sun = jnp.where(becomes_planet, offer1, st.sun)
    depth = jnp.where(becomes_planet, 1, st.depth)
    parent = jnp.where(becomes_planet, offer1, st.parent)  # next hop = the sun

    # Superstep B: new planets forward their sun's offer; remaining
    # unassigned vertices accept the max forwarded sun and become moons.
    planet_fwd = jnp.where(state == PLANET, sun, -1)
    offer2 = _push_max(g, planet_fwd)
    still_un = unassigned & ~becomes_planet
    becomes_moon = still_un & (offer2 >= 0)
    # pick the forwarding planet: max planet ID among in-neighbors whose sun
    # matches the accepted offer (two-hop confirmation route, paper §3.2).
    match_val = jnp.where(state == PLANET, ids, -1)
    msgs = edge_gather(g, jnp.stack([planet_fwd, match_val], axis=1))
    key_match = jnp.where(
        g.emask & (msgs[:, 0] >= 0) & (msgs[:, 0] == offer2[jnp.clip(g.dst, 0, n_pad - 1)])
        & (g.dst < n_pad),
        msgs[:, 1], -1)
    via = jax.ops.segment_max(key_match, g.dst, num_segments=n_pad + 1)[:n_pad]
    via = jnp.maximum(via, -1)

    state = jnp.where(becomes_moon, MOON, state)
    sun = jnp.where(becomes_moon, offer2, sun)
    depth = jnp.where(becomes_moon, 2, depth)
    parent = jnp.where(becomes_moon, via, parent)
    return MergerState(state, sun, depth, parent)


def run_merger(g: PaddedGraph, *, p_sun: float = 0.35, seed: int = 0,
               max_rounds: int = 96, force_every: int = 4) -> MergerState:
    """Run election+growth rounds until every valid vertex is assigned.

    The BSP halting vote ("any unassigned left?") is the only host sync per
    round. If two consecutive rounds make no progress, the next round runs
    in desperation mode (forced candidacy, existing suns not respected),
    which guarantees at least one new sun and hence termination.
    """
    st = init_state(g)
    # the jitted supersteps never read the static n/m fields, so normalize
    # them away: the jit caches key on padded shapes only, and every graph
    # in the same shape bucket reuses one compiled program (bucketing.py)
    gn = dataclasses.replace(g, n=0, m=0)
    with io_boundary():                 # staging: RNG seed → device key
        key = jax.random.PRNGKey(seed)
    prev_remaining = g.n + 1
    stalls = 0
    desperate = False
    for r in range(max_rounds):
        # sticky desperation: once the vote stalls twice, run Luby-MIS-style
        # rounds (all unassigned candidates, existing suns not respected)
        # until convergence — O(log n) rounds with strict progress.
        desperate = desperate or stalls >= 2
        with io_boundary():             # staging: per-round scalar knobs
            key, sub = jax.random.split(key)
            forced = jnp.asarray(desperate
                                 or r % force_every == force_every - 1)
            p = jnp.asarray(p_sun, jnp.float32)
            respect = jnp.asarray(not desperate)
        st = sun_election(gn, st, sub, p, forced, respect)
        st = system_growth(gn, st)
        # BSP halting vote (host sync, as a Giraph aggregator would)
        with io_boundary():
            remaining = int(jnp.sum((st.state == UNASSIGNED) & g.vmask))
        if remaining == 0:
            return st
        stalls = 0 if remaining < prev_remaining else stalls + 1
        prev_remaining = remaining
    raise RuntimeError(f"solar merger did not converge in {max_rounds} rounds")


def centralized_solar_merger(edges: np.ndarray, n: int, seed: int = 0
                             ) -> tuple[np.ndarray, int]:
    """Sequential Solar Merger reference (FM³'s greedy, Hachul 2005):
    visit vertices in random order; an unassigned vertex becomes a sun and
    absorbs its unassigned ≤2-hop neighborhood (planets then moons).
    Returns (sun_of[n], n_suns) — used for the Fig.5 level-count baseline.
    """
    from repro.graphs.graph import to_csr
    rng = np.random.default_rng(seed)
    row_ptr, col = to_csr(edges, n)
    sun_of = np.full(n, -1, dtype=np.int64)
    n_suns = 0
    for v in rng.permutation(n):
        if sun_of[v] >= 0:
            continue
        sun_of[v] = v
        n_suns += 1
        planets = [u for u in col[row_ptr[v]:row_ptr[v + 1]]
                   if sun_of[u] < 0]
        for u in planets:
            sun_of[u] = v
        for u in planets:
            for w in col[row_ptr[u]:row_ptr[u + 1]]:
                if sun_of[w] < 0:
                    sun_of[w] = v
    return sun_of, n_suns


def centralized_levels(edges: np.ndarray, n: int, *, threshold: int = 50,
                       max_levels: int = 24, seed: int = 0) -> list[int]:
    """Level sizes produced by iterating the centralized Solar Merger."""
    sizes = [n]
    cur_edges, cur_n = edges, n
    for _ in range(max_levels):
        if cur_n <= threshold or len(cur_edges) == 0:
            break
        sun_of, n_suns = centralized_solar_merger(cur_edges, cur_n, seed)
        if n_suns >= cur_n:
            break
        new_idx = np.full(cur_n, -1, dtype=np.int64)
        suns = np.unique(sun_of)
        new_idx[suns] = np.arange(len(suns))
        ce = new_idx[sun_of[cur_edges]]
        ce = ce[ce[:, 0] != ce[:, 1]]
        ce = np.unique(np.sort(ce, axis=1), axis=0) if len(ce) else ce
        cur_edges, cur_n = ce, len(suns)
        sizes.append(cur_n)
    return sizes


@dataclasses.dataclass
class LevelInfo:
    """Host-side record connecting level i to level i+1 (for the placer)."""
    parent_coarse: np.ndarray  # int32[n_pad_i] — coarse index of v's sun
    sun_of: np.ndarray         # int32[n_pad_i] — sun vertex of v (level-i idx)
    depth: np.ndarray          # int32[n_pad_i]
    state: np.ndarray          # int32[n_pad_i]
    sun_pos_index: np.ndarray  # int32[n_coarse] — level-i vertex of each coarse vertex


def next_level(g: PaddedGraph, st: MergerState, *, pad_mult: int = 256,
               bucket: bool = False) -> tuple[PaddedGraph, LevelInfo]:
    """Collapse solar systems into suns → coarse graph (host compaction).

    Coarse vertices = suns (mass = Σ member masses); coarse edges = unique
    inter-system links, weighted by the longest member path
    (depth_u + 1 + depth_v) over all parallel links, times the max endpoint
    edge weight (so weights compound across levels as in FM³).
    ``bucket=True`` pads the coarse graph to pow2 shape buckets
    (core/bucketing.py).
    """
    n_pad = g.n_pad
    state = np.asarray(st.state)
    sun = np.asarray(st.sun)
    depth = np.asarray(st.depth)
    vmask = np.asarray(g.vmask)
    mass = np.asarray(g.mass)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    emask = np.asarray(g.emask)
    ewt = np.asarray(g.ewt)

    is_sun = (state == SUN) & vmask
    n_coarse = int(is_sun.sum())
    new_idx = np.full((n_pad + 1,), -1, dtype=np.int64)
    new_idx[:n_pad][is_sun] = np.arange(n_coarse)
    sun_safe = np.where(vmask, sun, n_pad)
    parent_coarse = new_idx[sun_safe]  # -1 for padding rows

    # coarse masses
    cmass = np.zeros((n_coarse,), dtype=np.float32)
    member = vmask & (parent_coarse >= 0)
    np.add.at(cmass, parent_coarse[member], mass[member])

    # inter-system links → coarse edges
    e_ok = emask & (src < n_pad) & (dst < n_pad)
    su, sv = sun_safe[src[e_ok]], sun_safe[dst[e_ok]]
    cross = su != sv
    cu = new_idx[su[cross]]
    cv = new_idx[sv[cross]]
    plen = (depth[src[e_ok]][cross] + 1 + depth[dst[e_ok]][cross]).astype(np.float32)
    plen = plen * ewt[e_ok][cross]  # compound desired lengths across levels
    lo = np.minimum(cu, cv)
    hi = np.maximum(cu, cv)
    key = lo * (n_coarse + 1) + hi
    order = np.argsort(key)
    key_s, lo_s, hi_s, w_s = key[order], lo[order], hi[order], plen[order]
    if key_s.size:
        uniq_mask = np.concatenate([[True], key_s[1:] != key_s[:-1]])
        seg_id = np.cumsum(uniq_mask) - 1
        n_edges = int(seg_id[-1]) + 1
        w_max = np.zeros((n_edges,), np.float32)
        np.maximum.at(w_max, seg_id, w_s)
        ce = np.stack([lo_s[uniq_mask], hi_s[uniq_mask]], axis=1)
    else:
        ce = np.zeros((0, 2), np.int64)
        w_max = np.zeros((0,), np.float32)

    sun_pos_index = np.nonzero(is_sun)[0].astype(np.int32)
    cg = build_graph(ce, n_coarse, mass=cmass, ewt=w_max, pad_mult=pad_mult,
                     bucket=bucket)
    info = LevelInfo(
        parent_coarse=parent_coarse[:n_pad].astype(np.int32),
        sun_of=sun_safe[:n_pad].astype(np.int32),
        depth=depth.astype(np.int32), state=state.astype(np.int32),
        sun_pos_index=sun_pos_index)
    return cg, info
