"""Per-level parameter schedules (paper §3.4 dynamic tuning of GiLA).

The paper tunes k (repulsion horizon) by edge count, and the remaining
parameters so that coarse levels get more quality (more iterations, hotter
start) and fine levels get speed (good init ⇒ few iterations suffice).
"""
from __future__ import annotations

import dataclasses

from repro.core.gila import paper_k_schedule


@dataclasses.dataclass(frozen=True)
class LevelSchedule:
    k: int               # repulsion horizon (paper's table)
    cap: int             # neighbor-list cap (message-load bound)
    iters: int
    temp0: float
    temp_decay: float
    mode: str            # "exact" | "neighbor"


def make_schedule(level: int, n_levels: int, n: int, m: int,
                  *, exact_threshold: int = 2048,
                  coarsest_iters: int = 300, finest_iters: int = 50,
                  ideal_len: float = 1.0) -> LevelSchedule:
    """level = 0 is the input graph; level = n_levels-1 is the coarsest."""
    k = paper_k_schedule(m)
    cap = {1: 32, 2: 64, 3: 128, 4: 192, 5: 256, 6: 256}[k]
    # geometric interpolation: coarse → many iterations, fine → few
    if n_levels <= 1:
        iters = coarsest_iters
    else:
        frac = level / (n_levels - 1)           # 1 at coarsest
        iters = int(finest_iters * (coarsest_iters / finest_iters) ** frac)
    # hotter start on coarse levels (layout from scratch), gentle on fine
    extent = ideal_len * max(n, 4) ** 0.5
    temp0 = extent * (0.25 if level == n_levels - 1 else 0.06)
    mode = "exact" if n <= exact_threshold else "neighbor"
    return LevelSchedule(k=k, cap=cap, iters=max(iters, 10), temp0=temp0,
                         temp_decay=0.985 if level == n_levels - 1 else 0.96,
                         mode=mode)
