"""Per-level parameter schedules (paper §3.4 dynamic tuning of GiLA).

The paper tunes k (repulsion horizon) by edge count, and the remaining
parameters so that coarse levels get more quality (more iterations, hotter
start) and fine levels get speed (good init ⇒ few iterations suffice).

Repulsion-mode selection by level size:
  n ≤ exact_threshold   →  "exact"     tiled all-pairs (coarse levels)
  n ≤ grid_threshold    →  "neighbor"  capped k-hop lists (mid levels)
  n > grid_threshold    →  "grid"      grid-bucketed approximation (fine
                                       levels of big hierarchies, where
                                       k-hop caps degrade quality and the
                                       host-side list build dominates)

Every mode runs single-device (core/gila.py) and sharded: the schedule's
``mode``/``grid_dim``/``cell_cap`` feed ``core/distributed.py``'s
``layout_train_step`` unchanged (engine="multigila_dist" routes whole
levels through it). The sharded grid path psums O(G²) per-cell aggregates
and resolves the 3×3 near field from an all_gather of bucketed positions,
or — when vertices are band-partitioned by grid row — from just the two
boundary-cell bucket rows (halo variant; it beats the all_gather once
2·G·cell_cap ≪ n, see kernels/grid_force/README.md and DESIGN.md §4.3).
"""
from __future__ import annotations

import dataclasses

from repro.core.gila import paper_k_schedule


@dataclasses.dataclass(frozen=True)
class LevelSchedule:
    k: int               # repulsion horizon (paper's table)
    cap: int             # neighbor-list cap (message-load bound)
    iters: int
    temp0: float
    temp_decay: float
    mode: str            # "exact" | "neighbor" | "grid"
    grid_dim: int = 0    # G (grid mode only): G×G spatial cells
    cell_cap: int = 0    # bucket capacity per cell (grid mode only)
    engine: str = "gila"  # refinement engine id (core/engine.py registry)


def make_schedule(level: int, n_levels: int, n: int, m: int,
                  *, exact_threshold: int = 2048,
                  grid_threshold: int = 32768,
                  coarsest_iters: int = 300, finest_iters: int = 50,
                  ideal_len: float = 1.0,
                  n_pad: int | None = None,
                  engine: str = "gila") -> LevelSchedule:
    """level = 0 is the input graph; level = n_levels-1 is the coarsest.

    ``n_pad`` is the level's padded (bucketed) vertex count. The STATIC
    compiled-shape parameters — grid_dim/cell_cap — are chosen from it, so
    every graph in the same shape bucket shares one compiled program
    (core/bucketing.py). Mode selection stays on the true ``n``: with the
    default power-of-two thresholds, ``n ≤ T ⇔ bucket_pad(n) ≤ T``, so two
    same-bucket graphs can never disagree on the mode anyway.
    """
    k = paper_k_schedule(m)
    cap = {1: 32, 2: 64, 3: 128, 4: 192, 5: 256, 6: 256}[k]
    # geometric interpolation: coarse → many iterations, fine → few
    if n_levels <= 1:
        iters = coarsest_iters
    else:
        frac = level / (n_levels - 1)           # 1 at coarsest
        iters = int(finest_iters * (coarsest_iters / finest_iters) ** frac)
    # hotter start on coarse levels (layout from scratch), gentle on fine
    extent = ideal_len * max(n, 4) ** 0.5
    temp0 = extent * (0.25 if level == n_levels - 1 else 0.06)
    grid_dim = cell_cap = 0
    if n <= exact_threshold:
        mode = "exact"
    elif n <= grid_threshold:
        mode = "neighbor"
    else:
        mode = "grid"
        # deferred import: keeps the Pallas kernel stack off the module
        # import path for consumers that never select grid mode
        from repro.kernels.grid_force import choose_grid
        grid_dim, cell_cap = choose_grid(n_pad if n_pad is not None else n)
    sched = LevelSchedule(
        k=k, cap=cap, iters=max(iters, 10), temp0=temp0,
        temp_decay=0.985 if level == n_levels - 1 else 0.96,
        mode=mode, grid_dim=grid_dim, cell_cap=cell_cap, engine=engine)
    # give the engine its schedule hook (no-op for gila); deferred import
    # so the schedule module stays importable without the engine stack
    from repro.core.engine import get_engine
    return get_engine(engine).tune(sched)
