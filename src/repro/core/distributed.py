"""shard_map distribution of the layout supersteps over the production mesh.

Decomposition (DESIGN.md §4):
  * per-vertex state is sharded over the flattened vertex axes
    VTX = ("pod", "data") — or ("data",) on a single pod;
  * the all-pairs repulsion partner dimension is sharded over "model",
    giving a 2-D decomposition of the interaction matrix: device (v, m)
    computes rows of its vertex block against column chunk m, then psums
    partials over "model";
  * edge lists are pre-sorted by destination shard (Spinner order) so each
    device's segment-sum lands in its own vertex block; source positions
    come from an all_gather over VTX (8 bytes/vertex — the same per-round
    broadcast volume the paper's Giraph workers pay), or from a halo
    exchange of only the boundary vertices (optimized variant, §Perf).

Every function here is pure SPMD and lowers on the 512-chip mesh; the
dry-run rows for the layout engine come from `layout_step_spec` below.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.utils.compat import shard_map


def vtx_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, names) -> int:
    s = 1
    for n in (names if isinstance(names, tuple) else (names,)):
        s *= mesh.shape[n]
    return s


# -- exact N-body, 2-D decomposed ---------------------------------------------

def sharded_nbody(mesh: Mesh, n_pad: int):
    """Returns a jitted f(pos[n_pad,2], w[n_pad]) → forces, 2-D decomposed."""
    VTX = vtx_axes(mesh)
    msize = mesh.shape["model"]

    def local(pos_blk, w_blk, params):
        C, L, md = params[0], params[1], params[2]
        pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)   # [n_pad, 2]
        w_all = jax.lax.all_gather(w_blk, VTX, tiled=True)       # [n_pad]
        chunk = n_pad // msize
        mi = jax.lax.axis_index("model")
        cpos = jax.lax.dynamic_slice_in_dim(pos_all, mi * chunk, chunk)
        cw = jax.lax.dynamic_slice_in_dim(w_all, mi * chunk, chunk)
        dx = pos_blk[:, 0][:, None] - cpos[:, 0][None, :]
        dy = pos_blk[:, 1][:, None] - cpos[:, 1][None, :]
        d2 = dx * dx + dy * dy + md * md
        inv = (C * L * L) * cw[None, :] / d2
        partial = jnp.stack([jnp.sum(dx * inv, 1), jnp.sum(dy * inv, 1)], 1)
        return jax.lax.psum(partial, "model")

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(VTX, None), P(VTX), P()),
                   out_specs=P(VTX, None))
    return jax.jit(fn)


# -- message superstep (attraction / merger push) ------------------------------

def sharded_attraction(mesh: Mesh, n_pad: int, m_pad: int):
    """f(pos, src, dst_local, emask, ewt, params) → attraction forces.

    Edge arrays are sharded over VTX with ``dst_local`` already offset into
    the local vertex block (host-side pre-partitioning by destination).
    """
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    n_loc = n_pad // vsize

    def local(pos_blk, src, dst_local, emask, ewt, params):
        C, L, md = params[0], params[1], params[2]
        pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)
        pos_all = jnp.concatenate([pos_all, jnp.zeros((1, 2), pos_all.dtype)], 0)
        ps = pos_all[src]                       # [m_loc, 2] remote reads
        pd = pos_blk[jnp.clip(dst_local, 0, n_loc - 1)]
        delta = ps - pd
        dist = jnp.sqrt(jnp.sum(delta * delta, 1) + md * md)
        ell = jnp.maximum(ewt, 1e-6) * L
        f = (dist * dist) / ell
        vec = jnp.where(emask[:, None], delta / dist[:, None] * f[:, None], 0.0)
        out = jax.ops.segment_sum(vec, jnp.clip(dst_local, 0, n_loc),
                                  num_segments=n_loc + 1)
        return out[:n_loc]

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(VTX, None), P(VTX), P(VTX), P(VTX), P(VTX), P()),
                   out_specs=P(VTX, None))
    return jax.jit(fn)


def sharded_push_max(mesh: Mesh, n_pad: int):
    """Distributed merger superstep: broadcast int values, max-combine."""
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    n_loc = n_pad // vsize

    def local(vals_blk, src, dst_local, emask):
        vals_all = jax.lax.all_gather(vals_blk, VTX, tiled=True)
        vals_all = jnp.concatenate([vals_all, jnp.full((1,), -1, vals_all.dtype)], 0)
        msgs = jnp.where(emask, vals_all[src], -1)
        out = jax.ops.segment_max(msgs, jnp.clip(dst_local, 0, n_loc),
                                  num_segments=n_loc + 1)
        return jnp.maximum(out[:n_loc], -1)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(VTX), P(VTX), P(VTX), P(VTX)),
                   out_specs=P(VTX))
    return jax.jit(fn)


# -- neighbor-list repulsion (fine levels) -------------------------------------

def sharded_neighbor_force(mesh: Mesh, n_pad: int, cap: int):
    """f(pos, w, nbr_idx[n_pad,cap]) — k-hop repulsion with remote gathers."""
    VTX = vtx_axes(mesh)

    def local(pos_blk, w_blk, nbr_idx, params):
        C, L, md = params[0], params[1], params[2]
        pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)
        w_all = jax.lax.all_gather(w_blk, VTX, tiled=True)
        pos_all = jnp.concatenate([pos_all, jnp.zeros((1, 2), pos_all.dtype)], 0)
        w_all = jnp.concatenate([w_all, jnp.zeros((1,), w_all.dtype)], 0)
        npos = pos_all[nbr_idx]                 # [n_loc, cap, 2]
        nw = w_all[nbr_idx]
        delta = pos_blk[:, None, :] - npos
        d2 = jnp.sum(delta * delta, -1) + md * md
        inv = (C * L * L) * nw / d2
        return jnp.sum(delta * inv[:, :, None], axis=1)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(VTX, None), P(VTX), P(VTX, None), P()),
                   out_specs=P(VTX, None))
    return jax.jit(fn)


# -- full distributed layout step (used by the dry-run) ------------------------

def layout_train_step(mesh: Mesh, n_pad: int, m_pad: int, cap: int,
                      mode: str = "neighbor"):
    """One full distributed GiLA iteration: repulsion + attraction + update.

    Returns (step_fn, input_shardings) suitable for
    jax.jit(step_fn, in_shardings=...).lower(*specs).
    """
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    n_loc = n_pad // vsize
    msize = mesh.shape["model"]

    def local(pos_blk, w_blk, nbr_idx, src, dst_local, emask, ewt, params, temp):
        C, L, md = params[0], params[1], params[2]
        pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)
        w_all = jax.lax.all_gather(w_blk, VTX, tiled=True)
        pos_pad = jnp.concatenate([pos_all, jnp.zeros((1, 2), pos_all.dtype)], 0)
        w_pad = jnp.concatenate([w_all, jnp.zeros((1,), w_all.dtype)], 0)

        if mode == "exact":
            chunk = n_pad // msize
            mi = jax.lax.axis_index("model")
            cpos = jax.lax.dynamic_slice_in_dim(pos_all, mi * chunk, chunk)
            cw = jax.lax.dynamic_slice_in_dim(w_all, mi * chunk, chunk)
            dx = pos_blk[:, 0][:, None] - cpos[:, 0][None, :]
            dy = pos_blk[:, 1][:, None] - cpos[:, 1][None, :]
            d2 = dx * dx + dy * dy + md * md
            inv = (C * L * L) * cw[None, :] / d2
            rep = jax.lax.psum(
                jnp.stack([jnp.sum(dx * inv, 1), jnp.sum(dy * inv, 1)], 1),
                "model")
        else:
            # split the neighbor cap over the model axis → 2-D decomposition
            ccap = cap // msize
            mi = jax.lax.axis_index("model")
            nidx = jax.lax.dynamic_slice_in_dim(nbr_idx, mi * ccap, ccap, axis=1)
            npos = pos_pad[nidx]
            nw = w_pad[nidx]
            delta = pos_blk[:, None, :] - npos
            d2 = jnp.sum(delta * delta, -1) + md * md
            inv = (C * L * L) * nw / d2
            rep = jax.lax.psum(jnp.sum(delta * inv[:, :, None], axis=1), "model")

        ps = pos_pad[src]
        pd = pos_blk[jnp.clip(dst_local, 0, n_loc - 1)]
        delta = ps - pd
        dist = jnp.sqrt(jnp.sum(delta * delta, 1) + md * md)
        f = (dist * dist) / (jnp.maximum(ewt, 1e-6) * L)
        vec = jnp.where(emask[:, None], delta / dist[:, None] * f[:, None], 0.0)
        att = jax.ops.segment_sum(vec, jnp.clip(dst_local, 0, n_loc),
                                  num_segments=n_loc + 1)[:n_loc]

        force = rep + att
        norm = jnp.sqrt(jnp.sum(force * force, 1) + 1e-12)
        step = jnp.minimum(norm, temp)
        return pos_blk + force / norm[:, None] * step[:, None]

    step = shard_map(
        local, mesh=mesh,
        in_specs=(P(VTX, None), P(VTX), P(VTX, None), P(VTX), P(VTX), P(VTX),
                  P(VTX), P(), P()),
        out_specs=P(VTX, None))
    shardings = dict(
        pos=NamedSharding(mesh, P(VTX, None)),
        w=NamedSharding(mesh, P(VTX)),
        nbr_idx=NamedSharding(mesh, P(VTX, None)),
        edge=NamedSharding(mesh, P(VTX)),
        scalar=NamedSharding(mesh, P()),
    )
    return step, shardings


def layout_train_step_halo(mesh: Mesh, n_pad: int, m_pad: int, cap: int,
                           halo: int):
    """GiLA iteration with HALO EXCHANGE instead of the position all-gather
    (§Perf hillclimb C — the paper's Spinner-locality insight made explicit).

    With a Spinner partition, almost all k-hop neighbors are shard-local;
    each device needs only the boundary ("halo") positions of its peers.
    Host-side preprocessing produces, per device, ``send_idx[P, halo]``
    (local vertices each peer needs; sentinel-padded) and neighbor lists
    remapped into [local | halo-slot | sentinel] coordinates. Communication
    per superstep drops from all-gather(n·12B) to all_to_all(P·halo·12B).
    """
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    n_loc = n_pad // vsize

    def local(pos_blk, w_blk, nbr_local, send_idx, src_local, dst_local,
              emask, ewt, params, temp):
        C, L, md = params[0], params[1], params[2]
        P_ = send_idx.shape[0]
        table = jnp.concatenate(
            [pos_blk, jnp.zeros((1, 2), pos_blk.dtype)], 0)
        wtab = jnp.concatenate([w_blk, jnp.zeros((1,), w_blk.dtype)], 0)
        sidx = jnp.clip(send_idx, 0, n_loc)
        send = jnp.concatenate(
            [table[sidx], wtab[sidx][..., None]], axis=-1)     # [P, halo, 3]
        # hierarchical personalized all-to-all over the vertex axes:
        # peers laid out [pod, data]; exchange the data stage, then pod.
        shape = tuple(mesh.shape[a] for a in VTX)
        recv = send.reshape(shape + send.shape[1:])
        for d, ax in enumerate(VTX):
            recv = jax.lax.all_to_all(recv, ax, split_axis=d, concat_axis=d)
        recv = recv.reshape(P_, -1, 3)

        halo_pos = recv[..., :2].reshape(-1, 2)
        halo_w = recv[..., 2].reshape(-1)
        full_pos = jnp.concatenate(
            [pos_blk, halo_pos, jnp.zeros((1, 2), pos_blk.dtype)], 0)
        full_w = jnp.concatenate([w_blk, halo_w,
                                  jnp.zeros((1,), w_blk.dtype)], 0)

        npos = full_pos[nbr_local]                  # [n_loc, cap, 2]
        nw = full_w[nbr_local]
        delta = pos_blk[:, None, :] - npos
        d2 = jnp.sum(delta * delta, -1) + md * md
        inv = (C * L * L) * nw / d2
        rep = jnp.sum(delta * inv[:, :, None], axis=1)

        ps = full_pos[src_local]
        pd = pos_blk[jnp.clip(dst_local, 0, n_loc - 1)]
        delta = ps - pd
        dist = jnp.sqrt(jnp.sum(delta * delta, 1) + md * md)
        f = (dist * dist) / (jnp.maximum(ewt, 1e-6) * L)
        vec = jnp.where(emask[:, None], delta / dist[:, None] * f[:, None], 0.0)
        att = jax.ops.segment_sum(vec, jnp.clip(dst_local, 0, n_loc),
                                  num_segments=n_loc + 1)[:n_loc]

        force = rep + att
        norm = jnp.sqrt(jnp.sum(force * force, 1) + 1e-12)
        step = jnp.minimum(norm, temp)
        return pos_blk + force / norm[:, None] * step[:, None]

    step = shard_map(
        local, mesh=mesh,
        in_specs=(P(VTX, None), P(VTX), P(VTX, None), P(VTX, None), P(VTX),
                  P(VTX), P(VTX), P(VTX), P(), P()),
        out_specs=P(VTX, None))
    shardings = dict(
        pos=NamedSharding(mesh, P(VTX, None)),
        w=NamedSharding(mesh, P(VTX)),
        nbr_idx=NamedSharding(mesh, P(VTX, None)),
        send=NamedSharding(mesh, P(VTX, None)),
        edge=NamedSharding(mesh, P(VTX)),
        scalar=NamedSharding(mesh, P()),
    )
    return step, shardings


def layout_halo_specs(mesh: Mesh, n_pad: int, m_pad: int, cap: int,
                      halo: int):
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    f32, i32 = jnp.float32, jnp.int32
    return dict(
        pos=jax.ShapeDtypeStruct((n_pad, 2), f32),
        w=jax.ShapeDtypeStruct((n_pad,), f32),
        nbr_local=jax.ShapeDtypeStruct((n_pad, cap), i32),
        send_idx=jax.ShapeDtypeStruct((vsize * vsize, halo), i32),
        src_local=jax.ShapeDtypeStruct((m_pad,), i32),
        dst_local=jax.ShapeDtypeStruct((m_pad,), i32),
        emask=jax.ShapeDtypeStruct((m_pad,), jnp.bool_),
        ewt=jax.ShapeDtypeStruct((m_pad,), f32),
        params=jax.ShapeDtypeStruct((3,), f32),
        temp=jax.ShapeDtypeStruct((), f32),
    )


def layout_step_specs(n_pad: int, m_pad: int, cap: int):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    f32, i32 = jnp.float32, jnp.int32
    return dict(
        pos=jax.ShapeDtypeStruct((n_pad, 2), f32),
        w=jax.ShapeDtypeStruct((n_pad,), f32),
        nbr_idx=jax.ShapeDtypeStruct((n_pad, cap), i32),
        src=jax.ShapeDtypeStruct((m_pad,), i32),
        dst_local=jax.ShapeDtypeStruct((m_pad,), i32),
        emask=jax.ShapeDtypeStruct((m_pad,), jnp.bool_),
        ewt=jax.ShapeDtypeStruct((m_pad,), f32),
        params=jax.ShapeDtypeStruct((3,), f32),
        temp=jax.ShapeDtypeStruct((), f32),
    )
