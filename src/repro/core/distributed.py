"""shard_map distribution of the layout supersteps over the production mesh.

Decomposition (DESIGN.md §4):
  * per-vertex state is sharded over the flattened vertex axes
    VTX = ("pod", "data") — or ("data",) on a single pod;
  * the all-pairs repulsion partner dimension is sharded over "model",
    giving a 2-D decomposition of the interaction matrix: device (v, m)
    computes rows of its vertex block against column chunk m, then psums
    partials over "model";
  * edge lists are pre-sorted by destination shard (Spinner order) so each
    device's segment-sum lands in its own vertex block; source positions
    come from an all_gather over VTX (8 bytes/vertex — the same per-round
    broadcast volume the paper's Giraph workers pay), or from a halo
    exchange of only the boundary vertices (optimized variant, §Perf);
  * the grid-bucketed repulsion (mode="grid", the fine levels of big
    hierarchies) bins each device's vertex block locally against the
    psum'd global bounding box, psums the per-cell mass/centroid/second-
    moment aggregates over the vertex axes (O(G²) floats — cheap),
    computes the far field from the replicated aggregates with the cell
    columns split over "model", and resolves the exact 3×3 near field
    either from an all_gather of the bucketed positions (baseline) or by
    exchanging only the boundary-cell buckets with the two neighboring
    shards (halo variant, DESIGN.md §4.3).

Every function here is pure SPMD and lowers on the 512-chip mesh; the
dry-run rows for the layout engine come from `layout_step_specs` below.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.utils.compat import shard_map


def vtx_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, names) -> int:
    s = 1
    for n in (names if isinstance(names, tuple) else (names,)):
        s *= mesh.shape[n]
    return s


# -- exact N-body, 2-D decomposed ---------------------------------------------

def sharded_nbody(mesh: Mesh, n_pad: int):
    """Returns a jitted f(pos[n_pad,2], w[n_pad]) → forces, 2-D decomposed."""
    VTX = vtx_axes(mesh)
    msize = mesh.shape["model"]

    def local(pos_blk, w_blk, params):
        C, L, md = params[0], params[1], params[2]
        pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)   # [n_pad, 2]
        w_all = jax.lax.all_gather(w_blk, VTX, tiled=True)       # [n_pad]
        chunk = n_pad // msize
        mi = jax.lax.axis_index("model")
        cpos = jax.lax.dynamic_slice_in_dim(pos_all, mi * chunk, chunk)
        cw = jax.lax.dynamic_slice_in_dim(w_all, mi * chunk, chunk)
        dx = pos_blk[:, 0][:, None] - cpos[:, 0][None, :]
        dy = pos_blk[:, 1][:, None] - cpos[:, 1][None, :]
        d2 = dx * dx + dy * dy + md * md
        inv = (C * L * L) * cw[None, :] / d2
        partial = jnp.stack([jnp.sum(dx * inv, 1), jnp.sum(dy * inv, 1)], 1)
        return jax.lax.psum(partial, "model")

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(VTX, None), P(VTX), P()),
                   out_specs=P(VTX, None))
    return jax.jit(fn)


# -- message superstep (attraction / merger push) ------------------------------

def sharded_attraction(mesh: Mesh, n_pad: int, m_pad: int):
    """f(pos, src, dst_local, emask, ewt, params) → attraction forces.

    Edge arrays are sharded over VTX with ``dst_local`` already offset into
    the local vertex block (host-side pre-partitioning by destination).
    """
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    n_loc = n_pad // vsize

    def local(pos_blk, src, dst_local, emask, ewt, params):
        C, L, md = params[0], params[1], params[2]
        pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)
        pos_all = jnp.concatenate([pos_all, jnp.zeros((1, 2), pos_all.dtype)], 0)
        ps = pos_all[src]                       # [m_loc, 2] remote reads
        pd = pos_blk[jnp.clip(dst_local, 0, n_loc - 1)]
        delta = ps - pd
        dist = jnp.sqrt(jnp.sum(delta * delta, 1) + md * md)
        ell = jnp.maximum(ewt, 1e-6) * L
        f = (dist * dist) / ell
        vec = jnp.where(emask[:, None], delta / dist[:, None] * f[:, None], 0.0)
        out = jax.ops.segment_sum(vec, jnp.clip(dst_local, 0, n_loc),
                                  num_segments=n_loc + 1)
        return out[:n_loc]

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(VTX, None), P(VTX), P(VTX), P(VTX), P(VTX), P()),
                   out_specs=P(VTX, None))
    return jax.jit(fn)


def sharded_push_max(mesh: Mesh, n_pad: int):
    """Distributed merger superstep: broadcast int values, max-combine."""
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    n_loc = n_pad // vsize

    def local(vals_blk, src, dst_local, emask):
        vals_all = jax.lax.all_gather(vals_blk, VTX, tiled=True)
        vals_all = jnp.concatenate([vals_all, jnp.full((1,), -1, vals_all.dtype)], 0)
        msgs = jnp.where(emask, vals_all[src], -1)
        out = jax.ops.segment_max(msgs, jnp.clip(dst_local, 0, n_loc),
                                  num_segments=n_loc + 1)
        return jnp.maximum(out[:n_loc], -1)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(VTX), P(VTX), P(VTX), P(VTX)),
                   out_specs=P(VTX))
    return jax.jit(fn)


# -- neighbor-list repulsion (fine levels) -------------------------------------

def sharded_neighbor_force(mesh: Mesh, n_pad: int, cap: int):
    """f(pos, w, nbr_idx[n_pad,cap]) — k-hop repulsion with remote gathers."""
    VTX = vtx_axes(mesh)

    def local(pos_blk, w_blk, nbr_idx, params):
        C, L, md = params[0], params[1], params[2]
        pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)
        w_all = jax.lax.all_gather(w_blk, VTX, tiled=True)
        pos_all = jnp.concatenate([pos_all, jnp.zeros((1, 2), pos_all.dtype)], 0)
        w_all = jnp.concatenate([w_all, jnp.zeros((1,), w_all.dtype)], 0)
        npos = pos_all[nbr_idx]                 # [n_loc, cap, 2]
        nw = w_all[nbr_idx]
        delta = pos_blk[:, None, :] - npos
        d2 = jnp.sum(delta * delta, -1) + md * md
        inv = (C * L * L) * nw / d2
        return jnp.sum(delta * inv[:, :, None], axis=1)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(VTX, None), P(VTX), P(VTX, None), P()),
                   out_specs=P(VTX, None))
    return jax.jit(fn)


# -- grid-bucketed repulsion, sharded (fine levels of big hierarchies) ---------

def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _chunk_for(n: int, target: int = 2048) -> int:
    """Largest divisor of ``n`` that is ≤ ``target`` (near-field row chunk)."""
    for c in range(min(n, target), 0, -1):
        if n % c == 0:
            return c
    return 1


def _grid_rep_spmd(pos_blk, w_blk, C, L, md, *, mesh: Mesh, n_pad: int,
                   grid_dim: int, cell_cap: int, variant: str, backend: str,
                   pos_all=None, w_all=None):
    """SPMD-local grid repulsion for one vertex block (call inside shard_map).

    ``w_blk`` is the vmask-zeroed vertex mass (w = 0 ⇔ padding). Matches the
    single-device ``grid_repulsion`` composition term for term:

      * global bounding box via pmin/pmax over the vertex axes (exact);
      * binning: the baseline all_gathers positions/weights (which the
        full superstep needs for attraction anyway) and reruns the
        single-device ``bin_vertices`` on the replicated arrays — cell
        ids, bucket table, and bucket membership are bit-identical to the
        single-device op at zero extra collectives; the halo variant bins
        its block locally and uses local stable ranks (the band contract
        guarantees a cell's vertices share a shard, so local = global);
      * per-cell raw sums (mass / weighted position / second moment, full
        and overflow-only) psum'd over the vertex axes: O(G²) floats;
      * far field = all-cells aggregate term with the cell columns split
        over "model" (psum), plus the replicated correction terms
        (`kernels.grid_force.ops.far_corrections`);
      * near field = exact 3×3-neighborhood pairs for bucketed vertices,
        evaluated per local vertex in row chunks with the 9·cap partner
        columns split over "model". Partner buckets come from the
        replicated bucket table (variant="allgather") or from the
        band-local bucket table extended by the two ppermute'd boundary
        rows (variant="halo").

    The halo variant assumes the band contract (DESIGN.md §4.3): device d's
    vertices lie in grid rows [d·G/vsize, (d+1)·G/vsize). A vertex that
    violates it is reclassified as bucket overflow: it keeps the exact far
    field, its neighbors keep a softened aggregate view of its mass, and
    only its own near field degrades to the softened in-bucket aggregates
    — graceful degradation, never a blow-up or dropped mass.
    """
    from repro.kernels.grid_force import ops as gops

    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    msize = mesh.shape["model"]
    G, cap = grid_dim, cell_cap
    nc = G * G
    n_loc = pos_blk.shape[0]
    pos_blk = pos_blk.astype(jnp.float32)
    w_blk = w_blk.astype(jnp.float32)
    vmask_blk = w_blk > 0
    mi = jax.lax.axis_index("model")
    di = jnp.int32(0)                    # flattened device index over VTX
    for a in VTX:
        di = di * mesh.shape[a] + jax.lax.axis_index(a)

    # -- bin against the global bounding box ----------------------------------
    big = jnp.float32(3e38)
    lo = jax.lax.pmin(
        jnp.min(jnp.where(vmask_blk[:, None], pos_blk, big), axis=0), VTX)
    hi = jax.lax.pmax(
        jnp.max(jnp.where(vmask_blk[:, None], pos_blk, -big), axis=0), VTX)
    cell = jnp.maximum(hi - lo, 1e-6) / G
    bucket = None
    if variant == "halo":
        # local binning + local stable ranks (band contract: a cell's
        # vertices all share this shard, so local ranks are global ranks)
        ij = jnp.clip(jnp.floor((pos_blk - lo) / cell), 0,
                      G - 1).astype(jnp.int32)
        cid = jnp.where(vmask_blk, ij[:, 1] * G + ij[:, 0],
                        nc).astype(jnp.int32)
        order = jnp.argsort(cid)         # stable → ascending index in cell
        sc = cid[order]
        grank = jnp.zeros((n_loc,), jnp.int32).at[order].set(
            (jnp.arange(n_loc) - jnp.searchsorted(sc, sc, side="left"))
            .astype(jnp.int32))
        Gb = G // vsize
        nc_band = Gb * G
        lc = cid - di * nc_band          # band-local cell index
        band_ok = (lc >= 0) & (lc < nc_band) & (cid < nc)
        # a band-contract violator counts as bucket OVERFLOW, not in-bucket:
        # it enters the psum'd overflow aggregates, so its neighbors keep a
        # softened view of its mass and it keeps the exact far field — only
        # its own near field degrades (the documented contract)
        inb = (grank < cap) & band_ok
    else:
        # replicated global binning on the all_gathered arrays (the full
        # superstep gathers positions for attraction anyway): cell ids,
        # bucket table and bucket membership are bit-identical to the
        # single-device op, at zero extra collectives
        if pos_all is None:
            pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)
            w_all = jax.lax.all_gather(w_blk, VTX, tiled=True)
        pos_all = pos_all.astype(jnp.float32)
        w_all = w_all.astype(jnp.float32)
        cid_all, bucket, inb_all = gops.bin_vertices(pos_all, w_all > 0,
                                                     G, cap)
        cid = jax.lax.dynamic_slice_in_dim(cid_all, di * n_loc, n_loc)
        inb = jax.lax.dynamic_slice_in_dim(inb_all, di * n_loc, n_loc)

    # -- per-cell raw sums, psum'd over the vertex axes (O(G²) floats) --------
    # second moments about the cell centers, matching cell_centers()'s
    # conditioning argument (kernels/grid_force/ops.py)
    centers = gops.cell_centers_from_box(lo, hi, G)
    q = jnp.sum((pos_blk - centers[cid]) ** 2, axis=1)
    w_out = jnp.where(inb, 0.0, w_blk)

    def sums(wv):
        M = jax.ops.segment_sum(wv, cid, num_segments=nc + 1)
        S = jax.ops.segment_sum(wv[:, None] * pos_blk, cid,
                                num_segments=nc + 1)
        Q = jax.ops.segment_sum(wv * q, cid, num_segments=nc + 1)
        return M, S, Q
    M_full, S_full, Q_full, M_out, S_out, Q_out = jax.lax.psum(
        sums(w_blk) + sums(w_out), VTX)

    # -- far field: all-cells term (cell columns split over "model") ----------
    mu_full = S_full / jnp.maximum(M_full, 1e-12)[:, None]
    cell_xyw = jnp.concatenate([mu_full[:nc], M_full[:nc, None]], axis=1)
    ncp = _round_up(nc, msize)
    cells_p = jnp.pad(cell_xyw, ((0, ncp - nc), (0, 0)))     # pad mass = 0
    cells_m = jax.lax.dynamic_slice_in_dim(cells_p, mi * (ncp // msize),
                                           ncp // msize)
    rep = jax.lax.psum(
        gops.far_all_cells(pos_blk, cells_m, C, L, md, backend), "model")
    rep += gops.far_corrections(pos_blk, w_out, cid, inb,
                                M_full, S_full, Q_full, M_out, S_out, Q_out,
                                C, L, md, grid_dim=G, centers=centers)

    # -- near field: exact 3×3 pairs, chunked rows × "model"-split columns ----
    K = 9 * cap
    Kp = _round_up(K, msize)
    Kc = Kp // msize
    ch = _chunk_for(n_loc)
    if variant == "halo":
        okb = inb                        # already implies band_ok
        xyw = jnp.concatenate([pos_blk, w_blk[:, None]], axis=1)
        tbl = jnp.zeros((nc_band + 1, cap, 3), jnp.float32).at[
            jnp.where(okb, lc, nc_band), jnp.where(okb, grank, 0)].set(
            jnp.where(okb[:, None], xyw, 0.0))
        band = tbl[:nc_band].reshape(Gb, G, cap, 3)
        # boundary-bucket exchange: first/last grid row to the two neighbors
        # (2·G·cap·3 floats vs the baseline's n_pad·3-float all_gather);
        # devices with no peer receive zeros = empty buckets, which is
        # exactly right for rows beyond the grid.
        fwd = [(d, d + 1) for d in range(vsize - 1)]
        bwd = [(d + 1, d) for d in range(vsize - 1)]
        halo_top = jax.lax.ppermute(band[-1], VTX, fwd)      # d-1's last row
        halo_bot = jax.lax.ppermute(band[0], VTX, bwd)       # d+1's first row
        ext = jnp.concatenate([halo_top[None], band, halo_bot[None]], axis=0)
        sent = (Gb + 2) * G                                  # empty sentinel
        ext = jnp.concatenate([ext.reshape(sent * cap, 3),
                               jnp.zeros((cap, 3), jnp.float32)], axis=0)
        ext = ext.reshape(sent + 1, cap, 3)
        cx, cy = cid % G, cid // G
        ey = cy - di * Gb + 1                                # extended row
        cols = []
        for oy in (-1, 0, 1):
            for ox in (-1, 0, 1):
                nx, ny = cx + ox, cy + oy
                valid = band_ok & (nx >= 0) & (nx < G) & (ny >= 0) & (ny < G)
                cols.append(jnp.where(valid, (ey + oy) * G + nx, sent))
        near9 = jnp.stack(cols, axis=1).astype(jnp.int32)    # [n_loc, 9]
        near_mask = inb

        def near_chunk(args):
            pos_c, n9_c = args
            nbr = ext[n9_c].reshape(-1, K, 3)
            nbr = jnp.pad(nbr, ((0, 0), (0, Kp - K), (0, 0)))
            nbr = jax.lax.dynamic_slice_in_dim(nbr, mi * Kc, Kc, axis=1)
            return gops.near_field(pos_c[:, None, :], nbr[..., :2],
                                   nbr[..., 2], C, L, md,
                                   backend=backend)[:, 0]
    else:
        pos_p = jnp.concatenate(
            [pos_all, jnp.zeros((1, 2), jnp.float32)], 0)
        w_p = jnp.concatenate(
            [w_all, jnp.zeros((1,), jnp.float32)], 0)
        table = jnp.asarray(gops.neighbor_table(G))
        near9 = table[cid]                                   # [n_loc, 9]
        near_mask = inb

        def near_chunk(args):
            pos_c, n9_c = args
            idx = bucket[n9_c].reshape(-1, K)
            idx = jnp.pad(idx, ((0, 0), (0, Kp - K)), constant_values=n_pad)
            idx = jax.lax.dynamic_slice_in_dim(idx, mi * Kc, Kc, axis=1)
            return gops.near_field(pos_c[:, None, :], pos_p[idx], w_p[idx],
                                   C, L, md, backend=backend)[:, 0]

    f_near = jax.lax.map(near_chunk,
                         (pos_blk.reshape(n_loc // ch, ch, 2),
                          near9.reshape(n_loc // ch, ch, 9)))
    f_near = jax.lax.psum(f_near.reshape(n_loc, 2), "model")
    rep += jnp.where(near_mask[:, None], f_near, 0.0)
    return jnp.where(vmask_blk[:, None], rep, 0.0)


def sharded_grid_force(mesh: Mesh, n_pad: int, grid_dim: int, cell_cap: int,
                       variant: str = "allgather",
                       backend: str | None = None):
    """Returns a jitted f(pos[n_pad, 2], w[n_pad], params[3]) → forces.

    ``params = [C, L, min_dist]``; ``w`` is the vmask-zeroed vertex mass.
    Matches the single-device ``grid_repulsion`` (same grid_dim/cell_cap)
    to float tolerance; see ``_grid_rep_spmd`` for the decomposition and
    kernels/grid_force/README.md for when variant="halo" beats the
    all_gather baseline.
    """
    assert variant in ("allgather", "halo"), variant
    assert grid_dim >= 2 and cell_cap >= 1, (grid_dim, cell_cap)
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    assert n_pad % vsize == 0, (n_pad, vsize)
    if variant == "halo":
        assert grid_dim % vsize == 0, (grid_dim, vsize)
    if backend is None:
        from repro.kernels.grid_force.ops import backend_mode
        backend = backend_mode()

    def local(pos_blk, w_blk, params):
        C, L, md = params[0], params[1], params[2]
        return _grid_rep_spmd(pos_blk, w_blk, C, L, md, mesh=mesh,
                              n_pad=n_pad, grid_dim=grid_dim,
                              cell_cap=cell_cap, variant=variant,
                              backend=backend)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(VTX, None), P(VTX), P()),
                   out_specs=P(VTX, None))
    return jax.jit(fn)


# -- full distributed layout step (used by the dry-run) ------------------------

def layout_train_step(mesh: Mesh, n_pad: int, m_pad: int, cap: int,
                      mode: str = "neighbor", grid_dim: int = 0,
                      cell_cap: int = 0, engine: str = "gila"):
    """One full distributed refinement iteration for ``engine``.

    ``mode`` is "exact" | "neighbor" | "grid" (the same selection
    core/schedule.py makes by level size). Grid mode needs the static
    ``grid_dim``/``cell_cap`` from ``kernels.grid_force.choose_grid`` and
    ignores ``nbr_idx`` (pass cap = 1 dummies, see ``layout_step_specs``).

    ``engine="gila"`` is the FR superstep (repulsion + attraction +
    temp-clamped displacement). ``engine="stress"`` is the maxent-stress
    Jacobi superstep (core/stress.py): the per-vertex numerator/denominator
    segment-sums run over this shard's destination block (the same
    Spinner-order edge partition the attraction uses), the entropy repulsion
    reuses the mode branches with C scaled by the traced ``alpha``, and the
    step takes one extra replicated scalar ``alpha`` after ``temp``.

    Returns (step_fn, input_shardings) suitable for
    jax.jit(step_fn, in_shardings=...).lower(*specs).
    """
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    n_loc = n_pad // vsize
    msize = mesh.shape["model"]
    if mode == "grid":
        assert grid_dim >= 2 and cell_cap >= 1, (grid_dim, cell_cap)
        from repro.kernels.grid_force.ops import backend_mode
        grid_backend = backend_mode()

    def repulsion(pos_blk, w_blk, nbr_idx, pos_all, w_all, pos_pad, w_pad,
                  C, L, md):
        if mode == "exact":
            chunk = n_pad // msize
            mi = jax.lax.axis_index("model")
            cpos = jax.lax.dynamic_slice_in_dim(pos_all, mi * chunk, chunk)
            cw = jax.lax.dynamic_slice_in_dim(w_all, mi * chunk, chunk)
            dx = pos_blk[:, 0][:, None] - cpos[:, 0][None, :]
            dy = pos_blk[:, 1][:, None] - cpos[:, 1][None, :]
            d2 = dx * dx + dy * dy + md * md
            inv = (C * L * L) * cw[None, :] / d2
            return jax.lax.psum(
                jnp.stack([jnp.sum(dx * inv, 1), jnp.sum(dy * inv, 1)], 1),
                "model")
        if mode == "grid":
            return _grid_rep_spmd(pos_blk, w_blk, C, L, md, mesh=mesh,
                                  n_pad=n_pad, grid_dim=grid_dim,
                                  cell_cap=cell_cap, variant="allgather",
                                  backend=grid_backend,
                                  pos_all=pos_all, w_all=w_all)
        # split the neighbor cap over the model axis → 2-D decomposition
        ccap = cap // msize
        mi = jax.lax.axis_index("model")
        nidx = jax.lax.dynamic_slice_in_dim(nbr_idx, mi * ccap, ccap, axis=1)
        npos = pos_pad[nidx]
        nw = w_pad[nidx]
        delta = pos_blk[:, None, :] - npos
        d2 = jnp.sum(delta * delta, -1) + md * md
        inv = (C * L * L) * nw / d2
        return jax.lax.psum(jnp.sum(delta * inv[:, :, None], axis=1), "model")

    def local(pos_blk, w_blk, nbr_idx, src, dst_local, emask, ewt, params, temp):
        C, L, md = params[0], params[1], params[2]
        pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)
        w_all = jax.lax.all_gather(w_blk, VTX, tiled=True)
        pos_pad = jnp.concatenate([pos_all, jnp.zeros((1, 2), pos_all.dtype)], 0)
        w_pad = jnp.concatenate([w_all, jnp.zeros((1,), w_all.dtype)], 0)

        rep = repulsion(pos_blk, w_blk, nbr_idx, pos_all, w_all, pos_pad,
                        w_pad, C, L, md)

        ps = pos_pad[src]
        pd = pos_blk[jnp.clip(dst_local, 0, n_loc - 1)]
        delta = ps - pd
        dist = jnp.sqrt(jnp.sum(delta * delta, 1) + md * md)
        f = (dist * dist) / (jnp.maximum(ewt, 1e-6) * L)
        vec = jnp.where(emask[:, None], delta / dist[:, None] * f[:, None], 0.0)
        att = jax.ops.segment_sum(vec, jnp.clip(dst_local, 0, n_loc),
                                  num_segments=n_loc + 1)[:n_loc]

        force = rep + att
        norm = jnp.sqrt(jnp.sum(force * force, 1) + 1e-12)
        step = jnp.minimum(norm, temp)
        return pos_blk + force / norm[:, None] * step[:, None]

    def local_stress(pos_blk, w_blk, nbr_idx, src, dst_local, emask, ewt,
                     params, temp, alpha):
        C, L, md = params[0], params[1], params[2]
        pos_all = jax.lax.all_gather(pos_blk, VTX, tiled=True)
        w_all = jax.lax.all_gather(w_blk, VTX, tiled=True)
        pos_pad = jnp.concatenate([pos_all, jnp.zeros((1, 2), pos_all.dtype)], 0)
        w_pad = jnp.concatenate([w_all, jnp.zeros((1,), w_all.dtype)], 0)

        # entropy term: the FR repulsion field with C annealed by alpha
        rep = repulsion(pos_blk, w_blk, nbr_idx, pos_all, w_all, pos_pad,
                        w_pad, alpha * C, L, md)

        # weighted-Jacobi stress term over this shard's destination block
        ell = jnp.maximum(ewt, 1e-6) * L
        we = jnp.where(emask, 1.0 / (ell * ell), 0.0)
        ps = pos_pad[src]
        pd = pos_blk[jnp.clip(dst_local, 0, n_loc - 1)]
        delta = pd - ps
        dist = jnp.sqrt(jnp.sum(delta * delta, 1) + md * md)
        tgt = ps + delta / dist[:, None] * ell[:, None]
        vec = jnp.where(emask[:, None], we[:, None] * tgt, 0.0)
        seg = jnp.clip(dst_local, 0, n_loc)
        num = jax.ops.segment_sum(vec, seg, num_segments=n_loc + 1)[:n_loc]
        rho = jax.ops.segment_sum(we, seg, num_segments=n_loc + 1)[:n_loc]

        new = (num + rep) / jnp.maximum(rho, 1e-12)[:, None]
        new = jnp.where(rho[:, None] > 0, new, pos_blk)
        d = new - pos_blk
        norm = jnp.sqrt(jnp.sum(d * d, 1) + 1e-12)
        step = jnp.minimum(norm, temp)
        return pos_blk + d / norm[:, None] * step[:, None]

    if engine == "stress":
        step = shard_map(
            local_stress, mesh=mesh,
            in_specs=(P(VTX, None), P(VTX), P(VTX, None), P(VTX), P(VTX),
                      P(VTX), P(VTX), P(), P(), P()),
            out_specs=P(VTX, None))
    else:
        step = shard_map(
            local, mesh=mesh,
            in_specs=(P(VTX, None), P(VTX), P(VTX, None), P(VTX), P(VTX),
                      P(VTX), P(VTX), P(), P()),
            out_specs=P(VTX, None))
    shardings = dict(
        pos=NamedSharding(mesh, P(VTX, None)),
        w=NamedSharding(mesh, P(VTX)),
        nbr_idx=NamedSharding(mesh, P(VTX, None)),
        edge=NamedSharding(mesh, P(VTX)),
        scalar=NamedSharding(mesh, P()),
    )
    return step, shardings


def layout_train_step_halo(mesh: Mesh, n_pad: int, m_pad: int, cap: int,
                           halo: int, mode: str = "neighbor",
                           grid_dim: int = 0, cell_cap: int = 0):
    """GiLA iteration with HALO EXCHANGE instead of the position all-gather
    (§Perf hillclimb C — the paper's Spinner-locality insight made explicit).

    With a Spinner partition, almost all k-hop neighbors are shard-local;
    each device needs only the boundary ("halo") positions of its peers.
    Host-side preprocessing produces, per device, ``send_idx[P, halo]``
    (local vertices each peer needs; sentinel-padded) and neighbor lists
    remapped into [local | halo-slot | sentinel] coordinates. Communication
    per superstep drops from all-gather(n·12B) to all_to_all(P·halo·12B).

    ``mode="grid"`` replaces the neighbor-list repulsion with the sharded
    grid repulsion in its halo variant (boundary-cell bucket ppermute,
    ``nbr_local`` ignored — pass cap = 1 dummies). The attraction keeps
    this step's halo machinery, so no superstep all-gathers positions;
    requires the band contract of ``_grid_rep_spmd``.
    """
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    n_loc = n_pad // vsize
    if mode == "grid":
        assert grid_dim >= 2 and cell_cap >= 1, (grid_dim, cell_cap)
        assert grid_dim % vsize == 0, (grid_dim, vsize)
        from repro.kernels.grid_force.ops import backend_mode
        grid_backend = backend_mode()

    def local(pos_blk, w_blk, nbr_local, send_idx, src_local, dst_local,
              emask, ewt, params, temp):
        C, L, md = params[0], params[1], params[2]
        P_ = send_idx.shape[0]
        table = jnp.concatenate(
            [pos_blk, jnp.zeros((1, 2), pos_blk.dtype)], 0)
        wtab = jnp.concatenate([w_blk, jnp.zeros((1,), w_blk.dtype)], 0)
        sidx = jnp.clip(send_idx, 0, n_loc)
        send = jnp.concatenate(
            [table[sidx], wtab[sidx][..., None]], axis=-1)     # [P, halo, 3]
        # hierarchical personalized all-to-all over the vertex axes:
        # peers laid out [pod, data]; exchange the data stage, then pod.
        shape = tuple(mesh.shape[a] for a in VTX)
        recv = send.reshape(shape + send.shape[1:])
        for d, ax in enumerate(VTX):
            recv = jax.lax.all_to_all(recv, ax, split_axis=d, concat_axis=d)
        recv = recv.reshape(P_, -1, 3)

        halo_pos = recv[..., :2].reshape(-1, 2)
        halo_w = recv[..., 2].reshape(-1)
        full_pos = jnp.concatenate(
            [pos_blk, halo_pos, jnp.zeros((1, 2), pos_blk.dtype)], 0)
        full_w = jnp.concatenate([w_blk, halo_w,
                                  jnp.zeros((1,), w_blk.dtype)], 0)

        if mode == "grid":
            rep = _grid_rep_spmd(pos_blk, w_blk, C, L, md, mesh=mesh,
                                 n_pad=n_pad, grid_dim=grid_dim,
                                 cell_cap=cell_cap, variant="halo",
                                 backend=grid_backend)
        else:
            npos = full_pos[nbr_local]              # [n_loc, cap, 2]
            nw = full_w[nbr_local]
            delta = pos_blk[:, None, :] - npos
            d2 = jnp.sum(delta * delta, -1) + md * md
            inv = (C * L * L) * nw / d2
            rep = jnp.sum(delta * inv[:, :, None], axis=1)

        ps = full_pos[src_local]
        pd = pos_blk[jnp.clip(dst_local, 0, n_loc - 1)]
        delta = ps - pd
        dist = jnp.sqrt(jnp.sum(delta * delta, 1) + md * md)
        f = (dist * dist) / (jnp.maximum(ewt, 1e-6) * L)
        vec = jnp.where(emask[:, None], delta / dist[:, None] * f[:, None], 0.0)
        att = jax.ops.segment_sum(vec, jnp.clip(dst_local, 0, n_loc),
                                  num_segments=n_loc + 1)[:n_loc]

        force = rep + att
        norm = jnp.sqrt(jnp.sum(force * force, 1) + 1e-12)
        step = jnp.minimum(norm, temp)
        return pos_blk + force / norm[:, None] * step[:, None]

    step = shard_map(
        local, mesh=mesh,
        in_specs=(P(VTX, None), P(VTX), P(VTX, None), P(VTX, None), P(VTX),
                  P(VTX), P(VTX), P(VTX), P(), P()),
        out_specs=P(VTX, None))
    shardings = dict(
        pos=NamedSharding(mesh, P(VTX, None)),
        w=NamedSharding(mesh, P(VTX)),
        nbr_idx=NamedSharding(mesh, P(VTX, None)),
        send=NamedSharding(mesh, P(VTX, None)),
        edge=NamedSharding(mesh, P(VTX)),
        scalar=NamedSharding(mesh, P()),
    )
    return step, shardings


def layout_halo_specs(mesh: Mesh, n_pad: int, m_pad: int, cap: int,
                      halo: int, mode: str = "neighbor"):
    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    if mode == "grid":
        cap = 1                          # nbr_local unused in grid mode
    f32, i32 = jnp.float32, jnp.int32
    return dict(
        pos=jax.ShapeDtypeStruct((n_pad, 2), f32),
        w=jax.ShapeDtypeStruct((n_pad,), f32),
        nbr_local=jax.ShapeDtypeStruct((n_pad, cap), i32),
        send_idx=jax.ShapeDtypeStruct((vsize * vsize, halo), i32),
        src_local=jax.ShapeDtypeStruct((m_pad,), i32),
        dst_local=jax.ShapeDtypeStruct((m_pad,), i32),
        emask=jax.ShapeDtypeStruct((m_pad,), jnp.bool_),
        ewt=jax.ShapeDtypeStruct((m_pad,), f32),
        params=jax.ShapeDtypeStruct((3,), f32),
        temp=jax.ShapeDtypeStruct((), f32),
    )


def layout_step_specs(n_pad: int, m_pad: int, cap: int,
                      mode: str = "neighbor", engine: str = "gila"):
    """ShapeDtypeStructs for the dry-run (no allocation). In grid mode the
    neighbor lists are unused; cap collapses to a 1-wide dummy. The stress
    engine's step takes one extra replicated annealing scalar ``alpha``."""
    if mode == "grid":
        cap = 1
    f32, i32 = jnp.float32, jnp.int32
    specs = dict(
        pos=jax.ShapeDtypeStruct((n_pad, 2), f32),
        w=jax.ShapeDtypeStruct((n_pad,), f32),
        nbr_idx=jax.ShapeDtypeStruct((n_pad, cap), i32),
        src=jax.ShapeDtypeStruct((m_pad,), i32),
        dst_local=jax.ShapeDtypeStruct((m_pad,), i32),
        emask=jax.ShapeDtypeStruct((m_pad,), jnp.bool_),
        ewt=jax.ShapeDtypeStruct((m_pad,), f32),
        params=jax.ShapeDtypeStruct((3,), f32),
        temp=jax.ShapeDtypeStruct((), f32),
    )
    if engine == "stress":
        specs["alpha"] = jax.ShapeDtypeStruct((), f32)
    return specs


# -- host-side level driver (engine="multigila_dist" in core/multilevel.py) ----

def partition_edges(src, dst, emask, ewt, n_pad: int, vsize: int,
                    bucket: bool = False):
    """Host-side Spinner-order edge partition: group edges by the device
    block that owns their destination, pad every block to the max block
    length, and offset destinations into block-local coordinates.

    Returns (src[m_pad2], dst_local[m_pad2], emask[m_pad2], ewt[m_pad2],
    m_pad2) laid out so ``P(VTX)`` sharding puts each device exactly its
    own destination block (padding edges: src = n_pad sentinel, mask off).

    ``bucket=True`` rounds the per-device block length up to the next pow2
    bucket: the block length is otherwise data-dependent (max in-degree
    load), which would defeat the compiled-step cache keyed on m_pad
    (core/bucketing.py).
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    emask = np.asarray(emask)
    ewt = np.asarray(ewt)
    n_loc = n_pad // vsize
    src, dst, ewt = src[emask], dst[emask], ewt[emask]
    owner = dst // n_loc
    m_loc = max(int(np.bincount(owner, minlength=vsize).max()), 1)
    if bucket:
        from repro.graphs.graph import bucket_pad
        m_loc = bucket_pad(m_loc, minimum=64)
    S = np.full((vsize, m_loc), n_pad, np.int32)
    DL = np.zeros((vsize, m_loc), np.int32)
    EM = np.zeros((vsize, m_loc), bool)
    EW = np.ones((vsize, m_loc), np.float32)
    for d in range(vsize):
        sel = owner == d
        k = int(sel.sum())
        S[d, :k] = src[sel]
        DL[d, :k] = dst[sel] - d * n_loc
        EM[d, :k] = True
        EW[d, :k] = ewt[sel]
    return (S.reshape(-1), DL.reshape(-1), EM.reshape(-1), EW.reshape(-1),
            vsize * m_loc)


def _mesh_cache_key(mesh: Mesh) -> tuple:
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


def cached_layout_step(mesh: Mesh, n_pad: int, m_pad: int, cap: int, *,
                       mode: str, grid_dim: int = 0, cell_cap: int = 0,
                       engine: str = "gila"):
    """Process-wide cached (jitted step, shardings) for one shape bucket.

    ``layout_train_step`` returns a FRESH shard_map + jit wrapper per call,
    so calling it per level recompiles even for identical shapes; keying on
    (mesh, bucket shapes, mode statics) makes the whole hierarchy — and
    every later same-bucket graph — reuse one compiled program. The
    position argument is donated (no per-iteration copy on accelerators).

    Returns (jitted_step, shardings, fresh).
    """
    from repro.core import bucketing

    key = ("dist_step", engine, _mesh_cache_key(mesh), n_pad, m_pad, cap,
           mode, grid_dim, cell_cap, bucketing.kernel_backend())

    def build():
        step, sh = layout_train_step(mesh, n_pad, m_pad, cap, mode=mode,
                                     grid_dim=grid_dim, cell_cap=cell_cap,
                                     engine=engine)
        jitted = jax.jit(
            step, donate_argnums=bucketing.donate_argnums_if_supported(0))
        return jitted, sh

    (jitted, sh), fresh = bucketing.STEP_CACHE.get(key, build)
    return jitted, sh, fresh


def run_layout_level(mesh: Mesh, g, pos0, sched, *, ideal_len: float,
                     rep_const: float, min_dist: float = 1e-3,
                     seed: int = 0, bucket: bool = True) -> np.ndarray:
    """Lay out ONE hierarchy level with the distributed superstep.

    Host-side wrapper around ``layout_train_step``: re-pads the level to
    mesh-divisible sizes, partitions edges by destination shard, builds
    k-hop lists for mode="neighbor" (global indices — the step gathers
    from the replicated position table), and runs ``sched.iters`` cooling
    iterations. Returns positions [g.n_pad, 2] (numpy, padding zeroed),
    so it is a drop-in for ``gila.gila_layout`` in the multilevel driver.

    With ``bucket=True`` (the driver default) the step function comes from
    the process-wide compile cache and the edge partition is padded to a
    pow2 block bucket, so same-bucket levels share one compiled program.
    """
    import time

    from repro.core import gila
    from repro.core.bucketing import PHASES
    from repro.graphs.graph import unique_edges

    VTX = vtx_axes(mesh)
    vsize = _axis_size(mesh, VTX)
    msize = mesh.shape["model"]
    n_pad = _round_up(g.n_pad, vsize * msize)

    pos = np.zeros((n_pad, 2), np.float32)
    pos[:g.n_pad] = np.asarray(pos0, np.float32)[:g.n_pad]
    w = np.zeros((n_pad,), np.float32)
    w[:g.n_pad] = np.where(np.asarray(g.vmask), np.asarray(g.mass),
                           0.0).astype(np.float32)
    pos[w == 0] = 0.0

    src_e, dst_local, emask, ewt, m_pad = partition_edges(
        np.asarray(g.src), np.asarray(g.dst), np.asarray(g.emask),
        np.asarray(g.ewt), n_pad, vsize, bucket=bucket)

    if sched.mode == "neighbor":
        cap = _round_up(sched.cap, msize)
        idx, mask = gila.khop_neighbors(unique_edges(g), g.n, sched.k, cap,
                                        seed)
        nbr = np.full((n_pad, cap), n_pad, np.int32)
        nbr[:g.n] = np.where(mask, idx, n_pad)
    else:
        cap = 1
        nbr = np.full((n_pad, 1), n_pad, np.int32)

    engine = getattr(sched, "engine", "gila")
    jitted, sh, fresh = cached_layout_step(mesh, n_pad, m_pad, cap,
                                           mode=sched.mode,
                                           grid_dim=sched.grid_dim,
                                           cell_cap=sched.cell_cap,
                                           engine=engine)
    from repro.utils.transfer import io_boundary

    if engine == "stress":
        from repro.core.stress import alpha_schedule
        alpha, alpha_decay = alpha_schedule(sched.iters)
    else:
        alpha, alpha_decay = None, 1.0

    dput = jax.device_put
    with io_boundary():                     # ingest: host partition → mesh
        pos_d = dput(jnp.asarray(pos), sh["pos"])
        w_d = dput(jnp.asarray(w), sh["w"])
        nbr_d = dput(jnp.asarray(nbr), sh["nbr_idx"])
        src_d = dput(jnp.asarray(src_e), sh["edge"])
        dst_d = dput(jnp.asarray(dst_local), sh["edge"])
        em_d = dput(jnp.asarray(emask), sh["edge"])
        ew_d = dput(jnp.asarray(ewt), sh["edge"])
        params = dput(
            jnp.asarray([rep_const, ideal_len, min_dist], jnp.float32),
            sh["scalar"])
    temp = sched.temp0
    t0 = time.perf_counter()
    for it in range(sched.iters):
        with io_boundary():                 # staging: annealing scalars
            temp_d = dput(jnp.asarray(temp, jnp.float32), sh["scalar"])
            if alpha is not None:
                al_d = dput(jnp.asarray(alpha, jnp.float32), sh["scalar"])
        if alpha is not None:
            pos_d = jitted(pos_d, w_d, nbr_d, src_d, dst_d, em_d, ew_d,
                           params, temp_d, al_d)
        else:
            pos_d = jitted(pos_d, w_d, nbr_d, src_d, dst_d, em_d, ew_d,
                           params, temp_d)
        if it == 0 and fresh:               # first call traces + compiles
            pos_d.block_until_ready()
            PHASES.add("compile", time.perf_counter() - t0)
            t0 = time.perf_counter()
        temp *= sched.temp_decay
        if alpha is not None:
            alpha *= alpha_decay
    pos_d.block_until_ready()
    PHASES.add("refine", time.perf_counter() - t0)
    with io_boundary():                     # egress: gather to host
        out = np.asarray(pos_d)[:g.n_pad]
    return np.where(w[:g.n_pad, None] > 0, out, 0.0).astype(np.float32)
