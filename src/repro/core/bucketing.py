"""Shape buckets + process-wide compile cache for the multilevel driver.

The paper's headline number is END-TO-END wall clock (10M edges in ~60
minutes on commodity cloud machines), and at that scale the coarsen →
place → refine *driver* — not the force kernel — dominates time-to-layout.
Before this module, every hierarchy level paid a fresh XLA compile: each
level has a distinct (n, m), ``PaddedGraph`` carries them as static pytree
fields, and ``gila_layout`` additionally bakes the iteration count into the
trace. A 10-level hierarchy compiled ten programs; the next graph compiled
ten more.

The fix has three parts (DESIGN.md §8):

  1. *Pow2 shape buckets* — every level's ``PaddedGraph`` is padded (vertex
     and edge axes independently) to the next power-of-two bucket
     (``graphs.graph.bucket_pad``), so all levels of all hierarchies share
     O(log n_max) distinct shapes. Randomness is per-vertex
     (``utils/prng.py``), so re-padding is behavior-preserving.
  2. *Process-wide compile cache* — the per-level refinement runs through
     one cached jitted step per key ``(bucket_n, bucket_e, cap, mode,
     grid_dim, cell_cap)`` (plus the mesh for the dist engine). The static
     ``n``/``m`` fields are normalized away before tracing
     (``shape_normalized``), iteration count / temperature / cooling are
     traced scalars, and the schedule picks grid_dim/cell_cap from the
     bucket — so a fresh graph whose levels land in warm buckets triggers
     ZERO new compiles (asserted in tests/test_bucketing.py).
  3. *Buffer donation* — the position buffer is donated through the
     refinement loop (no copy per level / per distributed iteration on
     accelerators; donation is skipped on CPU where XLA does not implement
     it and only warns).

``PHASES`` collects the per-phase wall clock (coarsen / place / refine /
compile) that benchmarks/pipeline_bench.py reports.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs.graph import PaddedGraph, bucket_pad
from repro.core import gila


def shape_normalized(g: PaddedGraph) -> PaddedGraph:
    """Zero the static n/m fields: jitted consumers that never read them
    then cache on the padded shapes alone (one trace per shape bucket)."""
    return dataclasses.replace(g, n=0, m=0)


def donate_argnums_if_supported(*argnums: int) -> tuple:
    """Buffer donation is a no-op (plus a warning per call) on CPU."""
    return argnums if jax.default_backend() != "cpu" else ()


# -- per-phase wall-clock accounting ------------------------------------------

class PhaseTimes:
    """Accumulates wall-clock per pipeline phase (coarsen/place/refine/
    compile). ``compile`` is the first call into a cold cache entry — trace
    + XLA compile + the first execution (inseparable under jit dispatch);
    merger-superstep compiles land in ``coarsen`` the same way."""

    def __init__(self):
        self.t: dict[str, float] = {}

    def add(self, name: str, seconds: float) -> None:
        self.t[name] = self.t.get(name, 0.0) + seconds

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)

    def snapshot(self) -> dict:
        return dict(self.t)

    def reset(self) -> None:
        self.t.clear()


PHASES = PhaseTimes()


# -- the compile cache ---------------------------------------------------------

class CompileCache:
    """Process-wide cache of jitted step functions keyed on shape buckets.

    ``get(key, builder)`` returns ``(fn, fresh)``; ``fresh=True`` means the
    builder ran (the next call of ``fn`` traces and XLA-compiles)."""

    def __init__(self):
        self.entries: dict = {}
        self.hits = 0
        self.misses = 0

    def get(self, key, builder):
        fn = self.entries.get(key)
        if fn is not None:
            self.hits += 1
            return fn, False
        self.misses += 1
        fn = builder()
        self.entries[key] = fn
        return fn, True

    def clear(self) -> None:
        self.entries.clear()
        self.hits = 0
        self.misses = 0


STEP_CACHE = CompileCache()


def cache_stats() -> dict:
    """Introspection for tests/benchmarks: entries/hits/misses of the step
    cache plus the total jit-trace entry count of every tracked function."""
    return dict(entries=len(STEP_CACHE.entries), hits=STEP_CACHE.hits,
                misses=STEP_CACHE.misses, jit_entries=jit_cache_entries())


def jit_cache_entries() -> int:
    """Total trace-cache entries across the driver's jitted functions —
    the cached refine steps plus the jitted supersteps the driver calls.
    If this number does not grow across a layout, that layout triggered
    zero new traces (and hence zero new XLA compiles)."""
    import importlib
    # the package __init__ rebinds these names to functions; go through
    # importlib to reach the modules themselves
    _merger = importlib.import_module("repro.core.solar_merger")
    _placer = importlib.import_module("repro.core.solar_placer")

    fns = []
    for entry in STEP_CACHE.entries.values():
        # dist-engine entries are (jitted_step, shardings) tuples
        fns.append(entry[0] if isinstance(entry, tuple) else entry)
    fns += [_merger.sun_election, _merger.system_growth,
            _placer._place, gila.gila_forces, gila.gila_layout]
    total = 0
    for f in fns:
        size = getattr(f, "_cache_size", None)
        if callable(size):
            try:
                total += int(size())
            except Exception:
                pass
    return total


# -- the bucketed refinement step ----------------------------------------------

def _build_refine(mode: str, grid_dim: int, cell_cap: int):
    """Jitted per-level refinement with TRACED iteration count and cooling
    schedule: one compile covers every level (and every graph) whose arrays
    land in the same shape bucket. The position buffer is donated."""

    def refine(pos0, src, dst, vmask, emask, mass, ewt, nbr_idx, nbr_mask,
               iters, temp0, temp_decay, params):
        g = PaddedGraph(src=src, dst=dst, vmask=vmask, emask=emask,
                        mass=mass, ewt=ewt, n=0, m=0)

        def body(i, carry):
            pos, temp = carry
            pos = gila.layout_iteration(g, pos, nbr_idx, nbr_mask, params,
                                        temp, mode=mode, grid_dim=grid_dim,
                                        cell_cap=cell_cap)
            return pos, temp * temp_decay

        pos, _ = jax.lax.fori_loop(0, iters, body, (pos0, temp0))
        return pos

    return jax.jit(refine, donate_argnums=donate_argnums_if_supported(0))


def refine_level(g: PaddedGraph, pos0, sched, *, ideal_len: float,
                 rep_const: float, min_dist: float = 1e-3, seed: int = 0):
    """Bucketed drop-in for ``gila.gila_layout`` in the multilevel driver.

    Looks up (or builds) the cached step for this level's shape bucket and
    runs it with iters/temp as traced scalars. The first call into a cold
    entry is accounted to the ``compile`` phase, warm calls to ``refine``.
    """
    if sched.mode == "neighbor":
        with PHASES.phase("refine"):        # host-side k-hop list build
            nbr_idx, nbr_mask = gila.build_level_neighbors(
                g, sched.k, sched.cap, seed=seed)
    else:
        nbr_idx = jnp.zeros((g.n_pad, 1), jnp.int32)
        nbr_mask = jnp.zeros((g.n_pad, 1), bool)

    key = ("refine", g.n_pad, g.m_pad, int(nbr_idx.shape[1]), sched.mode,
           sched.grid_dim, sched.cell_cap)
    fn, fresh = STEP_CACHE.get(
        key, lambda: _build_refine(sched.mode, sched.grid_dim, sched.cell_cap))

    params = jnp.asarray([rep_const, ideal_len, min_dist], jnp.float32)
    t0 = time.perf_counter()
    pos = fn(jnp.asarray(pos0), g.src, g.dst, g.vmask, g.emask, g.mass,
             g.ewt, nbr_idx, nbr_mask, jnp.asarray(sched.iters, jnp.int32),
             jnp.asarray(sched.temp0, jnp.float32),
             jnp.asarray(sched.temp_decay, jnp.float32), params)
    pos.block_until_ready()
    PHASES.add("compile" if fresh else "refine", time.perf_counter() - t0)
    return pos
