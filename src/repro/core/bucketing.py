"""Shape buckets + process-wide compile cache for the multilevel driver.

The paper's headline number is END-TO-END wall clock (10M edges in ~60
minutes on commodity cloud machines), and at that scale the coarsen →
place → refine *driver* — not the force kernel — dominates time-to-layout.
Before this module, every hierarchy level paid a fresh XLA compile: each
level has a distinct (n, m), ``PaddedGraph`` carries them as static pytree
fields, and ``gila_layout`` additionally bakes the iteration count into the
trace. A 10-level hierarchy compiled ten programs; the next graph compiled
ten more.

The fix has three parts (DESIGN.md §8):

  1. *Pow2 shape buckets* — every level's ``PaddedGraph`` is padded (vertex
     and edge axes independently) to the next power-of-two bucket
     (``graphs.graph.bucket_pad``), so all levels of all hierarchies share
     O(log n_max) distinct shapes. Randomness is per-vertex
     (``utils/prng.py``), so re-padding is behavior-preserving.
  2. *Process-wide compile cache* — the per-level refinement runs through
     one cached jitted step per key ``(bucket_n, bucket_e, cap, mode,
     grid_dim, cell_cap)`` (plus the mesh for the dist engine). The static
     ``n``/``m`` fields are normalized away before tracing
     (``shape_normalized``), iteration count / temperature / cooling are
     traced scalars, and the schedule picks grid_dim/cell_cap from the
     bucket — so a fresh graph whose levels land in warm buckets triggers
     ZERO new compiles (asserted in tests/test_bucketing.py).
  3. *Buffer donation* — the position buffer is donated through the
     refinement loop (no copy per level / per distributed iteration on
     accelerators; donation is skipped on CPU where XLA does not implement
     it and only warns).

``PHASES`` collects the per-phase wall clock (coarsen / place / refine /
compile) that benchmarks/pipeline_bench.py reports.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs.graph import PaddedGraph, bucket_pad
from repro.graphs import packing
from repro.core import gila
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.utils.transfer import io_boundary


def shape_normalized(g: PaddedGraph) -> PaddedGraph:
    """Zero the static n/m fields: jitted consumers that never read them
    then cache on the padded shapes alone (one trace per shape bucket)."""
    return dataclasses.replace(g, n=0, m=0)


def donate_argnums_if_supported(*argnums: int) -> tuple:
    """Buffer donation is a no-op (plus a warning per call) on CPU."""
    return argnums if jax.default_backend() != "cpu" else ()


def kernel_backend() -> str:
    """The kernel backend ('pallas' | 'interpret' | 'ref') the NEXT trace
    will bake in — the ``REPRO_PALLAS`` override or the platform default.

    The kernel dispatchers read this ambient state at trace time, so it is
    part of the compiled program and must be part of every compile-cache
    key: an entry cached under one backend must not be served after the env
    var changes mid-process (tools/gilalint rule R2 enforces this for any
    new cache site)."""
    from repro.kernels.grid_force.ops import backend_mode
    return backend_mode()


# -- per-phase wall-clock accounting ------------------------------------------

# storage for the phase accounting lives in the thread-safe metrics
# registry (obs/metrics.py), one labeled counter series per phase
PHASE_SECONDS = obs_metrics.REGISTRY.counter(
    "gila_phase_seconds_total",
    "Wall-clock seconds per pipeline phase (coarsen/place/refine/compile)",
    "seconds")


class PhaseTimes:
    """Per-phase wall-clock accounting (coarsen/place/refine/compile).
    ``compile`` is the first call into a cold cache entry — trace
    + XLA compile + the first execution (inseparable under jit dispatch);
    merger-superstep compiles land in ``coarsen`` the same way.

    DEPRECATED facade: the numbers now live in the metrics registry
    (``gila_phase_seconds_total{phase=...}``), which is lock-protected —
    the old dict-backed version was mutated from the engine worker thread
    (host coarsening inside ``EngineCore``) and the caller thread
    concurrently, a read-modify-write race. The ``PHASES`` alias and its
    ``add``/``phase``/``snapshot``/``reset`` API are kept so
    benchmarks/pipeline_bench.py output is unchanged; new code should use
    ``obs_metrics.REGISTRY`` / ``obs_trace`` directly.
    """

    def add(self, name: str, seconds: float) -> None:
        PHASE_SECONDS.inc(max(float(seconds), 0.0), phase=name)

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)

    def snapshot(self) -> dict:
        return {dict(k)["phase"]: v
                for k, v in PHASE_SECONDS.values().items()}

    def reset(self) -> None:
        PHASE_SECONDS.clear()


PHASES = PhaseTimes()


# -- the compile cache ---------------------------------------------------------

CACHE_HITS = obs_metrics.REGISTRY.counter(
    "gila_compile_cache_hits_total",
    "Warm lookups of the process-wide compiled-step cache")
CACHE_MISSES = obs_metrics.REGISTRY.counter(
    "gila_compile_cache_misses_total",
    "Cold lookups (each one builds + compiles a new step program)")


class CompileCache:
    """Process-wide cache of jitted step functions keyed on shape buckets.

    ``get(key, builder)`` returns ``(fn, fresh)``; ``fresh=True`` means the
    builder ran (the next call of ``fn`` traces and XLA-compiles).
    Lock-protected: the engine worker thread and direct callers share one
    process-wide instance."""

    def __init__(self):
        self.entries: dict = {}
        self.hits = 0
        self.misses = 0
        self._lock = threading.RLock()

    def get(self, key, builder):
        with self._lock:
            fn = self.entries.get(key)
            if fn is not None:
                self.hits += 1
                CACHE_HITS.inc()
                return fn, False
            self.misses += 1
            CACHE_MISSES.inc()
            fn = builder()
            self.entries[key] = fn
            return fn, True

    def clear(self) -> None:
        with self._lock:
            self.entries.clear()
            self.hits = 0
            self.misses = 0


STEP_CACHE = CompileCache()


def cache_stats() -> dict:
    """Introspection for tests/benchmarks: entries/hits/misses of the step
    cache plus the total jit-trace entry count of every tracked function."""
    return dict(entries=len(STEP_CACHE.entries), hits=STEP_CACHE.hits,
                misses=STEP_CACHE.misses, jit_entries=jit_cache_entries())


def jit_cache_entries() -> int:
    """Total trace-cache entries across the driver's jitted functions —
    the cached refine steps plus the jitted supersteps the driver calls.
    If this number does not grow across a layout, that layout triggered
    zero new traces (and hence zero new XLA compiles)."""
    import importlib
    # the package __init__ rebinds these names to functions; go through
    # importlib to reach the modules themselves
    _merger = importlib.import_module("repro.core.solar_merger")
    _placer = importlib.import_module("repro.core.solar_placer")

    fns = []
    for entry in STEP_CACHE.entries.values():
        # dist-engine entries are (jitted_step, shardings) tuples
        fns.append(entry[0] if isinstance(entry, tuple) else entry)
    fns += [_merger.sun_election, _merger.system_growth,
            _placer._place, gila.gila_forces, gila.gila_layout]
    total = 0
    for f in fns:
        size = getattr(f, "_cache_size", None)
        if callable(size):
            try:
                total += int(size())
            except Exception:
                pass
    return total


# callback gauges: sampled at scrape/snapshot time, so a long-running
# service's /metrics always reports the LIVE cache state
obs_metrics.REGISTRY.gauge(
    "gila_compile_cache_entries",
    "Live compiled-step entries in the process-wide cache",
    fn=lambda: len(STEP_CACHE.entries))
obs_metrics.REGISTRY.gauge(
    "gila_jit_trace_entries",
    "Total jit trace-cache entries across the driver's tracked functions",
    fn=jit_cache_entries)


# -- the bucketed refinement step ----------------------------------------------

def _build_refine(mode: str, grid_dim: int, cell_cap: int):
    """Jitted per-level refinement with TRACED iteration count and cooling
    schedule: one compile covers every level (and every graph) whose arrays
    land in the same shape bucket. The position buffer is donated."""

    def refine(pos0, src, dst, vmask, emask, mass, ewt, nbr_idx, nbr_mask,
               iters, temp0, temp_decay, params):
        g = PaddedGraph(src=src, dst=dst, vmask=vmask, emask=emask,
                        mass=mass, ewt=ewt, n=0, m=0)

        def body(i, carry):
            pos, temp = carry
            pos = gila.layout_iteration(g, pos, nbr_idx, nbr_mask, params,
                                        temp, mode=mode, grid_dim=grid_dim,
                                        cell_cap=cell_cap)
            return pos, temp * temp_decay

        pos, _ = jax.lax.fori_loop(0, iters, body, (pos0, temp0))
        return pos

    return jax.jit(refine, donate_argnums=donate_argnums_if_supported(0))


def cached_refine(g: PaddedGraph, pos0, sched, nbr_idx, nbr_mask, *,
                  ideal_len: float, rep_const: float, min_dist: float = 1e-3):
    """(cache_key, fn, fresh, args) for one level's bucketed refine step.

    The single place the single-graph refine key is derived and its
    arguments staged — shared by the driver (``refine_level``) and the
    jaxpr audit of tools/gilalint, so the audit traces exactly the program
    the driver would run (gilalint R2 statically checks this call site).
    """
    key = ("refine", g.n_pad, g.m_pad, int(nbr_idx.shape[1]), sched.mode,
           sched.grid_dim, sched.cell_cap, kernel_backend())
    fn, fresh = STEP_CACHE.get(
        key, lambda: _build_refine(sched.mode, sched.grid_dim, sched.cell_cap))
    with io_boundary():                     # intentional host→device staging
        params = jnp.asarray([rep_const, ideal_len, min_dist], jnp.float32)
        args = (jnp.asarray(pos0), g.src, g.dst, g.vmask, g.emask, g.mass,
                g.ewt, nbr_idx, nbr_mask,
                jnp.asarray(sched.iters, jnp.int32),
                jnp.asarray(sched.temp0, jnp.float32),
                jnp.asarray(sched.temp_decay, jnp.float32), params)
    return key, fn, fresh, args


def refine_level(g: PaddedGraph, pos0, sched, *, ideal_len: float,
                 rep_const: float, min_dist: float = 1e-3, seed: int = 0):
    """Bucketed drop-in for ``gila.gila_layout`` in the multilevel driver.

    Looks up (or builds) the cached step for this level's shape bucket and
    runs it with iters/temp as traced scalars. The first call into a cold
    entry is accounted to the ``compile`` phase, warm calls to ``refine``.
    """
    if sched.mode == "neighbor":
        with PHASES.phase("refine"):        # host-side k-hop list build
            nbr_idx, nbr_mask = gila.build_level_neighbors(
                g, sched.k, sched.cap, seed=seed)
    else:
        with io_boundary():
            nbr_idx = jnp.zeros((g.n_pad, 1), jnp.int32)
            nbr_mask = jnp.zeros((g.n_pad, 1), bool)

    key, fn, fresh, args = cached_refine(g, pos0, sched, nbr_idx, nbr_mask,
                                         ideal_len=ideal_len,
                                         rep_const=rep_const,
                                         min_dist=min_dist)

    # the span brackets the existing dispatch + block_until_ready pair —
    # NO new host↔device sync is introduced by tracing (gilalint-checked)
    t0 = time.perf_counter()
    with obs_trace.span("refine.dispatch", cat="device", key=key,
                        fresh=fresh, mode=sched.mode):
        pos = fn(*args)
        pos.block_until_ready()
    PHASES.add("compile" if fresh else "refine", time.perf_counter() - t0)
    return pos


# -- the batched (multi-graph) refinement step ---------------------------------
#
# The multi-graph driver (core/multilevel.py:multigila_layout_many) groups the
# pending per-level refinements of MANY graphs by shape bucket and runs each
# group as ONE vmapped cached step: a 16-graph request whose levels land in
# warm buckets compiles nothing and dispatches one device program per level
# wave. Iteration counts / temperatures stay per-lane traced arrays; lanes
# whose iteration budget is exhausted (and the dead padding lanes of a pow2
# batch bucket) carry their positions through the remaining loop trips
# unchanged, which keeps every lane bit-identical to the same refinement run
# alone (tests/test_many.py).

# Lane shape-bucket floors for the batched driver. The vertex floor sits
# BELOW the single-graph driver's 256: with B lanes amortizing the compile,
# finer buckets pay for themselves immediately — a 45-vertex coarse level
# costs 64² pair interactions per lane instead of 256² (padding invariance
# makes the finer re-pad behavior-preserving). The edge floor is coarser
# than pow2-of-2m so that small per-seed wobbles in coarse-level edge counts
# do not mint fresh cache keys (attraction work is linear in m_pad — cheap
# relative to the n_pad² repulsion).
BATCH_MIN_N = 64
BATCH_MIN_E = 512
# use the incidence-gather attraction (see _build_refine_many) up to this
# per-vertex degree bucket; beyond it (hub-heavy graphs) the [n_pad, K]
# gather table outgrows the edge list and the flat scatter wins back
INC_K_MAX = 32


@dataclasses.dataclass
class RefineRequest:
    """One graph-level refinement queued for a batched group dispatch.

    ``g``/``pos0`` are already re-padded to the LANE bucket
    (``lane_shape``); ``sched`` carries the level's iteration budget and
    (static) mode/grid parameters; ``seed`` feeds the neighbor-list build;
    ``inc``/``inc_k`` the incidence-gather table (inc_k = 0 → the program
    aggregates attraction with a flat scatter instead). Build with
    ``make_request``. ``level``/``lane`` are observability metadata only
    (span annotations) — they MUST stay out of ``group_key``, or equal
    shapes at different hierarchy levels would stop sharing compiles.
    """
    g: PaddedGraph
    pos0: jnp.ndarray
    sched: "object"          # core.schedule.LevelSchedule
    seed: int
    inc: jnp.ndarray
    inc_k: int
    level: int = 0
    lane: object = None


def lane_shape(n: int, m: int) -> tuple[int, int]:
    """(n_pad, m_pad) lane bucket for a graph with n vertices / m edges."""
    return (bucket_pad(n, BATCH_MIN_N), bucket_pad(2 * m, BATCH_MIN_E))


def make_request(g: PaddedGraph, pos0, sched, seed: int, *, level: int = 0,
                 lane: object = None) -> RefineRequest:
    """Re-pad one level to its lane bucket and attach the incidence table."""
    n_pad, m_pad = lane_shape(g.n, g.m)
    g2 = packing.repad_graph(g, n_pad, m_pad)
    inc, k = packing.incidence_table(g2, INC_K_MAX)
    if inc is None:               # hub-heavy lane: flat-scatter attraction
        with io_boundary():
            inc, k = jnp.zeros((n_pad, 0), jnp.int32), 0
    return RefineRequest(g=g2, pos0=packing.repad_rows(pos0, n_pad),
                         sched=sched, seed=seed, inc=inc, inc_k=k,
                         level=int(level), lane=lane)


def group_key(req: RefineRequest) -> tuple:
    """Shape-bucket grouping key: requests with equal keys share one
    compiled batched program (and one device dispatch per wave)."""
    s = req.sched
    cap = s.cap if s.mode == "neighbor" else 1
    return (req.g.n_pad, req.g.m_pad, cap, req.inc_k, s.mode, s.grid_dim,
            s.cell_cap)


# padding occupancy — the direct measurement of fragmentation loss: the
# fraction of each dispatched [lanes, n_pad]/[lanes, m_pad] batch volume
# holding TRUE vertices/edge-slots rather than pow2 padding. Labeled by
# the shape bucket (and by lane bucket for the lane axis).
OCC_VERTICES = obs_metrics.REGISTRY.gauge(
    "gila_wave_padding_occupancy_vertices",
    "True vertices / (lanes * n_pad) of the last dispatch per bucket",
    "ratio")
OCC_EDGES = obs_metrics.REGISTRY.gauge(
    "gila_wave_padding_occupancy_edges",
    "True directed edge slots / (lanes * m_pad) of the last dispatch",
    "ratio")
OCC_LANES = obs_metrics.REGISTRY.gauge(
    "gila_wave_lane_occupancy",
    "Live lanes / pow2 lane bucket of the last dispatch per bucket",
    "ratio")


def _record_occupancy(reqs: list["RefineRequest"], lanes: int) -> None:
    n_pad, m_pad = reqs[0].g.n_pad, reqs[0].g.m_pad
    bucket = f"n{n_pad}_e{m_pad}"
    OCC_VERTICES.set(sum(r.g.n for r in reqs) / (lanes * n_pad),
                     bucket=bucket)
    OCC_EDGES.set(sum(2 * r.g.m for r in reqs) / (lanes * m_pad),
                  bucket=bucket)
    OCC_LANES.set(len(reqs) / lanes, bucket=bucket)


def _build_refine_many(mode: str, grid_dim: int, cell_cap: int, inc_k: int):
    """Jitted batched refinement over ``[B, n_pad]`` lanes.

    Per-lane arithmetic is element-for-element the computation of
    ``_build_refine`` (gila.layout_iteration), so every lane is
    bit-identical to the same level refined alone; the per-lane traced
    iteration budget is masked against the group's shared trip count.

    The *lowering* differs from a naive ``vmap`` in one deliberate way:
    aggregation/gather with per-lane indices lowers to batched
    scatter/gather HLO that XLA CPU executes an order of magnitude slower
    than the flat single-graph form. So the lanes are flattened into ONE
    index space — lane b's slot v lives at ``b * (n_pad + 1) + v``, a
    per-lane zero sentinel row coming along at slot n_pad — and the
    attraction aggregation runs, for ``inc_k > 0``, as ``inc_k`` unrolled
    gathered adds over the incidence table (``packing.incidence_table``):
    each vertex accumulates its incoming edge vectors in ascending slot
    order, which is byte-for-byte the accumulation order of the sequential
    step's ``segment_sum`` scatter — so the float sums stay bit-identical
    while costing ~15× less than a batched scatter. Hub-heavy lanes
    (``inc_k == 0``) fall back to one flat ``segment_sum`` over the fused
    index space. Dense per-lane math (exact/grid repulsion, cooling clamp)
    vmaps efficiently and stays vmapped — in grid mode that includes
    ``bin_vertices``, so spatial binning stays per-graph.
    """
    from repro.kernels.nbody import ops as nbody_ops

    def refine_many(pos0, src, dst, vmask, emask, mass, ewt, nbr_idx,
                    nbr_mask, inc, iters, temp0, temp_decay, params,
                    max_iters):
        B, n_pad = pos0.shape[0], pos0.shape[1]
        m_pad = src.shape[1]
        C, L, md = params[0], params[1], params[2]
        w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)   # [B, n_pad]
        offs = (jnp.arange(B, dtype=jnp.int32) * (n_pad + 1))[:, None]
        flat_dst = (dst + offs).reshape(-1)
        flat_src = src + offs
        flat_dst_clip = jnp.clip(dst, 0, n_pad - 1) + offs
        ell = jnp.maximum(ewt, 1e-6) * L                      # [B, m_pad]
        # incidence slots in the fused per-lane edge index space
        flat_inc = inc + (jnp.arange(B, dtype=jnp.int32)
                          * (m_pad + 1))[:, None, None]

        def flat_pos(pos):
            """[B, n_pad, 2] → [B*(n_pad+1), 2] with a zero sentinel row
            per lane (the dense-array 'empty inbox')."""
            posp = jnp.concatenate(
                [pos, jnp.zeros((B, 1, 2), pos.dtype)], axis=1)
            return posp.reshape(B * (n_pad + 1), 2)

        def attraction(pos):
            flat = flat_pos(pos)
            pos_src = flat[flat_src]                          # [B, m_pad, 2]
            pos_dst = flat[flat_dst_clip]
            delta = pos_src - pos_dst
            dist = jnp.sqrt(jnp.sum(delta * delta, axis=2) + md ** 2)
            f = (dist * dist) / ell
            vec = delta / dist[..., None] * f[..., None]
            vec = jnp.where(emask[..., None], vec, 0.0)
            if inc_k > 0:
                vflat = jnp.concatenate(
                    [vec, jnp.zeros((B, 1, 2), vec.dtype)],
                    axis=1).reshape(B * (m_pad + 1), 2)
                acc = jnp.zeros((B, n_pad, 2), vec.dtype)
                for k in range(inc_k):        # left-assoc: scatter order
                    acc = acc + vflat[flat_inc[:, :, k]]
                return acc
            out = jax.ops.segment_sum(vec.reshape(-1, 2), flat_dst,
                                      num_segments=B * (n_pad + 1))
            return out.reshape(B, n_pad + 1, 2)[:, :n_pad]

        if mode == "exact":
            def repulsion(pos):
                return jax.vmap(nbody_ops.nbody_repulsion,
                                in_axes=(0, 0, 0, None, None, None))(
                    pos, mass, vmask, C, L, md)
        elif mode == "neighbor":
            flat_nbr = nbr_idx + offs[:, :, None]             # [B, n_pad, K]

            def repulsion(pos):
                flat = flat_pos(pos)
                wp = jnp.concatenate(
                    [w, jnp.zeros((B, 1), w.dtype)], axis=1).reshape(-1)
                npos = flat[flat_nbr]                         # [B, n_pad, K, 2]
                nw = jnp.where(nbr_mask, wp[flat_nbr], 0.0)
                delta = pos[:, :, None, :] - npos
                d2 = jnp.sum(delta * delta, axis=-1) + md ** 2
                inv = (C * L * L) * nw / d2
                f = jnp.sum(delta * inv[..., None], axis=2)
                return jnp.where(vmask[..., None], f, 0.0)
        else:
            from repro.kernels.grid_force import ops as grid_ops

            def repulsion(pos):
                return jax.vmap(lambda p, m_, v_: grid_ops.grid_repulsion(
                    p, m_, v_, C, L, md,
                    grid_dim=grid_dim, cell_cap=cell_cap))(pos, mass, vmask)

        def body(i, carry):
            pos, temp = carry
            f = repulsion(pos) + attraction(pos)
            norm = jnp.sqrt(jnp.sum(f * f, axis=2) + 1e-12)
            step = jnp.minimum(norm, temp[:, None])
            new = pos + f / norm[..., None] * step[..., None]
            new = jnp.where(vmask[..., None], new, 0.0)
            live = i < iters
            return (jnp.where(live[:, None, None], new, pos),
                    jnp.where(live, temp * temp_decay, temp))

        pos, _ = jax.lax.fori_loop(0, max_iters, body, (pos0, temp0))
        return pos

    return jax.jit(refine_many,
                   donate_argnums=donate_argnums_if_supported(0))


def cached_refine_many(reqs: list[RefineRequest], nbrs: list[tuple], *,
                       ideal_len: float, rep_const: float,
                       min_dist: float = 1e-3, lanes_min: int = 8):
    """(cache_key, fn, fresh, args) for one batched shape-bucket group.

    ``nbrs`` is the per-request (nbr_idx, nbr_mask) list (dummies for
    non-neighbor modes). Shared by ``refine_level_many`` and the gilalint
    jaxpr audit — the audit traces the production staging path (and
    gilalint R2 statically checks this call site).
    """
    key0 = group_key(reqs[0])
    assert all(group_key(r) == key0 for r in reqs), "mixed group"
    sched0 = reqs[0].sched
    b = len(reqs)
    lanes = packing.lane_bucket(b, lanes_min)
    packed = packing.pack_graphs([r.g for r in reqs], lanes=lanes)
    _record_occupancy(reqs, lanes)
    with io_boundary():                     # intentional host→device staging
        pl = lambda a: packing.pad_lanes(a, b, lanes)
        pos0 = pl(jnp.stack([jnp.asarray(r.pos0) for r in reqs]))
        nbr_idx = pl(jnp.stack([ni for ni, _ in nbrs]))
        nbr_mask = pl(jnp.stack([nm for _, nm in nbrs]))
        inc = pl(jnp.stack([r.inc for r in reqs]))
        # dead lanes: iteration budget 0 — they ride through untouched
        iters = jnp.asarray([r.sched.iters for r in reqs] + [0] * (lanes - b),
                            jnp.int32)
        temp0 = pl(jnp.asarray([r.sched.temp0 for r in reqs], jnp.float32))
        decay = pl(jnp.asarray([r.sched.temp_decay for r in reqs],
                               jnp.float32))
        params = jnp.asarray([rep_const, ideal_len, min_dist], jnp.float32)
        max_iters = jnp.asarray(max(r.sched.iters for r in reqs), jnp.int32)

    cache_key = ("refine_many", lanes, kernel_backend()) + key0
    fn, fresh = STEP_CACHE.get(
        cache_key,
        lambda: _build_refine_many(sched0.mode, sched0.grid_dim,
                                   sched0.cell_cap, reqs[0].inc_k))
    args = (pos0, packed.g.src, packed.g.dst, packed.g.vmask, packed.g.emask,
            packed.g.mass, packed.g.ewt, nbr_idx, nbr_mask, inc, iters,
            temp0, decay, params, max_iters)
    return cache_key, fn, fresh, args


def refine_level_many(reqs: list[RefineRequest], *, ideal_len: float,
                      rep_const: float, min_dist: float = 1e-3,
                      lanes_min: int = 8,
                      lanes_cap: int | None = None) -> list[jnp.ndarray]:
    """Run one shape-bucket group of refinements as a single device program.

    All requests must share ``group_key``. Returns the per-request refined
    positions (lane-padded shape [n_pad, 2]), in request order.

    ``lanes_cap`` bounds the lane bucket of a single dispatch: an oversized
    group is split into ≤ lanes_cap chunks (lanes are arithmetically
    independent, so chunking is bit-exact). A long-lived engine
    (serve/engine.py) sets this so its lane-bucket spectrum is CLOSED —
    pow2 buckets in [lanes_min, lanes_cap] — and a mid-flight join can
    never mint a fresh lane-bucket compile once those buckets are warm.
    """
    assert reqs
    if lanes_cap is not None and len(reqs) > lanes_cap:
        out = []
        for i in range(0, len(reqs), lanes_cap):
            out.extend(refine_level_many(
                reqs[i:i + lanes_cap], ideal_len=ideal_len,
                rep_const=rep_const, min_dist=min_dist,
                lanes_min=lanes_min, lanes_cap=lanes_cap))
        return out
    mode = reqs[0].sched.mode

    # per-lane neighbor lists (host build, same code path + seed as the
    # single-graph driver so the lists — and hence the forces — match)
    if mode == "neighbor":
        from repro.graphs.graph import unique_edges
        nbrs = []
        with PHASES.phase("refine"):
            for r in reqs:
                idx, msk = gila.khop_neighbors(unique_edges(r.g), r.g.n,
                                               r.sched.k, r.sched.cap,
                                               seed=r.seed)
                nbrs.append(gila.pad_neighbors(idx, msk, r.g.n_pad))
    else:
        with io_boundary():
            z = (jnp.zeros((reqs[0].g.n_pad, 1), jnp.int32),
                 jnp.zeros((reqs[0].g.n_pad, 1), bool))
        nbrs = [z] * len(reqs)

    key, fn, fresh, args = cached_refine_many(
        reqs, nbrs, ideal_len=ideal_len, rep_const=rep_const,
        min_dist=min_dist, lanes_min=lanes_min)
    # span brackets the existing dispatch + sync only (no added syncs)
    t0 = time.perf_counter()
    with obs_trace.span("refine_many.dispatch", cat="device", key=key,
                        fresh=fresh, lanes=len(reqs)):
        out = fn(*args)
        out.block_until_ready()
    PHASES.add("compile" if fresh else "refine", time.perf_counter() - t0)
    b = len(reqs)
    with io_boundary():                     # egress: unpack the live lanes
        return [out[i] for i in range(b)]
