"""Shape buckets + process-wide compile cache for the multilevel driver.

The paper's headline number is END-TO-END wall clock (10M edges in ~60
minutes on commodity cloud machines), and at that scale the coarsen →
place → refine *driver* — not the force kernel — dominates time-to-layout.
Before this module, every hierarchy level paid a fresh XLA compile: each
level has a distinct (n, m), ``PaddedGraph`` carries them as static pytree
fields, and ``gila_layout`` additionally bakes the iteration count into the
trace. A 10-level hierarchy compiled ten programs; the next graph compiled
ten more.

The fix has three parts (DESIGN.md §8):

  1. *Pow2 shape buckets* — every level's ``PaddedGraph`` is padded (vertex
     and edge axes independently) to the next power-of-two bucket
     (``graphs.graph.bucket_pad``), so all levels of all hierarchies share
     O(log n_max) distinct shapes. Randomness is per-vertex
     (``utils/prng.py``), so re-padding is behavior-preserving.
  2. *Process-wide compile cache* — the per-level refinement runs through
     one cached jitted step per key ``(engine, bucket_n, bucket_e, cap,
     mode, grid_dim, cell_cap)`` (plus the mesh for the dist driver). The
     engine id selects WHICH step program the builder constructs
     (core/engine.py — GiLA forces vs maxent-stress share the key space
     but never an entry), so a warm stress pass compiles zero new GiLA
     variants and vice versa. The static
     ``n``/``m`` fields are normalized away before tracing
     (``shape_normalized``), iteration count / temperature / cooling are
     traced scalars, and the schedule picks grid_dim/cell_cap from the
     bucket — so a fresh graph whose levels land in warm buckets triggers
     ZERO new compiles (asserted in tests/test_bucketing.py).
  3. *Buffer donation* — the position buffer is donated through the
     refinement loop (no copy per level / per distributed iteration on
     accelerators; donation is skipped on CPU where XLA does not implement
     it and only warns).

``PHASES`` collects the per-phase wall clock (coarsen / place / refine /
compile) that benchmarks/pipeline_bench.py reports.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs.graph import PaddedGraph, bucket_pad
from repro.graphs import packing
from repro.core import engine as engines
from repro.core import gila
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.utils.transfer import io_boundary


def shape_normalized(g: PaddedGraph) -> PaddedGraph:
    """Zero the static n/m fields: jitted consumers that never read them
    then cache on the padded shapes alone (one trace per shape bucket)."""
    return dataclasses.replace(g, n=0, m=0)


def donate_argnums_if_supported(*argnums: int) -> tuple:
    """Buffer donation is a no-op (plus a warning per call) on CPU."""
    return argnums if jax.default_backend() != "cpu" else ()


def kernel_backend() -> str:
    """The kernel backend ('pallas' | 'interpret' | 'ref') the NEXT trace
    will bake in — the ``REPRO_PALLAS`` override or the platform default.

    The kernel dispatchers read this ambient state at trace time, so it is
    part of the compiled program and must be part of every compile-cache
    key: an entry cached under one backend must not be served after the env
    var changes mid-process (tools/gilalint rule R2 enforces this for any
    new cache site)."""
    from repro.kernels.grid_force.ops import backend_mode
    return backend_mode()


# -- per-phase wall-clock accounting ------------------------------------------

# storage for the phase accounting lives in the thread-safe metrics
# registry (obs/metrics.py), one labeled counter series per phase
PHASE_SECONDS = obs_metrics.REGISTRY.counter(
    "gila_phase_seconds_total",
    "Wall-clock seconds per pipeline phase (coarsen/place/refine/compile)",
    "seconds")


class PhaseTimes:
    """Per-phase wall-clock accounting (coarsen/place/refine/compile).
    ``compile`` is the first call into a cold cache entry — trace
    + XLA compile + the first execution (inseparable under jit dispatch);
    merger-superstep compiles land in ``coarsen`` the same way.

    DEPRECATED facade: the numbers now live in the metrics registry
    (``gila_phase_seconds_total{phase=...}``), which is lock-protected —
    the old dict-backed version was mutated from the engine worker thread
    (host coarsening inside ``EngineCore``) and the caller thread
    concurrently, a read-modify-write race. The ``PHASES`` alias and its
    ``add``/``phase``/``snapshot``/``reset`` API are kept so
    benchmarks/pipeline_bench.py output is unchanged; new code should use
    ``obs_metrics.REGISTRY`` / ``obs_trace`` directly.
    """

    def add(self, name: str, seconds: float) -> None:
        PHASE_SECONDS.inc(max(float(seconds), 0.0), phase=name)

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)

    def snapshot(self) -> dict:
        return {dict(k)["phase"]: v
                for k, v in PHASE_SECONDS.values().items()}

    def reset(self) -> None:
        PHASE_SECONDS.clear()


PHASES = PhaseTimes()


# -- the compile cache ---------------------------------------------------------

CACHE_HITS = obs_metrics.REGISTRY.counter(
    "gila_compile_cache_hits_total",
    "Warm lookups of the process-wide compiled-step cache")
CACHE_MISSES = obs_metrics.REGISTRY.counter(
    "gila_compile_cache_misses_total",
    "Cold lookups (each one builds + compiles a new step program)")


class CompileCache:
    """Process-wide cache of jitted step functions keyed on shape buckets.

    ``get(key, builder)`` returns ``(fn, fresh)``; ``fresh=True`` means the
    builder ran (the next call of ``fn`` traces and XLA-compiles).
    Lock-protected: the engine worker thread and direct callers share one
    process-wide instance."""

    def __init__(self):
        self.entries: dict = {}
        self.hits = 0
        self.misses = 0
        self._lock = threading.RLock()

    def get(self, key, builder):
        with self._lock:
            fn = self.entries.get(key)
            if fn is not None:
                self.hits += 1
                CACHE_HITS.inc()
                return fn, False
            self.misses += 1
            CACHE_MISSES.inc()
            fn = builder()
            self.entries[key] = fn
            return fn, True

    def clear(self) -> None:
        with self._lock:
            self.entries.clear()
            self.hits = 0
            self.misses = 0


STEP_CACHE = CompileCache()


def cache_stats() -> dict:
    """Introspection for tests/benchmarks: entries/hits/misses of the step
    cache plus the total jit-trace entry count of every tracked function."""
    return dict(entries=len(STEP_CACHE.entries), hits=STEP_CACHE.hits,
                misses=STEP_CACHE.misses, jit_entries=jit_cache_entries())


def jit_cache_entries() -> int:
    """Total trace-cache entries across the driver's jitted functions —
    the cached refine steps plus the jitted supersteps the driver calls.
    If this number does not grow across a layout, that layout triggered
    zero new traces (and hence zero new XLA compiles)."""
    import importlib
    # the package __init__ rebinds these names to functions; go through
    # importlib to reach the modules themselves
    _merger = importlib.import_module("repro.core.solar_merger")
    _placer = importlib.import_module("repro.core.solar_placer")

    fns = []
    for entry in STEP_CACHE.entries.values():
        # dist-engine entries are (jitted_step, shardings) tuples
        fns.append(entry[0] if isinstance(entry, tuple) else entry)
    fns += [_merger.sun_election, _merger.system_growth,
            _placer._place, gila.gila_forces, gila.gila_layout]
    total = 0
    for f in fns:
        size = getattr(f, "_cache_size", None)
        if callable(size):
            try:
                total += int(size())
            except Exception:
                pass
    return total


# callback gauges: sampled at scrape/snapshot time, so a long-running
# service's /metrics always reports the LIVE cache state
obs_metrics.REGISTRY.gauge(
    "gila_compile_cache_entries",
    "Live compiled-step entries in the process-wide cache",
    fn=lambda: len(STEP_CACHE.entries))
obs_metrics.REGISTRY.gauge(
    "gila_jit_trace_entries",
    "Total jit trace-cache entries across the driver's tracked functions",
    fn=jit_cache_entries)


# per-engine dispatch accounting: which refinement engine served how many
# cached-step dispatches, split by the single-graph vs batched path
REFINE_DISPATCHES = obs_metrics.REGISTRY.counter(
    "gila_refine_dispatches_total",
    "Cached refine-step dispatches, labeled by engine and dispatch path")


# -- the bucketed refinement step ----------------------------------------------

def _build_refine(mode: str, grid_dim: int, cell_cap: int,
                  engine: str = "gila"):
    """Build the jitted per-level refinement step for ``engine`` — a thin
    dispatch into the engine registry (core/engine.py), kept here so the
    gilalint jaxpr audit and tests keep one stable entry point. The step
    has TRACED iteration count and annealing vector: one compile covers
    every level (and every graph) whose arrays land in the same shape
    bucket. The position buffer is donated."""
    return engines.get_engine(engine).build_refine(mode, grid_dim, cell_cap)


def cached_refine(g: PaddedGraph, pos0, sched, nbr_idx, nbr_mask, *,
                  ideal_len: float, rep_const: float, min_dist: float = 1e-3):
    """(cache_key, fn, fresh, args) for one level's bucketed refine step.

    The single place the single-graph refine key is derived and its
    arguments staged — shared by the driver (``refine_level``) and the
    jaxpr audit of tools/gilalint, so the audit traces exactly the program
    the driver would run (gilalint R2 statically checks this call site).
    ``sched.engine`` picks the step program AND is part of the key: GiLA
    and stress entries of the same shape bucket never collide.
    """
    eng = engines.get_engine(sched.engine)
    key = ("refine", sched.engine, g.n_pad, g.m_pad, int(nbr_idx.shape[1]),
           sched.mode, sched.grid_dim, sched.cell_cap, kernel_backend())
    fn, fresh = STEP_CACHE.get(
        key, lambda: eng.build_refine(sched.mode, sched.grid_dim,
                                      sched.cell_cap))
    with io_boundary():                     # intentional host→device staging
        params = jnp.asarray([rep_const, ideal_len, min_dist], jnp.float32)
        args = (jnp.asarray(pos0), g.src, g.dst, g.vmask, g.emask, g.mass,
                g.ewt, nbr_idx, nbr_mask,
                jnp.asarray(sched.iters, jnp.int32),
                jnp.asarray(eng.lane_schedule(sched), jnp.float32), params)
    return key, fn, fresh, args


def refine_level(g: PaddedGraph, pos0, sched, *, ideal_len: float,
                 rep_const: float, min_dist: float = 1e-3, seed: int = 0):
    """Bucketed drop-in for ``gila.gila_layout`` in the multilevel driver.

    Looks up (or builds) the cached step for this level's shape bucket and
    runs it with iters/temp as traced scalars. The first call into a cold
    entry is accounted to the ``compile`` phase, warm calls to ``refine``.
    """
    eng = engines.get_engine(sched.engine)
    if sched.mode == "neighbor":
        with PHASES.phase("refine"):        # host-side k-hop list build
            nbr_idx, nbr_mask = eng.init_state(g, sched, seed)
    else:
        nbr_idx, nbr_mask = eng.init_state(g, sched, seed)

    key, fn, fresh, args = cached_refine(g, pos0, sched, nbr_idx, nbr_mask,
                                         ideal_len=ideal_len,
                                         rep_const=rep_const,
                                         min_dist=min_dist)

    # the span brackets the existing dispatch + block_until_ready pair —
    # NO new host↔device sync is introduced by tracing (gilalint-checked)
    t0 = time.perf_counter()
    with obs_trace.span("refine.dispatch", cat="device", key=key,
                        fresh=fresh, mode=sched.mode, engine=sched.engine):
        pos = fn(*args)
        pos.block_until_ready()
    PHASES.add("compile" if fresh else "refine", time.perf_counter() - t0)
    REFINE_DISPATCHES.inc(engine=sched.engine, path="single")
    return pos


# -- the batched (multi-graph) refinement step ---------------------------------
#
# The multi-graph driver (core/multilevel.py:multigila_layout_many) groups the
# pending per-level refinements of MANY graphs by shape bucket and runs each
# group as ONE vmapped cached step: a 16-graph request whose levels land in
# warm buckets compiles nothing and dispatches one device program per level
# wave. Iteration counts / temperatures stay per-lane traced arrays; lanes
# whose iteration budget is exhausted (and the dead padding lanes of a pow2
# batch bucket) carry their positions through the remaining loop trips
# unchanged, which keeps every lane bit-identical to the same refinement run
# alone (tests/test_many.py).

# Lane shape-bucket floors for the batched driver. The vertex floor sits
# BELOW the single-graph driver's 256: with B lanes amortizing the compile,
# finer buckets pay for themselves immediately — a 45-vertex coarse level
# costs 64² pair interactions per lane instead of 256² (padding invariance
# makes the finer re-pad behavior-preserving). The edge floor is coarser
# than pow2-of-2m so that small per-seed wobbles in coarse-level edge counts
# do not mint fresh cache keys (attraction work is linear in m_pad — cheap
# relative to the n_pad² repulsion).
BATCH_MIN_N = 64
BATCH_MIN_E = 512
# use the incidence-gather attraction (see _build_refine_many) up to this
# per-vertex degree bucket; beyond it (hub-heavy graphs) the [n_pad, K]
# gather table outgrows the edge list and the flat scatter wins back
INC_K_MAX = 32


@dataclasses.dataclass
class RefineRequest:
    """One graph-level refinement queued for a batched group dispatch.

    ``g``/``pos0`` are already re-padded to the LANE bucket
    (``lane_shape``); ``sched`` carries the level's iteration budget and
    (static) mode/grid parameters; ``seed`` feeds the neighbor-list build;
    ``inc``/``inc_k`` the incidence-gather table (inc_k = 0 → the program
    aggregates attraction with a flat scatter instead). Build with
    ``make_request``. ``level``/``lane`` are observability metadata only
    (span annotations) — they MUST stay out of ``group_key``, or equal
    shapes at different hierarchy levels would stop sharing compiles.
    """
    g: PaddedGraph
    pos0: jnp.ndarray
    sched: "object"          # core.schedule.LevelSchedule
    seed: int
    inc: jnp.ndarray
    inc_k: int
    level: int = 0
    lane: object = None


def lane_shape(n: int, m: int) -> tuple[int, int]:
    """(n_pad, m_pad) lane bucket for a graph with n vertices / m edges."""
    return (bucket_pad(n, BATCH_MIN_N), bucket_pad(2 * m, BATCH_MIN_E))


def make_request(g: PaddedGraph, pos0, sched, seed: int, *, level: int = 0,
                 lane: object = None) -> RefineRequest:
    """Re-pad one level to its lane bucket and attach the incidence table."""
    n_pad, m_pad = lane_shape(g.n, g.m)
    g2 = packing.repad_graph(g, n_pad, m_pad)
    inc, k = packing.incidence_table(g2, INC_K_MAX)
    if inc is None:               # hub-heavy lane: flat-scatter attraction
        with io_boundary():
            inc, k = jnp.zeros((n_pad, 0), jnp.int32), 0
    return RefineRequest(g=g2, pos0=packing.repad_rows(pos0, n_pad),
                         sched=sched, seed=seed, inc=inc, inc_k=k,
                         level=int(level), lane=lane)


def group_key(req: RefineRequest) -> tuple:
    """Shape-bucket grouping key: requests with equal keys share one
    compiled batched program (and one device dispatch per wave)."""
    s = req.sched
    cap = s.cap if s.mode == "neighbor" else 1
    return (s.engine, req.g.n_pad, req.g.m_pad, cap, req.inc_k, s.mode,
            s.grid_dim, s.cell_cap)


# padding occupancy — the direct measurement of fragmentation loss: the
# fraction of each dispatched [lanes, n_pad]/[lanes, m_pad] batch volume
# holding TRUE vertices/edge-slots rather than pow2 padding. Labeled by
# the shape bucket (and by lane bucket for the lane axis).
OCC_VERTICES = obs_metrics.REGISTRY.gauge(
    "gila_wave_padding_occupancy_vertices",
    "True vertices / (lanes * n_pad) of the last dispatch per bucket",
    "ratio")
OCC_EDGES = obs_metrics.REGISTRY.gauge(
    "gila_wave_padding_occupancy_edges",
    "True directed edge slots / (lanes * m_pad) of the last dispatch",
    "ratio")
OCC_LANES = obs_metrics.REGISTRY.gauge(
    "gila_wave_lane_occupancy",
    "Live lanes / pow2 lane bucket of the last dispatch per bucket",
    "ratio")


def _record_occupancy(reqs: list["RefineRequest"], lanes: int) -> None:
    n_pad, m_pad = reqs[0].g.n_pad, reqs[0].g.m_pad
    bucket = f"n{n_pad}_e{m_pad}"
    OCC_VERTICES.set(sum(r.g.n for r in reqs) / (lanes * n_pad),
                     bucket=bucket)
    OCC_EDGES.set(sum(2 * r.g.m for r in reqs) / (lanes * m_pad),
                  bucket=bucket)
    OCC_LANES.set(len(reqs) / lanes, bucket=bucket)


def _build_refine_many(mode: str, grid_dim: int, cell_cap: int, inc_k: int,
                       engine: str = "gila"):
    """Build the jitted batched refinement over ``[B, n_pad]`` lanes for
    ``engine`` — a thin dispatch into the engine registry (core/engine.py;
    the flat-index lowering rationale is documented on
    ``GilaEngine.build_refine_many``), kept here so the gilalint jaxpr
    audit and tests keep one stable entry point."""
    return engines.get_engine(engine).build_refine_many(
        mode, grid_dim, cell_cap, inc_k)


def cached_refine_many(reqs: list[RefineRequest], nbrs: list[tuple], *,
                       ideal_len: float, rep_const: float,
                       min_dist: float = 1e-3, lanes_min: int = 8):
    """(cache_key, fn, fresh, args) for one batched shape-bucket group.

    ``nbrs`` is the per-request (nbr_idx, nbr_mask) list (dummies for
    non-neighbor modes). Shared by ``refine_level_many`` and the gilalint
    jaxpr audit — the audit traces the production staging path (and
    gilalint R2 statically checks this call site).
    """
    key0 = group_key(reqs[0])
    assert all(group_key(r) == key0 for r in reqs), "mixed group"
    sched0 = reqs[0].sched
    eng = engines.get_engine(sched0.engine)
    b = len(reqs)
    lanes = packing.lane_bucket(b, lanes_min)
    packed = packing.pack_graphs([r.g for r in reqs], lanes=lanes)
    _record_occupancy(reqs, lanes)
    with io_boundary():                     # intentional host→device staging
        pl = lambda a: packing.pad_lanes(a, b, lanes)
        pos0 = pl(jnp.stack([jnp.asarray(r.pos0) for r in reqs]))
        nbr_idx = pl(jnp.stack([ni for ni, _ in nbrs]))
        nbr_mask = pl(jnp.stack([nm for _, nm in nbrs]))
        inc = pl(jnp.stack([r.inc for r in reqs]))
        # dead lanes: iteration budget 0 — they ride through untouched
        iters = jnp.asarray([r.sched.iters for r in reqs] + [0] * (lanes - b),
                            jnp.int32)
        # the per-lane annealing vector [lanes, sched_k] (engine-specific:
        # gila (temp0, decay); stress adds (alpha0, alpha_decay))
        sparams = pl(jnp.asarray([eng.lane_schedule(r.sched) for r in reqs],
                                 jnp.float32))
        params = jnp.asarray([rep_const, ideal_len, min_dist], jnp.float32)
        max_iters = jnp.asarray(max(r.sched.iters for r in reqs), jnp.int32)

    cache_key = ("refine_many", lanes, kernel_backend()) + key0
    fn, fresh = STEP_CACHE.get(
        cache_key,
        lambda: eng.build_refine_many(sched0.mode, sched0.grid_dim,
                                      sched0.cell_cap, reqs[0].inc_k))
    args = (pos0, packed.g.src, packed.g.dst, packed.g.vmask, packed.g.emask,
            packed.g.mass, packed.g.ewt, nbr_idx, nbr_mask, inc, iters,
            sparams, params, max_iters)
    return cache_key, fn, fresh, args


def refine_level_many(reqs: list[RefineRequest], *, ideal_len: float,
                      rep_const: float, min_dist: float = 1e-3,
                      lanes_min: int = 8,
                      lanes_cap: int | None = None) -> list[jnp.ndarray]:
    """Run one shape-bucket group of refinements as a single device program.

    All requests must share ``group_key``. Returns the per-request refined
    positions (lane-padded shape [n_pad, 2]), in request order.

    ``lanes_cap`` bounds the lane bucket of a single dispatch: an oversized
    group is split into ≤ lanes_cap chunks (lanes are arithmetically
    independent, so chunking is bit-exact). A long-lived engine
    (serve/engine.py) sets this so its lane-bucket spectrum is CLOSED —
    pow2 buckets in [lanes_min, lanes_cap] — and a mid-flight join can
    never mint a fresh lane-bucket compile once those buckets are warm.
    """
    assert reqs
    if lanes_cap is not None and len(reqs) > lanes_cap:
        out = []
        for i in range(0, len(reqs), lanes_cap):
            out.extend(refine_level_many(
                reqs[i:i + lanes_cap], ideal_len=ideal_len,
                rep_const=rep_const, min_dist=min_dist,
                lanes_min=lanes_min, lanes_cap=lanes_cap))
        return out
    mode = reqs[0].sched.mode
    eng = engines.get_engine(reqs[0].sched.engine)

    # per-lane engine state (host neighbor-list build for neighbor mode,
    # same code path + seed as the single-graph driver so the lists — and
    # hence the forces — match)
    if mode == "neighbor":
        with PHASES.phase("refine"):
            nbrs = [eng.init_state(r.g, r.sched, r.seed) for r in reqs]
    else:
        z = eng.init_state(reqs[0].g, reqs[0].sched, reqs[0].seed)
        nbrs = [z] * len(reqs)

    key, fn, fresh, args = cached_refine_many(
        reqs, nbrs, ideal_len=ideal_len, rep_const=rep_const,
        min_dist=min_dist, lanes_min=lanes_min)
    # span brackets the existing dispatch + sync only (no added syncs)
    t0 = time.perf_counter()
    with obs_trace.span("refine_many.dispatch", cat="device", key=key,
                        fresh=fresh, lanes=len(reqs),
                        engine=reqs[0].sched.engine):
        out = fn(*args)
        out.block_until_ready()
    PHASES.add("compile" if fresh else "refine", time.perf_counter() - t0)
    REFINE_DISPATCHES.inc(engine=reqs[0].sched.engine, path="many")
    b = len(reqs)
    with io_boundary():                     # egress: unpack the live lanes
        return [out[i] for i in range(b)]
