"""GiLA — the single-level distributed force-directed refinement (paper §3.4).

Fruchterman–Reingold forces where the repulsive term of vertex v is
restricted to its k-hop neighborhood N_v(k) (the paper's locality
principle). Two TPU-native realizations of the repulsion:

  * ``exact``    — tiled all-pairs N-body (used when n is small, i.e. the
                   coarse levels; dispatches to the Pallas kernel on TPU,
                   to the jnp reference elsewhere);
  * ``neighbor`` — padded k-hop neighbor lists built once per level by
                   controlled-flooding expansion (GiLA floods *positions*
                   every iteration because a Giraph vertex cannot store the
                   set; the set itself is topology-only, so we materialize
                   it once and gather positions per iteration — identical
                   forces, strictly less communication);
  * ``grid``     — grid-bucketed approximate repulsion (flat Barnes–Hut,
                   kernels/grid_force): exact forces within the 3×3 cell
                   neighborhood, per-cell aggregates beyond. Positions are
                   rebinned every iteration inside the layout loop, so the
                   spatial structure tracks the moving layout; used on fine
                   levels where even capped neighbor lists are too coarse
                   or too slow.

The per-level schedule of k follows the paper exactly:
k = 6 (m<1e3), 5 (m<5e3), 4 (m<1e4), 3 (m<1e5), 2 (m<1e6), 1 (m≥1e6).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.graph import PaddedGraph, edge_gather, to_csr, unique_edges


def paper_k_schedule(m: int) -> int:
    """k(m) exactly as tuned in paper §3.4."""
    if m < 1_000:
        return 6
    if m < 5_000:
        return 5
    if m < 10_000:
        return 4
    if m < 100_000:
        return 3
    if m < 1_000_000:
        return 2
    return 1


@dataclasses.dataclass(frozen=True)
class GilaParams:
    """Force-model parameters for one level."""
    ideal_len: float = 1.0       # base ideal edge length L
    rep_const: float = 1.0       # repulsion strength C (f_r = C·m_u·m_v·L²/d)
    iters: int = 100
    temp0: float = 1.0           # initial max displacement
    temp_decay: float = 0.97     # multiplicative cooling per iteration
    min_dist: float = 1e-3


# -- k-hop neighbor lists (controlled flooding, topology-only) ----------------

def khop_neighbors(edges: np.ndarray, n: int, k: int, cap: int,
                   seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Padded k-hop neighbor lists via iterated expansion with random
    subsampling above ``cap`` (GiLA's flooding with bounded message load).

    Fully vectorized over (vertex, neighbor) pair arrays — the previous
    per-vertex Python loop was O(n) host work per level and dominated
    mid-level setup time. Per round: the frontier pairs (v, u) expand to
    u's CSR neighborhood with ``np.repeat`` range arithmetic, candidates
    are deduplicated and checked against the accumulated sets via sorted
    ``v·(n+1)+u`` keys, and each vertex admits a uniform random sample of
    its remaining room (rank-by-random-priority within the vertex group —
    equivalent to the old per-vertex ``rng.choice`` without replacement).
    Deterministic in ``seed``; the random stream differs from the old
    loop's, and each vertex's list is returned in ascending id order.

    Returns (idx[n, cap] int32 with sentinel n, mask[n, cap] bool).
    """
    rng = np.random.default_rng(seed)
    row_ptr, col = to_csr(edges, n)
    deg = np.diff(row_ptr).astype(np.int64)
    col = col.astype(np.int64)
    base = n + 1                      # (v, u) pair → unique int64 key

    def per_vertex_sample(v, u, room_of):
        """Keep a uniform random sample of ≤ room_of[v] pairs per vertex
        (rank candidates by random priority within each vertex group)."""
        pri = rng.random(v.size)
        order = np.lexsort((pri, v))
        sv, su = v[order], u[order]
        rank = np.arange(sv.size) - np.searchsorted(sv, sv, side="left")
        keep = rank < room_of[sv]
        return sv[keep], su[keep]

    # hop 1: the CSR pairs themselves, subsampled to cap where deg > cap
    src_v = np.repeat(np.arange(n, dtype=np.int64), deg)
    cv, cu = per_vertex_sample(src_v, col, np.full(n, cap, np.int64))
    counts = np.bincount(cv, minlength=n)
    cur_keys = np.sort(cv * base + cu)
    fv, fu = cv, cu                   # frontier: last round's additions

    for _ in range(k - 1):
        room_of = cap - counts
        act = room_of[fv] > 0 if fv.size else np.zeros(0, bool)
        fv, fu = fv[act], fu[act]
        if fv.size == 0:
            break
        # expand each frontier pair (v, u) to u's whole neighborhood
        d_u = deg[fu]
        tot = int(d_u.sum())
        if tot == 0:
            break
        ev = np.repeat(fv, d_u)
        idx_ = (np.repeat(row_ptr[fu], d_u)
                + (np.arange(tot) - np.repeat(np.cumsum(d_u) - d_u, d_u)))
        ew = col[idx_]
        ok = ev != ew
        keys = np.unique(ev[ok] * base + ew[ok])          # dedup candidates
        # drop pairs already collected (cur_keys is sorted + unique)
        pos = np.searchsorted(cur_keys, keys)
        pos = np.minimum(pos, max(cur_keys.size - 1, 0))
        fresh = (keys != cur_keys[pos]) if cur_keys.size else \
            np.ones(keys.size, bool)
        keys = keys[fresh]
        if keys.size == 0:
            break
        av, au = per_vertex_sample(keys // base, keys % base, room_of)
        counts = counts + np.bincount(av, minlength=n)
        cur_keys = np.sort(np.concatenate([cur_keys, av * base + au]))
        fv, fu = av, au

    allv, allu = cur_keys // base, cur_keys % base        # sorted by (v, u)
    rank = np.arange(allv.size) - np.searchsorted(allv, allv, side="left")
    idx = np.full((n, cap), n, dtype=np.int32)
    mask = np.zeros((n, cap), dtype=bool)
    idx[allv, rank] = allu
    mask[allv, rank] = True
    return idx, mask


def pad_neighbors(idx: np.ndarray, mask: np.ndarray, n_pad: int
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pad [n,cap] lists up to [n_pad,cap] with sentinel n_pad."""
    n, cap = idx.shape
    out = np.full((n_pad, cap), n_pad, dtype=np.int32)
    out[:n] = np.where(mask, idx, n_pad)
    om = np.zeros((n_pad, cap), dtype=bool)
    om[:n] = mask
    return jnp.asarray(out), jnp.asarray(om)


# -- forces -------------------------------------------------------------------

def _repulsion_exact(pos, mass, vmask, C, L, min_dist):
    """All-pairs FR repulsion (jnp reference; Pallas kernel in kernels/nbody)."""
    from repro.kernels.nbody import ops as nbody_ops
    return nbody_ops.nbody_repulsion(pos, mass, vmask, C, L, min_dist)


def _repulsion_neighbors(pos, mass, nbr_idx, nbr_mask, vmask, C, L, min_dist):
    from repro.kernels.neighbor_force import ops as nf_ops
    return nf_ops.neighbor_repulsion(pos, mass, nbr_idx, nbr_mask, vmask,
                                     C, L, min_dist)


def _repulsion_grid(pos, mass, vmask, C, L, min_dist, grid_dim, cell_cap):
    """Grid-bucketed approximation (kernels/grid_force); rebins per call."""
    from repro.kernels.grid_force import ops as grid_ops
    return grid_ops.grid_repulsion(pos, mass, vmask, C, L, min_dist,
                                   grid_dim=grid_dim, cell_cap=cell_cap)


def _attraction(g: PaddedGraph, pos, L, min_dist):
    """FR attraction along edges with per-edge desired length ℓ_e = w_e·L:
    f_a(d) = d² / ℓ_e, directed toward the neighbor."""
    n_pad = g.n_pad
    pos_src = edge_gather(g, pos)
    pos_dst = pos[jnp.clip(g.dst, 0, n_pad - 1)]
    delta = pos_src - pos_dst                       # pull dst toward src
    dist = jnp.sqrt(jnp.sum(delta * delta, axis=1) + min_dist ** 2)
    ell = jnp.maximum(g.ewt, 1e-6) * L
    f = (dist * dist) / ell                         # FR: d²/ℓ
    vec = delta / dist[:, None] * f[:, None]
    vec = jnp.where(g.emask[:, None], vec, 0.0)
    out = jax.ops.segment_sum(vec, g.dst, num_segments=n_pad + 1)
    return out[:n_pad]


@partial(jax.jit, static_argnames=("mode", "grid_dim", "cell_cap"))
def gila_forces(g: PaddedGraph, pos, nbr_idx, nbr_mask, params_arr,
                mode: str = "neighbor", grid_dim: int = 0, cell_cap: int = 0):
    """Total force per vertex; ``params_arr = [C, L, min_dist]`` (traced).

    ``grid_dim``/``cell_cap`` are the static grid parameters for
    ``mode="grid"`` (pick them with ``kernels.grid_force.choose_grid``)."""
    C, L, min_dist = params_arr[0], params_arr[1], params_arr[2]
    if mode == "exact":
        rep = _repulsion_exact(pos, g.mass, g.vmask, C, L, min_dist)
    elif mode == "grid":
        rep = _repulsion_grid(pos, g.mass, g.vmask, C, L, min_dist,
                              grid_dim, cell_cap)
    else:
        rep = _repulsion_neighbors(pos, g.mass, nbr_idx, nbr_mask, g.vmask,
                                   C, L, min_dist)
    att = _attraction(g, pos, L, min_dist)
    return rep + att


def layout_iteration(g: PaddedGraph, pos, nbr_idx, nbr_mask, params_arr,
                     temp, *, mode: str, grid_dim: int = 0, cell_cap: int = 0):
    """One GiLA iteration: forces + cooling displacement clamp (shared by
    ``gila_layout`` and the bucketed cached step in core/bucketing.py)."""
    f = gila_forces(g, pos, nbr_idx, nbr_mask, params_arr, mode=mode,
                    grid_dim=grid_dim, cell_cap=cell_cap)
    norm = jnp.sqrt(jnp.sum(f * f, axis=1) + 1e-12)
    step = jnp.minimum(norm, temp)
    pos = pos + f / norm[:, None] * step[:, None]
    return jnp.where(g.vmask[:, None], pos, 0.0)


@partial(jax.jit, static_argnames=("mode", "iters", "grid_dim", "cell_cap"))
def gila_layout(g: PaddedGraph, pos0, nbr_idx, nbr_mask, *, mode: str,
                iters: int, temp0: float, temp_decay: float,
                ideal_len: float, rep_const: float, min_dist: float = 1e-3,
                grid_dim: int = 0, cell_cap: int = 0):
    """Run ``iters`` force iterations with a cooling displacement clamp.

    In ``mode="grid"`` the spatial binning happens inside ``gila_forces``,
    i.e. vertices are rebinned on every iteration of the loop.

    This is the exact-shape path: ``iters`` (and ``g.n``/``g.m``) are
    static, so every distinct level retraces. The multilevel driver uses
    the bucketed, compile-cached equivalent in core/bucketing.py unless
    ``LayoutConfig.bucketing=False``."""
    params_arr = jnp.asarray([rep_const, ideal_len, min_dist], jnp.float32)

    def body(i, carry):
        pos, temp = carry
        pos = layout_iteration(g, pos, nbr_idx, nbr_mask, params_arr, temp,
                               mode=mode, grid_dim=grid_dim, cell_cap=cell_cap)
        return pos, temp * temp_decay

    pos, _ = jax.lax.fori_loop(0, iters, body,
                               (pos0, jnp.asarray(temp0, jnp.float32)))
    return pos


def random_init(g: PaddedGraph, scale: float, seed: int = 0) -> jnp.ndarray:
    """Uniform initial positions, derived per-vertex (utils/prng.py) so the
    draw for a real vertex does not depend on the padding bucket."""
    from repro.utils.prng import uniform2_per_vertex
    from repro.utils.transfer import io_boundary
    with io_boundary():                 # staging: seed + id table → device
        key = jax.random.PRNGKey(seed)
        ids = jnp.arange(g.n_pad, dtype=jnp.int32)
        pos = uniform2_per_vertex(key, ids, minval=-scale, maxval=scale)
        return jnp.where(g.vmask[:, None], pos, 0.0)


def build_level_neighbors(g: PaddedGraph, k: int, cap: int, seed: int = 0
                          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Host-side k-hop list construction for a padded graph."""
    edges = unique_edges(g)
    idx, mask = khop_neighbors(edges, g.n, k, cap, seed)
    return pad_neighbors(idx, mask, g.n_pad)
