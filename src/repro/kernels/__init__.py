# Pallas TPU kernels for the compute hot spots the paper optimizes:
#   nbody/          — tiled all-pairs Fruchterman-Reingold repulsion
#                     (the single-level layout hot spot, paper §3.4)
#   neighbor_force/ — k-hop neighbor-list force accumulation (GiLA locality)
#   grid_force/     — grid-bucketed approximate repulsion (flat Barnes–Hut:
#                     exact 3×3 near field + per-cell aggregate far field)
#   flash_attention/— blocked causal attention for the LM architecture zoo
# Each subpackage: kernel.py (pl.pallas_call + explicit BlockSpec VMEM
# tiling), ops.py (jit'd wrapper with platform dispatch), ref.py (pure-jnp
# oracle). Kernels are validated on CPU with interpret=True.
