"""Flash attention (forward) — Pallas TPU kernel.

Classic streaming-softmax schedule adapted to the TPU memory hierarchy:
grid = (batch·head, q_blocks, kv_blocks) with the kv dimension innermost
(sequential accumulation); each program holds a (BQ × hd) query tile and a
(BK × hd) KV tile in VMEM, carries the running max/denominator in f32 VMEM
scratch, and writes the normalized output on the last kv step. Causal tiles
above the diagonal are handled by masking — the kernel stays shape-static.

VMEM at BQ=BK=512, hd=128, bf16: q 128K + k 128K + v 128K + acc f32 256K
+ m/l 4K ≈ 0.7 MB per program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # [BQ, hd]
    k = k_ref[0]                                   # [BK, hd]
    v = v_ref[0]
    scale = q.shape[-1] ** -0.5
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # fully-masked rows keep m = -inf; guard the exp shift
    shift = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - shift[:, None])
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - shift), 0.0)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False):
    """q [B,Sq,hd], k/v [B,Sk,hd]; B is the flattened batch·head dim."""
    B, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk)
    grid = (B, Sq // block_q, Sk // block_k)
    kern = functools.partial(_flash_kernel, causal=causal,
                             block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
