"""Jit'd wrapper + dispatch for flash attention.

The model's chunked-XLA attention (models/layers._sdpa) is the portable
path; on TPU this kernel replaces the inner (batch·head)-sliced attention.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "ref", "pallas"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 512, block_k: int = 512):
    """q [B,S,H,hd], k/v [B,S,KV,hd] (GQA) → [B,S,H,hd]."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    mode = _mode()
    # flatten (B, KV, G) → rows; KV heads broadcast over their G q-heads
    qf = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4) \
          .reshape(B * KV * G, Sq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd), G, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd), G, axis=0)
    if mode == "ref" or Sq % 128 or Sk % 128:
        out = flash_attention_ref(qf, kf, vf, causal=causal)
    else:
        bq = min(block_q, Sq)
        bk = min(block_k, Sk)
        out = flash_attention_pallas(qf, kf, vf, causal=causal, block_q=bq,
                                     block_k=bk,
                                     interpret=(mode == "interpret"))
    return out.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4) \
              .reshape(B, Sq, H, hd)
