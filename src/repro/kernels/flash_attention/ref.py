"""Pure-jnp oracle for blocked causal attention (single head-group)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q [B,Sq,hd], k/v [B,Sk,hd] → [B,Sq,hd] (f32 softmax)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kpos = jnp.arange(Sk)[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bqk,bkd->bqd", p, v)
