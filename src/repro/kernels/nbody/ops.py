"""Jit'd wrapper + platform dispatch for the N-body repulsion kernel.

On TPU the Pallas kernel runs natively; elsewhere the pure-jnp reference
executes (XLA fuses it well on CPU). Set ``REPRO_PALLAS=interpret`` to force
the Pallas kernel through the interpreter (used by integration tests).
"""
from __future__ import annotations

import os

import jax

from repro.kernels.nbody.kernel import nbody_repulsion_pallas
from repro.kernels.nbody.ref import nbody_repulsion_ref


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "ref", "pallas"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def nbody_repulsion(pos, mass, vmask, C, L, min_dist):
    mode = _mode()
    if mode == "ref":
        return nbody_repulsion_ref(pos, mass, vmask, C, L, min_dist)
    n = pos.shape[0]
    block = 256 if n % 256 == 0 else (128 if n % 128 == 0 else None)
    if block is None:  # unaligned shapes fall back to the oracle
        return nbody_repulsion_ref(pos, mass, vmask, C, L, min_dist)
    return nbody_repulsion_pallas(pos, mass, vmask, C, L, min_dist,
                                  block_rows=block, block_cols=block,
                                  interpret=(mode == "interpret"))
