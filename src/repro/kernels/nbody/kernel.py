"""Tiled all-pairs FR repulsion — Pallas TPU kernel.

Grid = (row_blocks, col_blocks); each program computes the partial force of
one (BR × BC) tile of the interaction matrix and accumulates into the row
block's output. Rows are the parallel dimension; columns are a reduction
(out block index depends only on i, accumulation guarded by @pl.when(j==0)).

VMEM budget per program (f32): BR·2 + BC·2 + BC + BR·BC·(dx,dy,d2,inv)
≈ 4·BR·BC·4B; BR=BC=256 → ~1.1 MB, well inside a v5e core's VMEM.
The tile math is VPU-elementwise (no MXU contraction is profitable for a
2-D force tile); arithmetic intensity ≈ BR·BC·9 flops / (BR+BC)·16 B reads,
so large tiles keep it compute-bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nbody_kernel(px_ref, w_ref, params_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    C, L, md = params_ref[0], params_ref[1], params_ref[2]
    rows = px_ref[...]            # [BR, 2] — row positions (block over i)
    # column positions travel through the second operand (block over j)
    cols = w_ref[...]             # [BC, 3] — (x, y, weight)
    cx, cy, cw = cols[:, 0], cols[:, 1], cols[:, 2]
    dx = rows[:, 0][:, None] - cx[None, :]
    dy = rows[:, 1][:, None] - cy[None, :]
    d2 = dx * dx + dy * dy + md * md
    inv = (C * L * L) * cw[None, :] / d2
    fx = jnp.sum(dx * inv, axis=1)
    fy = jnp.sum(dy * inv, axis=1)
    out_ref[...] += jnp.stack([fx, fy], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def nbody_repulsion_pallas(pos, mass, vmask, C, L, min_dist, *,
                           block_rows: int = 256, block_cols: int = 256,
                           interpret: bool = False):
    """pos: f32[n,2]; mass: f32[n]; vmask: bool[n] → forces f32[n,2].

    n must be a multiple of the block sizes (callers pad; padded rows have
    weight 0 so they contribute nothing and their output is discarded).
    """
    n = pos.shape[0]
    assert n % block_rows == 0 and n % block_cols == 0, (n, block_rows, block_cols)
    w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)
    cols = jnp.concatenate([pos.astype(jnp.float32), w[:, None]], axis=1)  # [n,3]
    params = jnp.asarray([C, L, min_dist], jnp.float32)

    grid = (n // block_rows, n // block_cols)
    out = pl.pallas_call(
        _nbody_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((block_cols, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.float32), cols, params)
    return jnp.where(vmask[:, None], out, 0.0)
