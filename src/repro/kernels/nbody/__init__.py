from repro.kernels.nbody import ops, ref, kernel
