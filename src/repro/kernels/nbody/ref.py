"""Pure-jnp oracle for the all-pairs FR repulsion kernel.

Force on v:  f_v = Σ_u C · L² · w_u · (pos_v − pos_u) / max(d², ε²)
with w_u = mass_u · vmask_u (source-mass weighting: a coarse sun of mass M
repels like M unit vertices, keeping levels consistent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def nbody_repulsion_ref(pos, mass, vmask, C, L, min_dist):
    w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)
    delta = pos[:, None, :] - pos[None, :, :]            # [n, n, 2]
    d2 = jnp.sum(delta * delta, axis=-1) + min_dist ** 2
    inv = (C * L * L) * w[None, :] / d2                  # [n, n]
    f = jnp.sum(delta * inv[:, :, None], axis=1)         # [n, 2]
    return jnp.where(vmask[:, None], f, 0.0)


def nbody_repulsion_ref_chunked(pos, mass, vmask, C, L, min_dist,
                                chunk: int = 1024):
    """Same oracle, row-chunked with lax.map so peak memory is
    O(chunk · n) instead of O(n²) — usable at 50k+ vertices."""
    n = pos.shape[0]
    npad = (n + chunk - 1) // chunk * chunk
    pp = jnp.pad(pos.astype(jnp.float32), ((0, npad - n), (0, 0)))
    w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)

    def block(rows):                                     # [chunk, 2]
        dx = rows[:, 0][:, None] - pos[:, 0][None, :]
        dy = rows[:, 1][:, None] - pos[:, 1][None, :]
        d2 = dx * dx + dy * dy + min_dist ** 2
        inv = (C * L * L) * w[None, :] / d2
        return jnp.stack([jnp.sum(dx * inv, axis=1),
                          jnp.sum(dy * inv, axis=1)], axis=1)

    f = jax.lax.map(block, pp.reshape(npad // chunk, chunk, 2))
    return jnp.where(vmask[:, None], f.reshape(npad, 2)[:n], 0.0)
