"""Pure-jnp oracle for the all-pairs FR repulsion kernel.

Force on v:  f_v = Σ_u C · L² · w_u · (pos_v − pos_u) / max(d², ε²)
with w_u = mass_u · vmask_u (source-mass weighting: a coarse sun of mass M
repels like M unit vertices, keeping levels consistent).
"""
from __future__ import annotations

import jax.numpy as jnp


def nbody_repulsion_ref(pos, mass, vmask, C, L, min_dist):
    w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)
    delta = pos[:, None, :] - pos[None, :, :]            # [n, n, 2]
    d2 = jnp.sum(delta * delta, axis=-1) + min_dist ** 2
    inv = (C * L * L) * w[None, :] / d2                  # [n, n]
    f = jnp.sum(delta * inv[:, :, None], axis=1)         # [n, 2]
    return jnp.where(vmask[:, None], f, 0.0)
