"""Neighbor-list FR repulsion — Pallas TPU kernel.

The (irregular) gather of neighbor positions happens in XLA, which lowers it
to efficient dynamic-slice streams; the kernel consumes the gathered
[BR, K, 2] tile from VMEM and performs the force math + K-reduction. This op
is memory-bound (≈ 9 flops per 12 gathered bytes), so the kernel's job is to
keep the tile resident and fuse the reduction; BR=128, K≤512 → ≤ 1.5 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _neighbor_kernel(pos_ref, npos_ref, nw_ref, params_ref, out_ref):
    C, L, md = params_ref[0], params_ref[1], params_ref[2]
    p = pos_ref[...]                      # [BR, 2]
    npos = npos_ref[...]                  # [BR, K, 2]
    nw = nw_ref[...]                      # [BR, K]
    dx = p[:, 0][:, None] - npos[:, :, 0]
    dy = p[:, 1][:, None] - npos[:, :, 1]
    d2 = dx * dx + dy * dy + md * md
    inv = (C * L * L) * nw / d2
    out_ref[...] = jnp.stack([jnp.sum(dx * inv, axis=1),
                              jnp.sum(dy * inv, axis=1)], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def neighbor_repulsion_pallas(pos, nbr_pos, nbr_w, C, L, min_dist, *,
                              block_rows: int = 128, interpret: bool = False):
    """pos f32[n,2]; nbr_pos f32[n,K,2]; nbr_w f32[n,K] (0 = masked)."""
    n, K = nbr_w.shape
    assert n % block_rows == 0, (n, block_rows)
    params = jnp.asarray([C, L, min_dist], jnp.float32)
    return pl.pallas_call(
        _neighbor_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, K, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.float32), nbr_pos.astype(jnp.float32),
      nbr_w.astype(jnp.float32), params)
