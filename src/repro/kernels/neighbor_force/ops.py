"""Jit'd wrapper + dispatch for the neighbor-list repulsion kernel."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.neighbor_force.kernel import neighbor_repulsion_pallas
from repro.kernels.neighbor_force.ref import neighbor_repulsion_ref


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "ref", "pallas"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def neighbor_repulsion(pos, mass, nbr_idx, nbr_mask, vmask, C, L, min_dist):
    mode = _mode()
    if mode == "ref":
        return neighbor_repulsion_ref(pos, mass, nbr_idx, nbr_mask, vmask,
                                      C, L, min_dist)
    # XLA-side gather (padded tables make the sentinel row contribute 0)
    w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)
    pos_p = jnp.concatenate([pos, jnp.zeros((1, 2), pos.dtype)], axis=0)
    w_p = jnp.concatenate([w, jnp.zeros((1,), w.dtype)], axis=0)
    nbr_pos = pos_p[nbr_idx]
    nbr_w = jnp.where(nbr_mask, w_p[nbr_idx], 0.0)
    n = pos.shape[0]
    block = 128 if n % 128 == 0 else None
    if block is None:
        return neighbor_repulsion_ref(pos, mass, nbr_idx, nbr_mask, vmask,
                                      C, L, min_dist)
    f = neighbor_repulsion_pallas(pos, nbr_pos, nbr_w, C, L, min_dist,
                                  block_rows=block,
                                  interpret=(mode == "interpret"))
    return jnp.where(vmask[:, None], f, 0.0)
