from repro.kernels.neighbor_force import ops, ref, kernel
