"""Pure-jnp oracle for the k-hop neighbor-list repulsion.

``nbr_idx[n, K]`` holds up to K neighbor indices per vertex (sentinel = n);
the gather uses a (n+1)-row padded position/mass table so sentinel rows
contribute zero force.
"""
from __future__ import annotations

import jax.numpy as jnp


def _pad_tables(pos, mass, vmask):
    w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)
    pos_p = jnp.concatenate([pos, jnp.zeros((1, 2), pos.dtype)], axis=0)
    w_p = jnp.concatenate([w, jnp.zeros((1,), w.dtype)], axis=0)
    return pos_p, w_p


def neighbor_repulsion_ref(pos, mass, nbr_idx, nbr_mask, vmask, C, L, min_dist):
    pos_p, w_p = _pad_tables(pos, mass, vmask)
    npos = pos_p[nbr_idx]                                 # [n, K, 2]
    nw = jnp.where(nbr_mask, w_p[nbr_idx], 0.0)           # [n, K]
    delta = pos[:, None, :] - npos                        # [n, K, 2]
    d2 = jnp.sum(delta * delta, axis=-1) + min_dist ** 2
    inv = (C * L * L) * nw / d2
    f = jnp.sum(delta * inv[:, :, None], axis=1)
    return jnp.where(vmask[:, None], f, 0.0)
