from repro.kernels.grid_force.ops import (bin_vertices, choose_grid,
                                          grid_repulsion, grid_cell_size)
from repro.kernels.grid_force.kernel import grid_near_pallas, grid_far_pallas
from repro.kernels.grid_force.ref import grid_near_ref, grid_far_ref
