"""Grid-bucketed FR repulsion — Pallas TPU kernels.

Two kernels back the grid (flat Barnes–Hut) mode:

  * ``grid_near_pallas`` — exact near field. One program per block of
    cells; each cell's bucket ([cap] resident vertices, gathered by XLA
    into a dense [n_cells, cap, 2] tile) interacts with the concatenated
    buckets of its 3×3 cell neighborhood ([n_cells, 9·cap, 3] as
    (x, y, weight); missing/padded slots carry weight 0 so they contribute
    nothing). Self-pairs have delta = 0 and therefore zero force, exactly
    as in the all-pairs kernel.

  * ``grid_far_pallas`` — two-set tiled n-body: every vertex against every
    cell aggregate (mass at centroid). Identical tiling to kernels/nbody
    but with independent row (vertices) and column (cells) sets; columns
    are the reduction dimension, rows the parallel one.

VMEM per near program (f32): Bc·cap·2 + Bc·9cap·3 + Bc·cap·9cap·4 temps
≈ 16·Bc·cap²·9 B; cap = 64, Bc = 1 → ~0.6 MB, comfortably inside a core's
VMEM, so Bc up to 8 is safe for the default caps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _near_kernel(rows_ref, cols_ref, params_ref, out_ref):
    C, L, md = params_ref[0], params_ref[1], params_ref[2]
    rows = rows_ref[...]                  # [Bc, cap, 2]
    cols = cols_ref[...]                  # [Bc, 9·cap, 3] — (x, y, w)
    dx = rows[:, :, 0][:, :, None] - cols[:, None, :, 0]
    dy = rows[:, :, 1][:, :, None] - cols[:, None, :, 1]
    d2 = dx * dx + dy * dy + md * md
    inv = (C * L * L) * cols[:, None, :, 2] / d2
    fx = jnp.sum(dx * inv, axis=2)
    fy = jnp.sum(dy * inv, axis=2)
    out_ref[...] = jnp.stack([fx, fy], axis=2)


@functools.partial(jax.jit, static_argnames=("block_cells", "interpret"))
def grid_near_pallas(rows_pos, nbr_pos, nbr_w, C, L, min_dist, *,
                     block_cells: int = 1, interpret: bool = False):
    """rows_pos f32[nc, cap, 2]; nbr_pos f32[nc, 9·cap, 2];
    nbr_w f32[nc, 9·cap] (0 = masked) → forces f32[nc, cap, 2]."""
    nc, cap, _ = rows_pos.shape
    K = nbr_w.shape[1]
    assert nc % block_cells == 0, (nc, block_cells)
    cols = jnp.concatenate([nbr_pos.astype(jnp.float32),
                            nbr_w.astype(jnp.float32)[..., None]], axis=2)
    params = jnp.asarray([C, L, min_dist], jnp.float32)
    return pl.pallas_call(
        _near_kernel,
        grid=(nc // block_cells,),
        in_specs=[
            pl.BlockSpec((block_cells, cap, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_cells, K, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_cells, cap, 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, cap, 2), jnp.float32),
        interpret=interpret,
    )(rows_pos.astype(jnp.float32), cols, params)


def _far_kernel(rows_ref, cols_ref, params_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    C, L, md = params_ref[0], params_ref[1], params_ref[2]
    rows = rows_ref[...]                  # [BR, 2]
    cols = cols_ref[...]                  # [BC, 3]
    dx = rows[:, 0][:, None] - cols[:, 0][None, :]
    dy = rows[:, 1][:, None] - cols[:, 1][None, :]
    d2 = dx * dx + dy * dy + md * md
    inv = (C * L * L) * cols[:, 2][None, :] / d2
    out_ref[...] += jnp.stack([jnp.sum(dx * inv, axis=1),
                               jnp.sum(dy * inv, axis=1)], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "interpret"))
def grid_far_pallas(pos, cell_xyw, C, L, min_dist, *,
                    block_rows: int = 256, block_cols: int = 256,
                    interpret: bool = False):
    """pos f32[n, 2] vertices; cell_xyw f32[nc, 3] cell (x, y, mass)
    aggregates → aggregate-field forces f32[n, 2]."""
    n = pos.shape[0]
    nc = cell_xyw.shape[0]
    assert n % block_rows == 0 and nc % block_cols == 0, \
        (n, nc, block_rows, block_cols)
    params = jnp.asarray([C, L, min_dist], jnp.float32)
    return pl.pallas_call(
        _far_kernel,
        grid=(n // block_rows, nc // block_cols),
        in_specs=[
            pl.BlockSpec((block_rows, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((block_cols, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.float32), cell_xyw.astype(jnp.float32), params)
