"""Pure-jnp oracles for the grid-force kernels.

``grid_near_ref`` / ``grid_far_ref`` mirror kernel.py's near/far kernels
operation-for-operation on the SAME pre-gathered inputs, so the Pallas
kernels must match them to float tolerance (asserted in
tests/test_grid_force.py). The end-to-end approximation quality of the
composed op (binning + near + far) is bounded against the all-pairs
oracle separately.
"""
from __future__ import annotations

import jax.numpy as jnp


def grid_near_ref(rows_pos, nbr_pos, nbr_w, C, L, min_dist):
    """rows_pos [nc, cap, 2]; nbr_pos [nc, K, 2]; nbr_w [nc, K] →
    [nc, cap, 2] near-field forces (masked slots have weight 0)."""
    dx = rows_pos[:, :, 0][:, :, None] - nbr_pos[:, :, 0][:, None, :]
    dy = rows_pos[:, :, 1][:, :, None] - nbr_pos[:, :, 1][:, None, :]
    d2 = dx * dx + dy * dy + min_dist ** 2
    inv = (C * L * L) * nbr_w[:, None, :] / d2
    return jnp.stack([jnp.sum(dx * inv, axis=2),
                      jnp.sum(dy * inv, axis=2)], axis=2)


def grid_far_ref(pos, cell_xyw, C, L, min_dist):
    """pos [n, 2] vs cell aggregates [nc, 3] (x, y, mass) → [n, 2]."""
    dx = pos[:, 0][:, None] - cell_xyw[:, 0][None, :]
    dy = pos[:, 1][:, None] - cell_xyw[:, 1][None, :]
    d2 = dx * dx + dy * dy + min_dist ** 2
    inv = (C * L * L) * cell_xyw[:, 2][None, :] / d2
    return jnp.stack([jnp.sum(dx * inv, axis=1),
                      jnp.sum(dy * inv, axis=1)], axis=1)
