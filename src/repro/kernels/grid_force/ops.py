"""Grid-bucketed approximate repulsion — binning, composition, dispatch.

``grid_repulsion`` is the op the layout engine calls (mode="grid" in
core/gila.py). Everything here is jit-compatible with static
``grid_dim``/``cell_cap``, so the whole op — including the per-iteration
rebinning — lives inside ``gila_layout``'s fori_loop.

Pipeline per call (positions move every iteration, so all of it reruns):

  1. *Bin*: bounding box of the valid vertices → uniform ``G×G`` grid;
     each vertex gets a cell id. A stable argsort + searchsorted assigns a
     within-cell rank; vertices with rank < ``cell_cap`` land in a dense
     bucket table [G²+1, cap] (sentinel row/slots = n). Overflow vertices
     keep repelling through the aggregate terms (see 3).
  2. *Near field* (exact): every bucketed vertex vs the buckets of its
     3×3 cell neighborhood — the Pallas kernel in kernel.py (jnp oracle in
     ref.py elsewhere).
  3. *Far field* (approximate): every vertex vs per-cell aggregates
     (total mass at centroid) of ALL cells, minus the same aggregate field
     of its 9 near cells (those were counted exactly), plus the
     aggregate field of near-cell *overflow* vertices (those were NOT in
     the buckets), Plummer-softened by the overflow set's RMS radius — a
     point stand-in for a spread-out set misbehaves at near range.
     Overflow vertices themselves additionally receive the softened
     in-bucket aggregates of their 9 near cells (they have no bucket row,
     so the exact kernel never sees them). With no overflow this is the
     textbook flat Barnes–Hut with opening radius one cell; with overflow
     it degrades gracefully instead of dropping mass.

Approximation error: far cells are ≥ 1 cell width away, so the opening
angle is ≤ 1 and the centroid approximation of the 1/d force field is
accurate to a few percent; tests/test_grid_force.py bounds it end-to-end
against the all-pairs oracle on random and clustered inputs.

Set ``REPRO_PALLAS=interpret|ref|pallas`` to force a backend (same
convention as the other kernel subsystems).

The helpers here are also the building blocks of the *sharded* grid path
(`core/distributed.py:sharded_grid_force`, DESIGN.md §4.3): binning and the
per-cell raw sums are local per shard and psum'd over the vertex axes;
``far_corrections`` then composes the far field from the replicated sums,
and ``near_field`` resolves the 3×3 near field per shard.
"""
from __future__ import annotations

import math
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.grid_force.kernel import grid_near_pallas, grid_far_pallas
from repro.kernels.grid_force.ref import grid_near_ref, grid_far_ref

_EPS = 1e-12


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "ref", "pallas"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def choose_grid(n: int, *, avg_occupancy: int = 12,
                multiple_of: int = 1) -> tuple[int, int]:
    """Static (grid_dim, cell_cap) for an n-vertex level.

    grid_dim targets ``avg_occupancy`` vertices per cell; cell_cap covers
    the mean plus ~6σ of a Poisson cell load (overflow beyond the cap is
    handled by the aggregate terms, so the cap bounds *work*, not
    correctness). ``multiple_of`` rounds grid_dim to a multiple — the
    sharded halo variant bands the grid rows over the vertex shards and
    needs grid_dim % vsize == 0 (core/distributed.py).
    """
    n = max(int(n), 1)
    G = int(round(math.sqrt(n / avg_occupancy)))
    G = max(2, min(G, 128))
    if multiple_of > 1:
        G = max(multiple_of, G // multiple_of * multiple_of)
    avg = n / (G * G)
    cap = int(math.ceil(avg + 6.0 * math.sqrt(avg) + 8.0))
    cap = min(max(8, (cap + 7) // 8 * 8), n)
    return G, max(cap, 1)


def grid_cell_size(lo, hi, grid_dim: int, xp=jnp):
    """Canonical G×G cell size over box (lo, hi): ``max(hi-lo, 1e-6)/G``
    in f32. Every consumer that must agree bit-for-bit on which cell/tile
    a point lands in (``bin_vertices``, ``cell_centers_from_box``, the
    serving layer's tile binning and viewport cover — serve/tiles.py,
    serve/query.py) derives the cell size HERE, with ``xp`` numpy or
    jax.numpy, instead of re-implementing the formula."""
    return xp.maximum(hi - lo, xp.float32(1e-6)) / xp.float32(grid_dim)


def _neighbor_table(G: int) -> np.ndarray:
    """[G²+1, 9] cell ids of each cell's 3×3 neighborhood (incl. itself);
    out-of-range neighbors and the sentinel row point at cell G²."""
    nc = G * G
    cells = np.arange(nc)
    cx, cy = cells % G, cells // G
    cols = []
    for oy in (-1, 0, 1):
        for ox in (-1, 0, 1):
            nx, ny = cx + ox, cy + oy
            ok = (0 <= nx) & (nx < G) & (0 <= ny) & (ny < G)
            cols.append(np.where(ok, ny * G + nx, nc))
    table = np.stack(cols, axis=1).astype(np.int32)
    return np.concatenate([table, np.full((1, 9), nc, np.int32)], axis=0)


def bin_vertices(pos, vmask, grid_dim: int, cell_cap: int, *, box=None):
    """Bucket vertices into a G×G grid over their bounding box.

    Returns (cid[n] int32 with sentinel G², bucket[G²+1, cap] int32 with
    sentinel n, inb[n] bool — vertex made it into its cell's bucket).

    ``box`` optionally fixes the binning box to ``(lo[2], hi[2])`` instead of
    the vertices' own bounding box — the serving tile pyramid
    (serve/tiles.py) bins every zoom band against the same global box so
    tile keys align across bands. Bucket slot order is the vertices' array
    order (the argsort is stable), which is how the pyramid builder turns
    the slots into a top-k: it presents vertices sorted by descending mass.
    """
    n = pos.shape[0]
    G, cap = grid_dim, cell_cap
    nc = G * G
    if box is None:
        big = jnp.float32(3e38)
        lo = jnp.min(jnp.where(vmask[:, None], pos, big), axis=0)
        hi = jnp.max(jnp.where(vmask[:, None], pos, -big), axis=0)
    else:
        lo, hi = box
    cell = grid_cell_size(lo, hi, G)
    ij = jnp.clip(jnp.floor((pos - lo) / cell), 0, G - 1).astype(jnp.int32)
    cid = jnp.where(vmask, ij[:, 1] * G + ij[:, 0], nc).astype(jnp.int32)

    order = jnp.argsort(cid)                       # stable in JAX
    sc = cid[order]
    rank = jnp.arange(n) - jnp.searchsorted(sc, sc, side="left")
    ok = (rank < cap) & (sc < nc)
    bucket = jnp.full((nc + 1, cap), n, jnp.int32)
    bucket = bucket.at[jnp.where(ok, sc, nc),
                       jnp.where(ok, rank, 0)].set(
        jnp.where(ok, order.astype(jnp.int32), n))
    inb = jnp.zeros((n,), bool).at[order].set(ok)
    return cid, bucket, inb


def _cell_aggregates(pos, w, cid, nc: int):
    """(mass[nc+1], weighted-sum[nc+1, 2], centroid[nc+1, 2]) per cell
    (sentinel row is empty)."""
    M = jax.ops.segment_sum(w, cid, num_segments=nc + 1)
    S = jax.ops.segment_sum(w[:, None] * pos, cid, num_segments=nc + 1)
    return M, S, S / jnp.maximum(M, _EPS)[:, None]


def _agg_field_9(pos, mu9, m9, C, L, md, r9=None):
    """Aggregate force field of each vertex's 9 gathered cells:
    pos [n, 2], mu9 [n, 9, 2], m9 [n, 9] → [n, 2]. ``r9`` optionally
    Plummer-softens each aggregate by its RMS radius (a point mass cannot
    faithfully stand in for a spread-out set at near range — softening by
    the set's extent bounds the spurious 1/d² spike)."""
    dx = pos[:, 0][:, None] - mu9[..., 0]
    dy = pos[:, 1][:, None] - mu9[..., 1]
    d2 = dx * dx + dy * dy + md * md
    if r9 is not None:
        d2 = d2 + r9 * r9
    inv = (C * L * L) * m9 / d2
    return jnp.stack([jnp.sum(dx * inv, axis=1),
                      jnp.sum(dy * inv, axis=1)], axis=1)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _far_all_cells(pos, cell_xyw, C, L, md, mode: str):
    """Aggregate field of ALL cells on every vertex (backend-dispatched)."""
    n, nc = pos.shape[0], cell_xyw.shape[0]
    if mode == "ref":
        chunk = 512
        npad = _round_up(n, chunk)
        pp = jnp.pad(pos, ((0, npad - n), (0, 0)))
        out = jax.lax.map(
            lambda blk: grid_far_ref(blk, cell_xyw, C, L, md),
            pp.reshape(npad // chunk, chunk, 2))
        return out.reshape(npad, 2)[:n]
    npad, ncpad = _round_up(n, 128), _round_up(nc, 128)
    pp = jnp.pad(pos, ((0, npad - n), (0, 0)))
    cp = jnp.pad(cell_xyw, ((0, ncpad - nc), (0, 0)))   # padded cells: w = 0
    out = grid_far_pallas(pp, cp, C, L, md, block_rows=128, block_cols=128,
                          interpret=(mode == "interpret"))
    return out[:n]


def near_field(rows_pos, nbr_pos, nbr_w, C, L, min_dist, *,
               backend: str | None = None, block_cells: int = 1):
    """Backend-dispatched near-field evaluation (kernel.py vs ref.py).

    rows_pos [R, cap, 2] vs nbr_pos/nbr_w [R, K, 2]/[R, K] → [R, cap, 2].
    The sharded path calls this per shard with cap = 1 (one row per local
    vertex); the single-device path with cap = cell_cap (one row per cell).
    """
    backend = backend or _mode()
    if backend == "ref":
        return grid_near_ref(rows_pos, nbr_pos, nbr_w, C, L, min_dist)
    return grid_near_pallas(rows_pos, nbr_pos, nbr_w, C, L, min_dist,
                            block_cells=block_cells,
                            interpret=(backend == "interpret"))


def cell_centers_from_box(lo, hi, grid_dim: int):
    """Geometric centers of the G×G cells over bounding box (lo, hi):
    [G²+1, 2] (sentinel row = 0). Shared by the single-device op and the
    sharded SPMD body (which derives lo/hi by pmin/pmax) so the centered
    second moments stay bit-identical across the two paths."""
    G = grid_dim
    cell = grid_cell_size(lo, hi, G)
    ids = jnp.arange(G * G)
    xy = jnp.stack([ids % G, ids // G], axis=1).astype(jnp.float32)
    ctr = lo[None, :] + (xy + 0.5) * cell[None, :]
    return jnp.concatenate([ctr, jnp.zeros((1, 2), jnp.float32)], axis=0)


def cell_centers(pos, vmask, grid_dim: int):
    """Geometric centers of the G×G cells over the vertices' bounding box.
    Second moments are accumulated about these — |pos − center| is at most
    a cell diagonal, so the RMS-radius cancellation ``Q/M − |µ|²`` stays
    well-conditioned in f32 no matter where the box sits (a cluster far
    from the origin would otherwise lose the radius entirely)."""
    big = jnp.float32(3e38)
    lo = jnp.min(jnp.where(vmask[:, None], pos, big), axis=0)
    hi = jnp.max(jnp.where(vmask[:, None], pos, -big), axis=0)
    return cell_centers_from_box(lo, hi, grid_dim)


def _rms(Q, M, S, centers):
    """Per-cell RMS radius from mass M, weighted-position sum S and the
    second moment Q accumulated about ``centers``."""
    mu_rel = S / jnp.maximum(M, _EPS)[:, None] - centers
    return jnp.sqrt(jnp.maximum(
        Q / jnp.maximum(M, _EPS) - jnp.sum(mu_rel * mu_rel, axis=1), 0.0))


def far_corrections(pos, w_out, cid, inb,
                    M_full, S_full, Q_full, M_out, S_out, Q_out,
                    C, L, md, *, grid_dim: int, centers):
    """Near-9 / overflow correction terms of the far field, computed from
    *replicated* per-cell raw sums (mass M, weighted position sum S, second
    moment Q about the cell ``centers``; ``_full`` = every vertex,
    ``_out`` = bucket-overflow only).

    Returns the per-vertex force to ADD to the all-cells aggregate term
    (``_far_all_cells``): subtract the 9 near cells' full aggregates (those
    pairs were counted exactly by the near field), add back the softened
    overflow aggregates, and — for overflow vertices only, which the exact
    kernel never sees — the softened in-bucket aggregates of the 9 cells.
    Shared verbatim between ``grid_repulsion`` and the sharded SPMD body in
    ``core/distributed.py`` (there the raw sums arrive via psum).
    """
    G = grid_dim
    nc = G * G
    mu_full = S_full / jnp.maximum(M_full, _EPS)[:, None]
    mu_out = S_out / jnp.maximum(M_out, _EPS)[:, None]
    r_out = _rms(Q_out, M_out, S_out, centers)
    M_in = M_full - M_out
    S_in = S_full - S_out
    mu_in = S_in / jnp.maximum(M_in, _EPS)[:, None]
    r_in = _rms(Q_full - Q_out, M_in, S_in, centers)

    table = jnp.asarray(_neighbor_table(G))
    near9 = table[cid]                                      # [n, 9]
    f = -_agg_field_9(pos, mu_full[near9], M_full[near9], C, L, md)
    # overflow add-back: an overflowed vertex sits inside its own cell's
    # overflow aggregate, which would exert a spurious self-force — remove
    # its own (mass, position) from the center cell (table column 4) before
    # evaluating.
    m9 = M_out[near9]
    mu9 = mu_out[near9]
    m_self = w_out                                          # w if overflowed
    m_adj = jnp.maximum(M_out[cid] - m_self, 0.0)
    s_adj = S_out[cid] - m_self[:, None] * pos
    m9 = m9.at[:, 4].set(m_adj)
    mu9 = mu9.at[:, 4].set(s_adj / jnp.maximum(m_adj, _EPS)[:, None])
    f += _agg_field_9(pos, mu9, m9, C, L, md, r9=r_out[near9])
    # an overflowed vertex also never met the *bucketed* vertices of its
    # 3×3 neighborhood (it has no bucket row of its own) — restore them as
    # softened in-bucket aggregates, gated to overflow vertices only
    f_bkt = _agg_field_9(pos, mu_in[near9], M_in[near9], C, L, md,
                         r9=r_in[near9])
    return f + jnp.where(inb, 0.0, 1.0)[:, None] * f_bkt


def grid_repulsion(pos, mass, vmask, C, L, min_dist, *,
                   grid_dim: int, cell_cap: int):
    """Grid-approximated FR repulsion: pos f32[n, 2] → forces f32[n, 2].

    Static ``grid_dim``/``cell_cap`` (pick with ``choose_grid``); all array
    work is traced, so the op rebins on every call.
    """
    assert grid_dim >= 2 and cell_cap >= 1, (grid_dim, cell_cap)
    mode = _mode()
    n = pos.shape[0]
    G, cap = grid_dim, cell_cap
    nc = G * G
    pos = pos.astype(jnp.float32)
    w = jnp.where(vmask, mass, 0.0).astype(jnp.float32)

    cid, bucket, inb = bin_vertices(pos, vmask, G, cap)
    M_full, S_full, mu_full = _cell_aggregates(pos, w, cid, nc)
    w_out = jnp.where(inb, 0.0, w)
    M_out, S_out, _ = _cell_aggregates(pos, w_out, cid, nc)
    # per-cell second moments → RMS radii (for near-range softening),
    # accumulated about the cell centers (see cell_centers on conditioning)
    centers = cell_centers(pos, vmask, G)
    q = jnp.sum((pos - centers[cid]) ** 2, axis=1)
    Q_full = jax.ops.segment_sum(w * q, cid, num_segments=nc + 1)
    Q_out = jax.ops.segment_sum(w_out * q, cid, num_segments=nc + 1)

    # -- near field: exact within the 3×3 neighborhood ------------------------
    table = jnp.asarray(_neighbor_table(G))                 # [nc+1, 9]
    pos_p = jnp.concatenate([pos, jnp.zeros((1, 2), jnp.float32)], axis=0)
    w_p = jnp.concatenate([w, jnp.zeros((1,), jnp.float32)], axis=0)
    rows_idx = bucket[:nc]                                  # [nc, cap]
    rows_pos = pos_p[rows_idx]
    nbr_bucket = bucket[table[:nc]].reshape(nc, 9 * cap)
    nbr_pos = pos_p[nbr_bucket]
    nbr_w = w_p[nbr_bucket]
    near = near_field(rows_pos, nbr_pos, nbr_w, C, L, min_dist, backend=mode)
    f_near = jnp.zeros((n + 1, 2), jnp.float32).at[
        rows_idx.reshape(-1)].set(near.reshape(-1, 2))[:n]

    # -- far field: all-cell aggregates, near cells swapped for overflow ------
    cell_xyw = jnp.concatenate([mu_full[:nc], M_full[:nc, None]], axis=1)
    f_far = _far_all_cells(pos, cell_xyw, C, L, min_dist, mode)
    f_far += far_corrections(pos, w_out, cid, inb,
                             M_full, S_full, Q_full, M_out, S_out, Q_out,
                             C, L, min_dist, grid_dim=G, centers=centers)

    return jnp.where(vmask[:, None], f_near + f_far, 0.0)


# public aliases for the sharded path (core/distributed.py) and tests
neighbor_table = _neighbor_table
cell_aggregates = _cell_aggregates
agg_field_9 = _agg_field_9
far_all_cells = _far_all_cells
backend_mode = _mode
