"""Benchmark graph generators — the families used by the paper's benchmarks.

RegularGraphs families (Table 1): grids (plain / deficient / crossing-free
variants approximated), cylinders, trees, snowflakes, spiders, sierpinski
triangles, flowers, random grids; RealGraphs/BigGraphs stand-ins: scale-free
(Barabási–Albert), random (GNP), road-like lattices with deletions, and
Delaunay triangulations / triangulated meshes ("hugetric"-like).

All generators return ``(edges[m,2] int64 unique undirected, n)`` in host
numpy; they are deterministic given ``seed``.
"""
from __future__ import annotations

import numpy as np


def _dedup(edges: np.ndarray, n: int) -> np.ndarray:
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    e = e[e[:, 0] != e[:, 1]]
    e = np.sort(e, axis=1)
    e = np.unique(e, axis=0)
    assert e.size == 0 or (e.min() >= 0 and e.max() < n)
    return e


def grid(w: int, h: int, *, periodic_w: bool = False, periodic_h: bool = False,
         drop_frac: float = 0.0, seed: int = 0):
    """w×h lattice. ``periodic_w`` → cylinder; both → torus; ``drop_frac`` →
    'deficient' grids (Grid_*_df families)."""
    idx = np.arange(w * h).reshape(h, w)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    if periodic_w:
        e.append(np.stack([idx[:, -1].ravel(), idx[:, 0].ravel()], 1))
    if periodic_h:
        e.append(np.stack([idx[-1, :].ravel(), idx[0, :].ravel()], 1))
    edges = np.concatenate(e, axis=0)
    if drop_frac > 0:
        rng = np.random.default_rng(seed)
        keep = rng.random(edges.shape[0]) >= drop_frac
        edges = edges[keep]
    return _dedup(edges, w * h), w * h


def cylinder(circ: int, length: int):
    return grid(circ, length, periodic_w=True)


def torus(w: int, h: int):
    return grid(w, h, periodic_w=True, periodic_h=True)


def tree(arity: int, depth: int):
    """Complete ``arity``-ary tree of the given depth (tree_06_03 ≈ (6,3))."""
    edges = []
    nodes = [0]
    nxt = 1
    for _ in range(depth):
        new_nodes = []
        for u in nodes:
            for _ in range(arity):
                edges.append((u, nxt))
                new_nodes.append(nxt)
                nxt += 1
        nodes = new_nodes
    return _dedup(np.array(edges or np.zeros((0, 2))), nxt), nxt


def snowflake(arms: int, seg: int, depth: int):
    """Koch-flake-like tree: a path of ``seg`` from the center per arm, each
    tip sprouting ``arms`` recursive sub-arms ``depth`` times (m = n-1)."""
    edges = []
    nxt = 1

    def arm(root, d):
        nonlocal nxt
        cur = root
        for _ in range(seg):
            edges.append((cur, nxt))
            cur = nxt
            nxt += 1
        if d > 0:
            for _ in range(arms):
                arm(cur, d - 1)

    for _ in range(arms):
        arm(0, depth)
    return _dedup(np.array(edges), nxt), nxt


def spider(legs: int, leglen: int, hub_cliques: int = 2):
    """Spider: a clique-ish hub of ``hub_cliques*legs`` chords + ``legs``
    paths of length ``leglen`` (spider_A ≈ (8, 11, 2))."""
    edges = []
    nxt = 1
    hub = [0]
    for i in range(legs):
        cur = 0
        for _ in range(leglen):
            edges.append((cur, nxt))
            cur = nxt
            nxt += 1
        hub.append(cur)
    rng = np.random.default_rng(7)
    for _ in range(hub_cliques * legs):
        a, b = rng.choice(len(hub), size=2, replace=False)
        edges.append((hub[a], hub[b]))
    return _dedup(np.array(edges), nxt), nxt


def sierpinski(level: int):
    """Sierpinski triangle graph of the given level."""
    # corners of the initial triangle
    tri = [(0, 1, 2)]
    edges = {(0, 1), (0, 2), (1, 2)}
    nxt = 3
    mid: dict[tuple[int, int], int] = {}

    def midpoint(a, b):
        nonlocal nxt
        key = (min(a, b), max(a, b))
        if key not in mid:
            mid[key] = nxt
            nxt += 1
        return mid[key]

    for _ in range(level):
        new_tri = []
        new_edges = set()
        mid.clear()
        for (a, b, c) in tri:
            ab, bc, ca = midpoint(a, b), midpoint(b, c), midpoint(c, a)
            new_tri += [(a, ab, ca), (ab, b, bc), (ca, bc, c), (ab, bc, ca)]
            for (u, v) in [(a, ab), (ab, b), (b, bc), (bc, c), (c, ca), (ca, a),
                           (ab, bc), (bc, ca), (ca, ab)]:
                new_edges.add((min(u, v), max(u, v)))
        tri = [t for t in new_tri]
        edges = new_edges
    return _dedup(np.array(sorted(edges)), nxt), nxt


def flower(petals: int, petal_size: int):
    """Flower: ``petals`` cliques of ``petal_size`` sharing one center vertex
    (flower_001 ≈ dense small graph, flower_005 larger)."""
    edges = []
    nxt = 1
    for _ in range(petals):
        verts = [0] + list(range(nxt, nxt + petal_size))
        nxt += petal_size
        for i in range(len(verts)):
            for j in range(i + 1, len(verts)):
                edges.append((verts[i], verts[j]))
    return _dedup(np.array(edges), nxt), nxt


def random_regular(n: int, d: int, seed: int = 0):
    """d-regular-ish random graph via stub matching (grid_rnd_* stand-in)."""
    rng = np.random.default_rng(seed)
    stubs = np.repeat(np.arange(n), d)
    rng.shuffle(stubs)
    pairs = stubs.reshape(-1, 2)
    return _dedup(pairs, n), n


def gnp(n: int, avg_deg: float, seed: int = 0):
    """Erdős–Rényi with expected average degree ``avg_deg``."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    e = rng.integers(0, n, size=(int(m * 1.15) + 8, 2))
    e = _dedup(e, n)
    return e[:m], n


def scale_free(n: int, m_attach: int = 2, seed: int = 0):
    """Barabási–Albert preferential attachment (RealGraphs are mostly
    scale-free: amazon/DBLP/asic). Vectorized repeated-endpoint sampling."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list[int] = list(range(m_attach))
    edges = []
    for v in range(m_attach, n):
        # sample m_attach targets preferentially from the repeated list
        idx = rng.integers(0, len(repeated), size=m_attach)
        ts = {repeated[i] for i in idx}
        while len(ts) < m_attach:
            ts.add(int(rng.integers(0, v)))
        for t in ts:
            edges.append((v, t))
            repeated.append(t)
        repeated.extend([v] * m_attach)
    return _dedup(np.array(edges), n), n


def delaunay(n: int, seed: int = 0):
    """Delaunay triangulation of random points (delaunay_n22 family)."""
    from scipy.spatial import Delaunay  # available in this container
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    tri = Delaunay(pts)
    s = tri.simplices
    edges = np.concatenate([s[:, [0, 1]], s[:, [1, 2]], s[:, [0, 2]]], axis=0)
    return _dedup(edges, n), n


def tri_mesh(w: int, h: int):
    """Triangulated grid ('hugetric' family): lattice + one diagonal/cell."""
    e_grid, n = grid(w, h)
    idx = np.arange(w * h).reshape(h, w)
    diag = np.stack([idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()], 1)
    return _dedup(np.concatenate([e_grid, diag], 0), n), n


def road_like(w: int, h: int, drop_frac: float = 0.25, seed: int = 3):
    """Sparse lattice with deletions — roadNet-like degree distribution."""
    return grid(w, h, drop_frac=drop_frac, seed=seed)


def with_degree_one_fringe(edges: np.ndarray, n: int, frac: float = 0.2,
                           seed: int = 0):
    """Attach ``frac*n`` degree-1 vertices (exercises pruning/reinsertion)."""
    rng = np.random.default_rng(seed)
    k = int(frac * n)
    hosts = rng.integers(0, n, size=k)
    fringe = np.arange(n, n + k)
    extra = np.stack([hosts, fringe], axis=1)
    return _dedup(np.concatenate([edges, extra], axis=0), n + k), n + k


def from_cli(name: str, args) -> tuple[np.ndarray, int, tuple]:
    """Resolve a generator by name with CLI-style float args (integral
    floats become ints): returns (edges, n, resolved_args). Shared by the
    layout/serve CLIs and examples so argument coercion lives once."""
    gen = globals()[name]
    gargs = tuple(int(a) if float(a).is_integer() else float(a)
                  for a in args)
    edges, n = gen(*gargs)
    return edges, n, gargs


# Named suite approximating the paper's benchmark families --------------------

def regulargraphs_suite(small: bool = False):
    """(name, edges, n) tuples — families of the paper's RegularGraphs set.

    ``small=True`` returns reduced sizes for CI-speed tests.
    """
    if small:
        specs = [
            ("grid_8_8", lambda: grid(8, 8)),
            ("tree_3_3", lambda: tree(3, 3)),
            ("cyl_8_6", lambda: cylinder(8, 6)),
            ("sierp_3", lambda: sierpinski(3)),
            ("snow_3_2_1", lambda: snowflake(3, 2, 1)),
            ("spider_4_5", lambda: spider(4, 5)),
            ("flower_4_5", lambda: flower(4, 5)),
            ("rnd_64_4", lambda: random_regular(64, 4, 1)),
        ]
    else:
        specs = [
            ("karate_like", lambda: gnp(34, 4.6, 2)),
            ("grid_20_20", lambda: grid(20, 20)),
            ("grid_20_20_df", lambda: grid(20, 20, drop_frac=0.05, seed=1)),
            ("grid_40_40", lambda: grid(40, 40)),
            ("cylinder_010", lambda: cylinder(10, 10)),
            ("cylinder_032", lambda: cylinder(32, 31)),
            ("tree_06_03", lambda: tree(6, 3)),
            ("tree_06_04", lambda: tree(6, 4)),
            ("snowflake_A", lambda: snowflake(3, 4, 2)),
            ("snowflake_B", lambda: snowflake(4, 5, 3)),
            ("spider_A", lambda: spider(8, 11, 2)),
            ("spider_B", lambda: spider(25, 39, 1)),
            ("sierpinski_04", lambda: sierpinski(4)),
            ("sierpinski_06", lambda: sierpinski(6)),
            ("flower_001", lambda: flower(14, 14)),
            ("grid_rnd_032", lambda: random_regular(985, 4, 5)),
            ("3elt_like", lambda: delaunay(4720, 11)),
            ("uk_like", lambda: road_like(80, 61, 0.30, 4)),
        ]
    return [(name, *fn()) for name, fn in specs]
