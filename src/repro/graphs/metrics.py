"""Layout quality metrics from the paper: CRE and NELD (+ stress).

CRE  = average number of edge crossings per edge (Table 1).
NELD = edge-length standard deviation / mean edge length (Table 1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.graph import PaddedGraph, canonical_edges, unique_edges, to_csr


def edge_lengths(pos: np.ndarray, edges: np.ndarray) -> np.ndarray:
    p, q = pos[edges[:, 0]], pos[edges[:, 1]]
    return np.linalg.norm(p - q, axis=1)


def neld(pos: np.ndarray, edges: np.ndarray) -> float:
    """Normalized edge-length standard deviation."""
    ln = edge_lengths(np.asarray(pos), np.asarray(edges))
    mu = float(ln.mean())
    if mu <= 0:
        return 0.0
    return float(ln.std() / mu)


@partial(jax.jit, static_argnames=())
def _cross_block(p1, p2, q1, q2, share):
    """Count proper crossings between segment block A (p1,p2)[B,2] and block
    B (q1,q2)[C,2]; ``share`` masks pairs sharing an endpoint (+ diagonal)."""
    def orient(a, b, c):
        # sign of cross product (b-a) x (c-a): [B,C]
        return ((b[:, None, 0] - a[:, None, 0]) * (c[None, :, 1] - a[:, None, 1])
                - (b[:, None, 1] - a[:, None, 1]) * (c[None, :, 0] - a[:, None, 0]))

    d1 = orient(p1, p2, q1)
    d2 = orient(p1, p2, q2)
    d3 = orient(q1, q2, p1).T
    d4 = orient(q1, q2, p2).T
    proper = (d1 * d2 < 0) & (d3 * d4 < 0)
    return jnp.sum(jnp.where(share, False, proper))


def count_crossings(pos: np.ndarray, edges: np.ndarray, block: int = 2048) -> int:
    """Exact proper-crossing count, blocked O(m^2). Use for m ≲ 5e4.

    The edge list is canonicalized first (``canonical_edges``): duplicate
    and reversed duplicate edges would otherwise each be counted against
    every segment they cross, silently inflating CRE. Only PROPER
    (transversal) crossings count — collinear overlaps and shared-endpoint
    touches are excluded by construction, per the paper's metric.
    """
    return _count_crossings_canonical(pos, canonical_edges(edges), block)


def _count_crossings_canonical(pos, edges: np.ndarray, block: int) -> int:
    """Crossing count over an ALREADY-canonical edge list (``cre`` shares
    one canonicalization pass between the count and its denominator)."""
    pos = np.asarray(pos, dtype=np.float32)
    m = edges.shape[0]
    if m < 2:
        return 0
    P1 = jnp.asarray(pos[edges[:, 0]])
    P2 = jnp.asarray(pos[edges[:, 1]])
    E = jnp.asarray(edges)
    total = 0
    for i0 in range(0, m, block):
        i1 = min(i0 + block, m)
        for j0 in range(i0, m, block):
            j1 = min(j0 + block, m)
            ei, ej = E[i0:i1], E[j0:j1]
            share = ((ei[:, 0, None] == ej[None, :, 0]) |
                     (ei[:, 0, None] == ej[None, :, 1]) |
                     (ei[:, 1, None] == ej[None, :, 0]) |
                     (ei[:, 1, None] == ej[None, :, 1]))
            if i0 == j0:
                # only strict upper triangle within the diagonal block
                ii = jnp.arange(i1 - i0)
                share = share | (ii[:, None] >= ii[None, :])
            c = _cross_block(P1[i0:i1], P2[i0:i1], P1[j0:j1], P2[j0:j1], share)
            total += int(c)
    return total


def cre(pos: np.ndarray, edges: np.ndarray, block: int = 2048) -> float:
    """Average crossings per edge (each crossing involves 2 edges).

    Normalized by the CANONICAL edge count, so a list carrying duplicates
    or both edge directions reports the same CRE as its deduplicated form.
    """
    edges = canonical_edges(edges)
    m = int(edges.shape[0])
    if m == 0:
        return 0.0
    return 2.0 * _count_crossings_canonical(pos, edges, block) / m


def bfs_distances(edges: np.ndarray, n: int, sources: np.ndarray) -> np.ndarray:
    """Host BFS from each source → int32[len(sources), n] (unreachable=-1)."""
    row_ptr, col = to_csr(edges, n)
    out = np.full((len(sources), n), -1, dtype=np.int32)
    for si, s in enumerate(sources):
        dist = out[si]
        dist[s] = 0
        frontier = [int(s)]
        d = 0
        while frontier:
            d += 1
            nxt = []
            for u in frontier:
                for v in col[row_ptr[u]:row_ptr[u + 1]]:
                    if dist[v] < 0:
                        dist[v] = d
                        nxt.append(int(v))
            frontier = nxt
    return out


def sampled_stress(pos: np.ndarray, edges: np.ndarray, n: int,
                   n_sources: int = 16, seed: int = 0) -> float:
    """Normalized stress over BFS distances from sampled sources."""
    rng = np.random.default_rng(seed)
    sources = rng.choice(n, size=min(n_sources, n), replace=False)
    D = bfs_distances(edges, n, sources)
    P = np.asarray(pos)[:n]
    num = den = 0.0
    for si in range(D.shape[0]):
        d = D[si]
        ok = d > 0
        geo = np.linalg.norm(P - P[sources[si]], axis=1)[ok]
        gd = d[ok].astype(np.float64)
        # scale-invariant stress: optimal scalar fit
        alpha = float((geo * gd).sum() / max((geo * geo).sum(), 1e-12))
        num += float((((alpha * geo) - gd) ** 2 / gd ** 2).sum())
        den += float(ok.sum())
    return num / max(den, 1.0)


def quality_report(g: PaddedGraph, pos, max_cre_edges: int = 40000) -> dict:
    """CRE/NELD/stress summary used by the quality benchmark."""
    edges = unique_edges(g)
    posn = np.asarray(pos)[: g.n_pad]
    rep = {
        "n": g.n, "m": g.m,
        "neld": neld(posn, edges),
        "stress": sampled_stress(posn, edges, g.n),
    }
    rep["cre"] = cre(posn, edges) if g.m <= max_cre_edges else float("nan")
    return rep
