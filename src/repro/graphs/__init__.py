from repro.graphs.graph import (PaddedGraph, build_graph, unique_edges, to_csr,
                                push_max, push_sum_vec, edge_gather)
from repro.graphs import generators, metrics, io
