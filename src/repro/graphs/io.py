"""Edge-list IO + SVG export for computed layouts.

``load_edgelist`` is a chunked streaming reader: the old ``np.loadtxt``
path materialized the whole file as float64 text — the ingestion
bottleneck for 10M-edge inputs — while this one parses bounded line
chunks straight to int64 and understands the formats the paper's inputs
come in (``#``/``%`` comment lines, MatrixMarket ``.mtx`` headers with
1-based indices, trailing weight columns, empty files).
"""
from __future__ import annotations

import numpy as np

# number of data lines parsed per chunk — bounds peak memory at
# ~CHUNK_LINES · line length bytes regardless of file size
CHUNK_LINES = 1 << 20


def save_edgelist(path: str, edges: np.ndarray) -> None:
    np.savetxt(path, np.asarray(edges, dtype=np.int64), fmt="%d")


def _parse_chunk(lines: list[str]) -> np.ndarray:
    # first three tokens per line (split stops after 4 — columns past the
    # weight never get tokenized); float64 since weights/ids arrive as
    # text. Lines shorter than the chunk's widest row pad with weight 1.
    toks = [ln.split(None, 3)[:3] for ln in lines]
    width = max(len(t) for t in toks)
    if width > 1:
        toks = [t + ["1"] * (width - len(t)) for t in toks]
    return np.array(toks, dtype=np.float64)


def load_edgelist(path: str, weights: bool = False):
    """Stream an edge list (or MatrixMarket ``.mtx``) → (edges[m, 2], n).

    * ``#`` and ``%`` lines are comments (``%%MatrixMarket`` included);
    * a MatrixMarket body is detected by its ``%%MatrixMarket`` banner:
      the first data line is the ``rows cols nnz`` size line (skipped) and
      entries are 1-based (shifted to 0-based);
    * with ``weights=True`` the return is ``(edges, n, w)`` where ``w`` is
      the third column as float32 (1.0 where a line has no weight);
      otherwise the weight column is parsed and dropped;
    * an empty file yields ``(int64[0, 2], 0)`` without warnings.
    """
    is_mtx = False
    size_line_pending = False
    chunks: list[np.ndarray] = []
    n_header = 0
    buf: list[str] = []

    def flush():
        if buf:
            chunks.append(_parse_chunk(buf))
            buf.clear()

    with open(path) as f:
        for line in f:
            s = line.strip()
            if not s:
                continue
            if s[0] in "#%":
                if s.lower().startswith("%%matrixmarket"):
                    is_mtx = True
                    size_line_pending = True
                continue
            if size_line_pending:          # mtx "rows cols nnz" size line
                dims = s.split()
                n_header = max(int(dims[0]), int(dims[1]))
                size_line_pending = False
                continue
            buf.append(s)
            if len(buf) >= CHUNK_LINES:
                flush()
    flush()

    if not chunks:
        if weights:
            return np.zeros((0, 2), np.int64), n_header, np.zeros(0, np.float32)
        return np.zeros((0, 2), np.int64), n_header
    width = max(c.shape[1] for c in chunks)
    if width > 1:
        # normalize to one width: a chunk entirely of 2-column lines inside
        # a weighted file pads with weight 1
        chunks = [c if c.shape[1] == width else
                  np.hstack([c, np.ones((len(c), width - c.shape[1]))])
                  for c in chunks]
    raw = np.concatenate(chunks, axis=0)
    if raw.shape[1] == 1:
        # flat one-number-per-line files pair consecutive values, as the
        # old loadtxt(...).reshape(-1, 2) path did (odd counts still raise)
        raw = raw.reshape(-1, 2)
    e = raw[:, :2].astype(np.int64)
    w = (raw[:, 2].astype(np.float32) if raw.shape[1] > 2
         else np.ones(len(raw), np.float32))
    if is_mtx:
        e -= 1
    n = int(e.max()) + 1 if e.size else 0
    if weights:
        return e, max(n, n_header), w
    return e, max(n, n_header)


def save_svg(path: str, pos: np.ndarray, edges: np.ndarray,
             size: int = 1000, stroke: float = 0.6,
             max_edges: int = 200_000) -> None:
    """Minimal SVG writer so layouts can be inspected without matplotlib.

    Above ``max_edges`` the drawn edges are deterministically subsampled
    (evenly spaced in edge order) — a 10M-edge SVG is unusable and takes
    minutes to write; the cap is noted in the file's header comment.
    """
    pos = np.asarray(pos, dtype=np.float64)
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    m_total = len(edges)
    if m_total > max_edges:
        keep = np.unique(np.linspace(0, m_total - 1, max_edges)
                         .astype(np.int64))
        edges = edges[keep]
    lo, hi = pos.min(axis=0), pos.max(axis=0)
    span = np.maximum(hi - lo, 1e-9)
    P = (pos - lo) / span * (size - 20) + 10
    lines = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{size}" height="{size}">']
    if len(edges) < m_total:
        lines.append(f'<!-- edge cap: drew {len(edges)} of {m_total} edges '
                     f'(deterministic evenly-spaced subsample) -->')
    lines.append('<rect width="100%" height="100%" fill="white"/>')
    for (u, v) in edges:
        lines.append(
            f'<line x1="{P[u,0]:.1f}" y1="{P[u,1]:.1f}" '
            f'x2="{P[v,0]:.1f}" y2="{P[v,1]:.1f}" '
            f'stroke="black" stroke-width="{stroke}" stroke-opacity="0.5"/>')
    r = max(1.0, 3.0 - 0.0002 * len(pos))
    for p in P:
        lines.append(f'<circle cx="{p[0]:.1f}" cy="{p[1]:.1f}" r="{r:.1f}" fill="#c33"/>')
    lines.append("</svg>")
    with open(path, "w") as f:
        f.write("\n".join(lines))
