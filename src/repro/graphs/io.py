"""Edge-list IO + SVG export for computed layouts."""
from __future__ import annotations

import numpy as np


def save_edgelist(path: str, edges: np.ndarray) -> None:
    np.savetxt(path, np.asarray(edges, dtype=np.int64), fmt="%d")


def load_edgelist(path: str) -> tuple[np.ndarray, int]:
    e = np.loadtxt(path, dtype=np.int64).reshape(-1, 2)
    return e, int(e.max()) + 1 if e.size else 0


def save_svg(path: str, pos: np.ndarray, edges: np.ndarray,
             size: int = 1000, stroke: float = 0.6) -> None:
    """Minimal SVG writer so layouts can be inspected without matplotlib."""
    pos = np.asarray(pos, dtype=np.float64)
    lo, hi = pos.min(axis=0), pos.max(axis=0)
    span = np.maximum(hi - lo, 1e-9)
    P = (pos - lo) / span * (size - 20) + 10
    lines = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{size}" height="{size}">',
             f'<rect width="100%" height="100%" fill="white"/>']
    for (u, v) in np.asarray(edges, dtype=np.int64):
        lines.append(
            f'<line x1="{P[u,0]:.1f}" y1="{P[u,1]:.1f}" '
            f'x2="{P[v,0]:.1f}" y2="{P[v,1]:.1f}" '
            f'stroke="black" stroke-width="{stroke}" stroke-opacity="0.5"/>')
    r = max(1.0, 3.0 - 0.0002 * len(pos))
    for p in P:
        lines.append(f'<circle cx="{p[0]:.1f}" cy="{p[1]:.1f}" r="{r:.1f}" fill="#c33"/>')
    lines.append("</svg>")
    with open(path, "w") as f:
        f.write("\n".join(lines))
