"""Packing B same-bucket graphs into one batched device program.

The multi-graph driver (``core/multilevel.py:multigila_layout_many``) lays
out many user graphs at once by stacking every level that lands in the same
shape bucket into a ``[B, n_pad]`` batched ``PaddedGraph`` and running ONE
vmapped cached refinement step for the whole group (core/bucketing.py).
This module owns the two array plumbing pieces that make that safe:

  * ``repad_graph`` — re-pad a ``PaddedGraph`` to a different (n_pad, m_pad),
    rewriting the sentinel indices. Behavior-preserving by the padding-
    invariance contract of PR 4 (per-vertex RNG streams, zero-contribution
    padding rows): the same graph padded to 64 or 256 slots produces
    bit-identical positions for every real vertex. The batched driver uses
    this to drop each lane to the FINEST bucket that fits (floor below the
    single-graph driver's 256), which is where most of the batched speedup
    comes from — a 45-vertex coarse level costs 64² pair interactions per
    lane instead of 256².
  * ``pack_graphs`` / ``pad_lanes`` — stack same-shape lanes into batched
    arrays and pad the batch axis to a power-of-two lane bucket so the
    number of compiled batched programs stays logarithmic in the largest
    request (the same trick as ``serve/query.py``'s query batches). Dead
    lanes replicate lane 0 with ``iters = 0``, so they are carried through
    the loop untouched and cost (almost) nothing.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.graphs.graph import PaddedGraph, bucket_pad
from repro.utils.transfer import io_boundary


def repad_graph(g: PaddedGraph, n_pad: int, m_pad: int) -> PaddedGraph:
    """Re-pad ``g`` to (n_pad, m_pad), rewriting sentinels to the new n_pad.

    Valid half-edges are compacted to a prefix in their original order
    (graphs built by ``build_graph`` already store them that way, so this
    is the identity permutation and segment-sum accumulation order — and
    hence the float result — is preserved bit-for-bit).
    """
    assert n_pad >= g.n and m_pad >= 2 * g.m, (n_pad, m_pad, g.n, g.m)
    if n_pad == g.n_pad and m_pad == g.m_pad:
        return g
    src_o = np.asarray(g.src)
    dst_o = np.asarray(g.dst)
    em_o = np.asarray(g.emask)
    keep = np.nonzero(em_o)[0]                      # order-preserving compact
    k = keep.size
    assert k <= m_pad, (k, m_pad)

    src = np.full((m_pad,), n_pad, np.int32)
    dst = np.full((m_pad,), n_pad, np.int32)
    ewt = np.ones((m_pad,), np.float32)
    emask = np.zeros((m_pad,), bool)
    src[:k] = src_o[keep]
    dst[:k] = dst_o[keep]
    ewt[:k] = np.asarray(g.ewt)[keep]
    emask[:k] = True

    vmask = np.zeros((n_pad,), bool)
    vmask[: g.n] = np.asarray(g.vmask)[: g.n]
    mass = np.zeros((n_pad,), np.float32)
    mass[: g.n] = np.asarray(g.mass)[: g.n]
    with io_boundary():                 # intentional host→device staging
        return PaddedGraph(src=jnp.asarray(src), dst=jnp.asarray(dst),
                           vmask=jnp.asarray(vmask), emask=jnp.asarray(emask),
                           mass=jnp.asarray(mass), ewt=jnp.asarray(ewt),
                           n=g.n, m=g.m)


def repad_rows(a, n_pad: int):
    """Slice or zero-pad the leading (vertex) axis of ``a`` to ``n_pad``
    rows. Rows past the valid count are padding — their values never reach
    a real vertex (masks/zero weights), so slicing them off or appending
    zeros is behavior-preserving."""
    with io_boundary():                 # intentional host→device staging
        a = jnp.asarray(a)
        if a.shape[0] == n_pad:
            return a
        if a.shape[0] > n_pad:
            return a[:n_pad]
        pad = [(0, n_pad - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, pad)


def incidence_table(g: PaddedGraph, k: int = 32
                    ) -> tuple[jnp.ndarray, int] | tuple[None, int]:
    """int32[n_pad, k] half-edge slots arriving at each vertex (sentinel
    slot = m_pad), or ``(None, max_degree)`` when a vertex's degree exceeds
    the FIXED column count ``k``.

    ``k`` is deliberately a constant, not a bucket of the observed max
    degree: it is part of the batched-refine cache key (core/bucketing.py),
    and the max degree of a random graph family wobbles across pow2
    boundaries from seed to seed — a data-dependent k would mint fresh
    compiles on the warm path.

    Slots are listed in ascending order — the order in which a scatter-add
    (``segment_sum``) applies them — so an unrolled left-associated
    gather+add over the k columns accumulates each vertex's messages in
    exactly the float order of the sequential driver's ``segment_sum``
    (core/bucketing.py uses this to replace the batched scatter, which XLA
    CPU executes ~15× slower than k gathered adds).
    """
    dst = np.asarray(g.dst)
    slots = np.nonzero(np.asarray(g.emask))[0]
    d = dst[slots]
    if d.size == 0:
        with io_boundary():
            return jnp.full((g.n_pad, k), g.m_pad, jnp.int32), k
    counts = np.bincount(d, minlength=g.n_pad)
    dmax = int(counts.max())
    if dmax > k:
        return None, dmax
    order = np.argsort(d, kind="stable")        # stable: slots stay ascending
    ds, ss = d[order], slots[order]
    rank = np.arange(ds.size) - np.searchsorted(ds, ds, side="left")
    inc = np.full((g.n_pad, k), g.m_pad, np.int64)
    inc[ds, rank] = ss
    with io_boundary():                 # intentional host→device staging
        return jnp.asarray(inc, jnp.int32), k


@dataclasses.dataclass
class PackedGraphs:
    """B same-shape lanes stacked into one batched ``PaddedGraph``.

    ``g`` holds ``[B, n_pad]`` / ``[B, m_pad]`` arrays (static n/m zeroed:
    jitted consumers key on padded shapes only); ``b`` is the number of
    REAL lanes — lanes b..B-1 are dead padding.
    """
    g: PaddedGraph
    b: int

    @property
    def lanes(self) -> int:
        return int(self.g.vmask.shape[0])

    @property
    def n_pad(self) -> int:
        return int(self.g.vmask.shape[1])

    @property
    def m_pad(self) -> int:
        return int(self.g.src.shape[1])


def lane_bucket(b: int, minimum: int = 8) -> int:
    """Pow2 batch bucket with a floor: straggler waves (a few hierarchies
    one level deeper than the rest of the batch) reuse the floor-size
    program instead of compiling a fresh B=1/2/4 variant."""
    return bucket_pad(b, minimum)


def pad_lanes(stacked, b: int, lanes: int, dead_value=None):
    """Pad the batch axis of ``stacked`` ([b, ...]) to ``lanes`` rows by
    replicating lane 0 (or ``dead_value``). Dead lanes run with iters=0 in
    the batched step, so replication only keeps shapes/dtypes honest."""
    if b == lanes:
        return stacked
    with io_boundary():                 # intentional host→device staging
        fill = stacked[0:1] if dead_value is None else dead_value
        reps = jnp.concatenate([fill] * (lanes - b), axis=0)
        return jnp.concatenate([stacked, reps], axis=0)


def pack_graphs(gs: list[PaddedGraph], lanes: int | None = None
                ) -> PackedGraphs:
    """Stack same-shape graphs into a batched ``PaddedGraph`` (lane-padded
    to ``lanes``; default = ``lane_bucket(len(gs))``)."""
    assert gs, "pack_graphs needs at least one lane"
    n_pad, m_pad = gs[0].n_pad, gs[0].m_pad
    for g in gs:
        assert (g.n_pad, g.m_pad) == (n_pad, m_pad), \
            "pack_graphs: all lanes must share one shape bucket"
    lanes = lanes if lanes is not None else lane_bucket(len(gs))
    assert lanes >= len(gs)

    def stack(field):
        arr = jnp.stack([getattr(g, field) for g in gs], axis=0)
        return pad_lanes(arr, len(gs), lanes)

    batched = PaddedGraph(src=stack("src"), dst=stack("dst"),
                          vmask=stack("vmask"), emask=stack("emask"),
                          mass=stack("mass"), ewt=stack("ewt"), n=0, m=0)
    return PackedGraphs(g=batched, b=len(gs))
