"""Static-shape padded graph representation for JAX.

Every graph is stored with BOTH directions of each undirected edge so that a
``segment_*`` over ``dst`` aggregates all messages arriving at a vertex —
the dense-array equivalent of a Giraph superstep's message delivery.

Padding convention: invalid vertices/edges use the sentinel index ``n_pad``
(one past the last valid slot). Segment ops therefore use
``num_segments=n_pad + 1`` and drop the last row.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def bucket_pad(x: int, minimum: int = 256) -> int:
    """Next power-of-two ≥ max(x, minimum) — the shape-bucket padding.

    Pow2 buckets give every level of every hierarchy one of O(log n)
    distinct shapes, so jitted per-level programs (keyed on padded shapes,
    core/bucketing.py) are compiled once per bucket and reused across
    levels AND across graphs.
    """
    x = max(int(x), minimum, 1)
    return 1 << (x - 1).bit_length()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedGraph:
    """A padded, undirected graph as JAX arrays.

    Attributes:
      src, dst:   int32[m_pad] — directed half-edges (each undirected edge
                  appears once per direction). Padding rows are (n_pad, n_pad).
      vmask:      bool[n_pad] — valid-vertex mask.
      emask:      bool[m_pad] — valid-half-edge mask.
      mass:       float32[n_pad] — vertex masses (≥1 after pruning).
      ewt:        float32[m_pad] — desired-length weight per half edge
                  (1 on the input graph; coarse graphs get path lengths).
      n, m:       static python ints — number of valid vertices / undirected
                  edges (m_pad == 2 * padded undirected count).
    """
    src: jnp.ndarray
    dst: jnp.ndarray
    vmask: jnp.ndarray
    emask: jnp.ndarray
    mass: jnp.ndarray
    ewt: jnp.ndarray
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_pad(self) -> int:
        return int(self.vmask.shape[0])

    @property
    def m_pad(self) -> int:
        return int(self.src.shape[0])

    # -- degree -------------------------------------------------------------
    def degrees(self) -> jnp.ndarray:
        """int32[n_pad] vertex degrees (valid half-edges per destination)."""
        ones = jnp.where(self.emask, 1, 0)
        deg = jax.ops.segment_sum(ones, self.dst, num_segments=self.n_pad + 1)
        return deg[: self.n_pad]


def build_graph(edges: np.ndarray, n: int, *, n_pad: int | None = None,
                m_pad: int | None = None, mass: np.ndarray | None = None,
                ewt: np.ndarray | None = None, pad_mult: int = 256,
                bucket: bool = False) -> PaddedGraph:
    """Build a PaddedGraph from a unique undirected edge list ``edges[k,2]``.

    Self loops and duplicate edges must already be removed. ``n_pad``/``m_pad``
    default to the sizes rounded up to ``pad_mult``; with ``bucket=True``
    they instead round up to the next power-of-two bucket (``bucket_pad``),
    which the multilevel driver uses to reuse compiled per-level programs
    across levels and graphs (core/bucketing.py).
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    m = int(edges.shape[0])
    if n_pad is None:
        n_pad = (bucket_pad(n, pad_mult) if bucket
                 else max(_round_up(max(n, 1), pad_mult), pad_mult))
    if m_pad is None:
        m_pad = (bucket_pad(2 * m, pad_mult) if bucket
                 else max(_round_up(max(2 * m, 1), pad_mult), pad_mult))
    assert m_pad >= 2 * m and n_pad >= n

    src = np.full((m_pad,), n_pad, dtype=np.int32)
    dst = np.full((m_pad,), n_pad, dtype=np.int32)
    emask = np.zeros((m_pad,), dtype=bool)
    w = np.ones((m_pad,), dtype=np.float32)
    if m:
        both_src = np.concatenate([edges[:, 0], edges[:, 1]]).astype(np.int32)
        both_dst = np.concatenate([edges[:, 1], edges[:, 0]]).astype(np.int32)
        src[: 2 * m] = both_src
        dst[: 2 * m] = both_dst
        emask[: 2 * m] = True
        if ewt is not None:
            ew = np.asarray(ewt, dtype=np.float32).reshape(-1)
            w[: 2 * m] = np.concatenate([ew, ew])
    vmask = np.zeros((n_pad,), dtype=bool)
    vmask[:n] = True
    ms = np.zeros((n_pad,), dtype=np.float32)
    ms[:n] = 1.0 if mass is None else np.asarray(mass, dtype=np.float32)[:n]
    return PaddedGraph(
        src=jnp.asarray(src), dst=jnp.asarray(dst),
        vmask=jnp.asarray(vmask), emask=jnp.asarray(emask),
        mass=jnp.asarray(ms), ewt=jnp.asarray(w), n=n, m=m)


def unique_edges(g: PaddedGraph) -> np.ndarray:
    """Return the (host) unique undirected edge list [m, 2] (src < dst)."""
    src = np.asarray(g.src)[: 2 * g.m]
    dst = np.asarray(g.dst)[: 2 * g.m]
    keep = src < dst
    return np.stack([src[keep], dst[keep]], axis=1).astype(np.int64)


def canonical_edges(edges: np.ndarray) -> np.ndarray:
    """Canonicalize a raw edge list: drop self loops, sort each pair's
    endpoints, and collapse duplicates (including reversed duplicates) —
    the array-level analogue of ``unique_edges``. Metrics that treat edges
    as undirected segments (graphs/metrics.py) canonicalize through this
    first, so a list carrying both (u, v) and (v, u) is not double-counted.
    """
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    e = e[e[:, 0] != e[:, 1]]
    if e.size == 0:
        return e.reshape(0, 2)
    e = np.sort(e, axis=1)
    return np.unique(e, axis=0)


def to_csr(edges: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side CSR (row_ptr[n+1], col_idx[2m]) from unique undirected edges."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    both = np.concatenate([edges, edges[:, ::-1]], axis=0)
    order = np.argsort(both[:, 0], kind="stable")
    both = both[order]
    col = both[:, 1].astype(np.int32)
    counts = np.bincount(both[:, 0], minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return row_ptr, col


# -- message-passing primitives (the Giraph superstep vocabulary) -------------

def edge_gather(g: PaddedGraph, values: jnp.ndarray) -> jnp.ndarray:
    """Per half-edge value read from the SOURCE endpoint (padding → 0-row)."""
    padded = jnp.concatenate(
        [values, jnp.zeros((1,) + values.shape[1:], values.dtype)], axis=0)
    return padded[g.src]


@partial(jax.jit, static_argnames=("combine",))
def push_max(g: PaddedGraph, values: jnp.ndarray, combine: str = "max") -> jnp.ndarray:
    """One superstep: every vertex broadcasts ``values[v]``; each vertex
    aggregates incoming messages with max/sum (padding-safe)."""
    msgs = edge_gather(g, values)
    if combine == "max":
        if jnp.issubdtype(values.dtype, jnp.floating):
            neutral = jnp.finfo(values.dtype).min
        else:
            neutral = jnp.iinfo(values.dtype).min
        msgs = jnp.where(g.emask, msgs, neutral)
        out = jax.ops.segment_max(msgs, g.dst, num_segments=g.n_pad + 1)
        if not jnp.issubdtype(values.dtype, jnp.floating):
            out = jnp.maximum(out, -1)  # empty inbox → -1 ("no message")
    elif combine == "sum":
        msgs = jnp.where(g.emask, msgs, jnp.zeros_like(msgs))
        out = jax.ops.segment_sum(msgs, g.dst, num_segments=g.n_pad + 1)
    else:
        raise ValueError(combine)
    return out[: g.n_pad]


def push_sum_vec(g: PaddedGraph, values: jnp.ndarray) -> jnp.ndarray:
    """Vector-valued sum-combiner superstep: values[n_pad, d] → [n_pad, d]."""
    msgs = edge_gather(g, values)
    msgs = jnp.where(g.emask[:, None], msgs, jnp.zeros_like(msgs))
    out = jax.ops.segment_sum(msgs, g.dst, num_segments=g.n_pad + 1)
    return out[: g.n_pad]
