"""Model assembly: config → params/apply for all 10 assigned architectures.

Layer stacks scan over *layer groups* (one period of the hybrid pattern;
1 layer for homogeneous archs) with params stacked [G, ...] — this keeps
compile time flat in depth and makes the roofline's while-loop trip counts
explicit (see launch/roofline.py). DeepSeekMoE's dense layer 0 is a prefix
outside the scan.

Decode maintains per-group state pytrees (KV caches for "attn" positions,
conv+SSM state for "ssm" positions) scanned alongside the params.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.parallel.sharding import ShardingRules, current_rules, shard

ACT = L.ACT_DTYPE
VLM_PATCHES = 256        # stub frontend: patch embeddings prefix length
ATTN_CHUNK = 2048        # flash-style KV chunking threshold/size


def _use_moe(cfg: ArchConfig, global_layer: int) -> bool:
    m = cfg.moe
    if m is None:
        return False
    if global_layer == 0 and m.first_dense_ff:
        return False
    return (global_layer % m.every) == m.every - 1


# -- init -----------------------------------------------------------------------

def _init_sublayer(key, cfg: ArchConfig, kind: str, global_layer: int):
    ks = jax.random.split(key, 4)
    p = {"norm1": L.init_norm(cfg.d_model, cfg.norm)}
    if kind == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    else:
        p["ssm"] = SSM.init_ssm(ks[0], cfg)
    if kind == "attn" or cfg.family != "ssm":
        p["norm2"] = L.init_norm(cfg.d_model, cfg.norm)
        if _use_moe(cfg, global_layer):
            p["moe"] = MOE.init_moe(ks[1], cfg.d_model, cfg.moe)
        elif cfg.moe is not None and global_layer == 0 and cfg.moe.first_dense_ff:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.moe.first_dense_ff,
                                  cfg.activation)
        elif cfg.d_ff:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation)
    if cfg.enc_layers and kind == "attn":
        p["norm_x"] = L.init_norm(cfg.d_model, cfg.norm)
        p["cross"] = L.init_cross_attention(ks[2], cfg)
    return p


def _sublayer_specs(cfg: ArchConfig, kind: str, global_layer: int,
                    rules: ShardingRules):
    p = {"norm1": {"scale": P(None)}}
    if cfg.norm == "layernorm":
        p["norm1"]["bias"] = P(None)
    if kind == "attn":
        p["attn"] = L.attention_param_specs(cfg, rules)
    else:
        p["ssm"] = SSM.ssm_param_specs(cfg, rules)
    if kind == "attn" or cfg.family != "ssm":
        p["norm2"] = dict(p["norm1"])
        if _use_moe(cfg, global_layer):
            p["moe"] = MOE.moe_param_specs(cfg.moe, rules)
        elif cfg.moe is not None and global_layer == 0 and cfg.moe.first_dense_ff:
            p["mlp"] = L.mlp_param_specs(cfg.activation, rules)
        elif cfg.d_ff:
            p["mlp"] = L.mlp_param_specs(cfg.activation, rules)
    if cfg.enc_layers and kind == "attn":
        p["norm_x"] = dict(p["norm1"])
        p["cross"] = L.attention_param_specs(cfg, rules)
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 8)
    pat = cfg.layer_pattern()
    G = cfg.n_layer_groups
    params: dict = {"embed": L.init_embedding(keys[-1], cfg.vocab_padded,
                                              cfg.d_model),
                    "final_norm": L.init_norm(cfg.d_model, cfg.norm)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": L._normal(keys[-2],
                                            (cfg.d_model, cfg.vocab_padded),
                                            cfg.d_model ** -0.5)}
    # prefix layers (deepseek dense layer 0)
    prefix_n = 1 if (cfg.moe is not None and cfg.moe.first_dense_ff) else 0
    if prefix_n:
        params["prefix"] = [_init_sublayer(keys[0], cfg, pat[0], 0)]
    # scanned groups: stack leaves over G groups
    scanned_layers = cfg.n_layers - prefix_n
    Gs = scanned_layers // len(pat)

    def group_params(g):
        ps = []
        for i, kind in enumerate(pat):
            gl = prefix_n + g * len(pat) + i
            ps.append(_init_sublayer(keys[gl], cfg, kind, gl))
        return ps

    groups = [group_params(g) for g in range(Gs)]
    params["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    if cfg.enc_layers:
        enc = []
        for e in range(cfg.enc_layers):
            pe = {"norm1": L.init_norm(cfg.d_model, cfg.norm),
                  "attn": L.init_attention(keys[cfg.n_layers + e % 4], cfg),
                  "norm2": L.init_norm(cfg.d_model, cfg.norm),
                  "mlp": L.init_mlp(jax.random.fold_in(key, 1000 + e),
                                    cfg.d_model, cfg.d_ff, cfg.activation)}
            enc.append(pe)
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        params["enc_norm"] = L.init_norm(cfg.d_model, cfg.norm)
    return params


def param_specs(cfg: ArchConfig, rules: ShardingRules) -> dict:
    pat = cfg.layer_pattern()
    specs: dict = {"embed": {"tok": P(rules.tp, None)},
                   "final_norm": {"scale": P(None)}}
    if cfg.norm == "layernorm":
        specs["final_norm"]["bias"] = P(None)
    if not cfg.tie_embeddings:
        specs["lm_head"] = {"w": P(None, rules.tp)}
    prefix_n = 1 if (cfg.moe is not None and cfg.moe.first_dense_ff) else 0
    if prefix_n:
        specs["prefix"] = [_sublayer_specs(cfg, pat[0], 0, rules)]
    group = [_sublayer_specs(cfg, kind, prefix_n + i, rules)
             for i, kind in enumerate(pat)]
    # scanned leaves gain a leading group axis (unsharded)
    specs["groups"] = jax.tree.map(
        lambda s: P(None, *s), group, is_leaf=lambda x: isinstance(x, P))
    if cfg.enc_layers:
        enc = {"norm1": {"scale": P(None)},
               "attn": L.attention_param_specs(cfg, rules),
               "norm2": {"scale": P(None)},
               "mlp": L.mlp_param_specs(cfg.activation, rules)}
        if cfg.norm == "layernorm":
            enc["norm1"]["bias"] = P(None)
            enc["norm2"]["bias"] = P(None)
        specs["encoder"] = jax.tree.map(
            lambda s: P(None, *s), enc, is_leaf=lambda x: isinstance(x, P))
        specs["enc_norm"] = dict(specs["final_norm"])
    return specs


# -- forward --------------------------------------------------------------------

def _apply_sublayer(p, x, cfg: ArchConfig, kind: str, positions, *,
                    causal=True, chunk=0, state=None, cache_pos=None,
                    enc_out=None):
    """Pre-norm residual sublayer. Returns (x, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    if kind == "attn":
        if state is not None:
            y, new_cache = L.apply_attention(
                p["attn"], h, cfg, positions, causal=True, chunk=chunk,
                cache=state["kv"], cache_pos=cache_pos)
            new_state = dict(state, kv=new_cache)
        else:
            y, _ = L.apply_attention(p["attn"], h, cfg, positions,
                                     causal=causal, chunk=chunk)
            new_state = None
        x = x + y
        if enc_out is not None and "cross" in p:
            hx = L.apply_norm(p["norm_x"], x, cfg.norm)
            ckv = L.cross_kv(p["cross"], enc_out, cfg)
            y, _ = L.apply_attention(p["cross"], hx, cfg, positions,
                                     cross_kv=ckv)
            x = x + y
    else:
        if state is not None and h.shape[1] == 1:      # decode
            y, new_ssm = SSM.apply_ssm_decode(p["ssm"], h, cfg, state["ssm"])
            new_state = dict(state, ssm=new_ssm)
        elif state is not None:                         # prefill with state
            y, new_ssm = SSM.apply_ssm(p["ssm"], h, cfg, return_state=True,
                                       initial_state=state["ssm"])
            new_state = dict(state, ssm=new_ssm)
        else:
            y = SSM.apply_ssm(p["ssm"], h, cfg)
            new_state = None
        x = x + y
    if "norm2" in p:
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            r = current_rules()
            if (r is not None and r.mesh is not None and r.experts
                    and r.moe_impl == "shard_map"):
                y, aux = MOE.apply_moe_shardmap(p["moe"], h, cfg.moe,
                                                cfg.activation)
            elif (r is not None and r.mesh is not None and r.experts
                    and r.moe_impl == "all_to_all"):
                y, aux = MOE.apply_moe_a2a(p["moe"], h, cfg.moe,
                                           cfg.activation)
            else:
                y, aux = MOE.apply_moe(p["moe"], h, cfg.moe, cfg.activation)
        elif "mlp" in p:
            y = L.apply_mlp(p["mlp"], h, cfg.activation)
        else:
            y = jnp.zeros_like(x)
        x = x + y
    return x, new_state, aux


def _group_states(cfg: ArchConfig, batch: int, cache_len: int):
    """State pytree template for ONE group (list over in-group positions)."""
    pat = cfg.layer_pattern()
    states = []
    for kind in pat:
        if kind == "attn":
            kv = {"k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), ACT),
                  "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), ACT)}
            states.append({"kv": kv})
        else:
            states.append({"ssm": SSM.init_ssm_state(cfg, batch, ACT)})
    return states


def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int):
    Gs = _scanned_groups(cfg)
    one = _group_states(cfg, batch, cache_len)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (Gs,) + x.shape), one)
    state = {"groups": stacked}
    if cfg.moe is not None and cfg.moe.first_dense_ff:
        state["prefix"] = _group_states(cfg, batch, cache_len)[:1]
    return state


def _scanned_groups(cfg: ArchConfig) -> int:
    prefix_n = 1 if (cfg.moe is not None and cfg.moe.first_dense_ff) else 0
    return (cfg.n_layers - prefix_n) // len(cfg.layer_pattern())


def _encode(params, cfg, frames):
    """Encoder stack (seamless): non-causal attention over frame embeds."""
    x = frames.astype(ACT)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], x.shape[:2])

    def body(carry, p):
        h = L.apply_norm(p["norm1"], carry, cfg.norm)
        y, _ = L.apply_attention(p["attn"], h, cfg, positions, causal=False,
                                 chunk=ATTN_CHUNK if S > 4096 else 0)
        carry = carry + y
        h = L.apply_norm(p["norm2"], carry, cfg.norm)
        carry = carry + L.apply_mlp(p["mlp"], h, cfg.activation)
        return carry, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.apply_norm(params["enc_norm"], x, cfg.norm)


def forward(params, cfg: ArchConfig, batch: dict, *, remat: str = "none"):
    """Training/prefill forward → logits [B,S,vocab_padded], aux loss.

    batch keys: tokens [B,S]; vlm: patches [B,256,D]; encdec: frames
    [B,S_enc,D] (tokens are then the decoder side).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.apply_embedding(params["embed"], tokens)
    if cfg.modality == "vlm" and "patches" in batch:
        npatch = batch["patches"].shape[1]
        x = jnp.concatenate([batch["patches"].astype(ACT),
                             x[:, npatch:]], axis=1)
    x = shard_batch(x)
    enc_out = None
    if cfg.enc_layers:
        enc_out = _encode(params, cfg, batch["frames"])
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    chunk = ATTN_CHUNK if S > 4096 else 0
    pat = cfg.layer_pattern()
    aux_total = jnp.zeros((), jnp.float32)

    if "prefix" in params:
        for i, p in enumerate(params["prefix"]):
            x, _, aux = _apply_sublayer(p, x, cfg, pat[i], positions,
                                        chunk=chunk, enc_out=enc_out)
            aux_total = aux_total + aux

    def group_fn(x, gp):
        aux_g = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pat):
            x, _, aux = _apply_sublayer(gp[i], x, cfg, kind, positions,
                                        chunk=chunk, enc_out=enc_out)
            aux_g = aux_g + aux
        return x, aux_g

    if remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat == "dots" else None)
        group_fn = jax.checkpoint(group_fn, policy=policy,
                                  prevent_cse=False)

    def body(carry, gp):
        x, aux_acc = carry
        x, aux_g = group_fn(x, gp)
        return (shard_batch(x), aux_acc + aux_g), None

    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["groups"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.apply_lm_head(params["embed"], params.get("lm_head"), x,
                             cfg.tie_embeddings)
    return logits, aux_total


def shard_batch(x):
    """Residual-stream constraint: DP batch + (optionally) Megatron-SP seq.

    With rules.seq set, GSPMD keeps the residual sequence-sharded over the
    model axis between blocks and converts the TP all-reduces into
    all-gather + reduce-scatter pairs (activation memory ÷ model_size)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    if (x.ndim >= 3 and r.seq is not None
            and x.shape[1] % r.mesh.shape["model"] == 0 and x.shape[1] > 1):
        return shard(x, r.batch, r.seq, *([None] * (x.ndim - 2)))
    return shard(x, r.batch, *([None] * (x.ndim - 1)))


def loss_fn(params, cfg: ArchConfig, batch: dict, *, remat: str = "none",
            aux_weight: float = 0.01):
    logits, aux = forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    r = current_rules()
    if r is not None and r.mesh is not None:
        lf = shard(lf, r.batch, None, r.tp)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    if r is not None and r.mesh is not None:
        # keep the one-hot vocab-sharded — replicated it is B·S·V floats
        onehot = shard(onehot, r.batch, None, r.tp)
    gold = jnp.sum(lf * onehot, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# -- serving --------------------------------------------------------------------

def prefill(params, cfg: ArchConfig, batch: dict, cache_len: int,
            *, chunks: int = 1):
    """Run the prompt, return (last-token logits, decode state, next_pos).

    ``chunks > 1`` enables chunked prefill (vLLM-style): the prompt is
    processed in sequential super-chunks against the growing KV/SSM state,
    dividing the activation live-set by ``chunks`` — required to serve the
    largest archs on a single pod.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    assert S % chunks == 0
    Sc = S // chunks
    state = init_decode_state(cfg, B, cache_len)
    x_full = L.apply_embedding(params["embed"], tokens)
    if cfg.modality == "vlm" and "patches" in batch:
        npatch = batch["patches"].shape[1]
        x_full = jnp.concatenate([batch["patches"].astype(ACT),
                                  x_full[:, npatch:]], axis=1)
    x_full = shard_batch(x_full)
    enc_out = _encode(params, cfg, batch["frames"]) if cfg.enc_layers else None
    pat = cfg.layer_pattern()

    x_last = None
    for c in range(chunks):
        x = x_full[:, c * Sc:(c + 1) * Sc]
        positions = jnp.broadcast_to(
            (c * Sc + jnp.arange(Sc))[None, :], (B, Sc))
        chunk = ATTN_CHUNK if Sc > 4096 else 0

        if "prefix" in params:
            new_prefix = []
            for i, p in enumerate(params["prefix"]):
                x, st, _ = _apply_sublayer(p, x, cfg, pat[i], positions,
                                           chunk=chunk,
                                           state=state["prefix"][i],
                                           cache_pos=c * Sc, enc_out=enc_out)
                new_prefix.append(st)
            state["prefix"] = new_prefix

        def body(x, inp):
            gp, gst = inp
            new_states = []
            for i, kind in enumerate(pat):
                x, st, _ = _apply_sublayer(gp[i], x, cfg, kind, positions,
                                           chunk=chunk, state=gst[i],
                                           cache_pos=c * Sc, enc_out=enc_out)
                new_states.append(st)
            return x, new_states

        x, gstates = jax.lax.scan(body, x,
                                  (params["groups"], state["groups"]))
        state["groups"] = gstates
        x_last = x
    x = L.apply_norm(params["final_norm"], x_last[:, -1:], cfg.norm)
    logits = L.apply_lm_head(params["embed"], params.get("lm_head"), x,
                             cfg.tie_embeddings)
    return logits, state, S


def decode_step(params, cfg: ArchConfig, token, state, pos, *, enc_out=None):
    """One decode step. token [B,1] int32, pos scalar int32 → logits, state."""
    B = token.shape[0]
    x = L.apply_embedding(params["embed"], token)
    x = shard_batch(x)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    pat = cfg.layer_pattern()

    if "prefix" in params:
        new_prefix = []
        for i, p in enumerate(params["prefix"]):
            x, st, _ = _apply_sublayer(p, x, cfg, pat[i], positions,
                                       state=state["prefix"][i],
                                       cache_pos=pos, enc_out=enc_out)
            new_prefix.append(st)
        state = dict(state, prefix=new_prefix)

    def body(x, inp):
        gp, gst = inp
        new_states = []
        for i, kind in enumerate(pat):
            x, st, _ = _apply_sublayer(gp[i], x, cfg, kind, positions,
                                       state=gst[i], cache_pos=pos,
                                       enc_out=enc_out)
            new_states.append(st)
        return x, new_states

    x, gstates = jax.lax.scan(body, x, (params["groups"], state["groups"]))
    state = dict(state, groups=gstates)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.apply_lm_head(params["embed"], params.get("lm_head"), x,
                             cfg.tie_embeddings)
    return logits, state


# -- input specs (dry-run / data pipeline) ----------------------------------------

def input_specs(cfg: ArchConfig, cell: ShapeCell, *, per_device_batch=None
                ) -> dict:
    """ShapeDtypeStructs for every model input of a shape cell (no alloc)."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cell.kind == "train":
        spec = {"tokens": jax.ShapeDtypeStruct((B, _dec_len(cfg, S)), i32),
                "labels": jax.ShapeDtypeStruct((B, _dec_len(cfg, S)), i32)}
        if cfg.enc_layers:
            spec["frames"] = jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), ACT)
        if cfg.modality == "vlm":
            spec["patches"] = jax.ShapeDtypeStruct((B, VLM_PATCHES,
                                                    cfg.d_model), ACT)
        return spec
    if cell.kind == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((B, _dec_len(cfg, S)), i32)}
        if cfg.enc_layers:
            spec["frames"] = jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), ACT)
        if cfg.modality == "vlm":
            spec["patches"] = jax.ShapeDtypeStruct((B, VLM_PATCHES,
                                                    cfg.d_model), ACT)
        return spec
    # decode: one new token against a cache of length S
    spec = {"token": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.enc_layers:
        spec["enc_out"] = jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), ACT)
    return spec


def _dec_len(cfg: ArchConfig, S: int) -> int:
    return S // 2 if cfg.enc_layers else S
