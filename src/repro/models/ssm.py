"""Mamba-2 SSD (state-space duality) block — chunked matmul form + O(1) decode.

The SSD forward computes, per head h with state size N and head dim P:
    h_t = exp(dt_t·A) · h_{t-1} + dt_t · B_t ⊗ x_t          (state [N,P])
    y_t = C_t · h_t + D · x_t
in chunked form (chunk Q): an intra-chunk attention-like term
(C_i·B_j masked by the decay kernel L_ij) plus an inter-chunk scan carrying
the state — both MXU-friendly einsums, following Dao & Gu (arXiv:2405.21060),
adapted so the head dimension TP-shards over "model".

Decode keeps per-layer state: conv window [B, W-1, d_conv_ch] + SSM state
[B, H, P, N]; one token costs O(H·P·N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard, current_rules
from repro.models.layers import _normal


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def init_ssm(key, cfg):
    s = cfg.ssm
    D = cfg.d_model
    d_inner, H, conv_ch = dims(cfg)
    GN = s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    sc = D ** -0.5
    return {
        "w_z": _normal(ks[0], (D, d_inner), sc),
        "w_x": _normal(ks[1], (D, d_inner), sc),
        "w_B": _normal(ks[2], (D, GN), sc),
        "w_C": _normal(ks[3], (D, GN), sc),
        "w_dt": _normal(ks[4], (D, H), sc),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "conv_w": _normal(ks[5], (s.conv_width, conv_ch), 0.5),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "w_out": _normal(ks[6], (d_inner, D), d_inner ** -0.5),
    }


def ssm_param_specs(cfg, rules):
    from jax.sharding import PartitionSpec as P
    tp = rules.tp
    return {
        "w_z": P(None, tp), "w_x": P(None, tp),
        "w_B": P(None, None), "w_C": P(None, None),
        "w_dt": P(None, tp), "dt_bias": P(tp), "A_log": P(tp), "D": P(tp),
        "conv_w": P(None, None), "conv_b": P(None),
        "w_out": P(tp, None),
    }


def _causal_conv(xbc, conv_w, conv_b, state=None):
    """Depthwise causal conv over [B,S,Ch]; returns (out, new_state)."""
    W = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for w in range(W):  # W is 4: unrolled taps fuse into one pass
        out = out + xp[:, w: w + xbc.shape[1]] * conv_w[w].astype(xbc.dtype)
    out = out + conv_b.astype(xbc.dtype)
    new_state = xp[:, xp.shape[1] - (W - 1):]
    return jax.nn.silu(out), new_state


def _proj_in(p, x, cfg):
    dt = x.dtype
    s = cfg.ssm
    d_inner, H, _ = dims(cfg)
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"].astype(dt))
    xin = jnp.einsum("bsd,di->bsi", x, p["w_x"].astype(dt))
    Bv = jnp.einsum("bsd,dn->bsn", x, p["w_B"].astype(dt))
    Cv = jnp.einsum("bsd,dn->bsn", x, p["w_C"].astype(dt))
    dtv = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt)).astype(jnp.float32)
        + p["dt_bias"])
    r = current_rules()
    if r is not None and r.mesh is not None:
        z = shard(z, r.batch, None, r.tp)
        xin = shard(xin, r.batch, None, r.tp)
    return z, xin, Bv, Cv, dtv


def apply_ssm(p, x, cfg, *, return_state: bool = False, initial_state=None):
    """Training/prefill forward, chunked SSD. x [B,S,D] → [B,S,D]
    (+ final {conv, h} state when ``return_state``). ``initial_state``
    continues from a previous prefill chunk (chunked prefill)."""
    s = cfg.ssm
    B_, S_orig, D = x.shape
    d_inner, H, conv_ch = dims(cfg)
    P_, N, Q = s.head_dim, s.d_state, s.chunk
    dt_ = x.dtype

    z, xin, Bv, Cv, dtv = _proj_in(p, x, cfg)
    xbc = jnp.concatenate([xin, Bv, Cv], axis=-1)
    xbc, conv_state = _causal_conv(
        xbc, p["conv_w"], p["conv_b"],
        initial_state["conv"] if initial_state is not None else None)
    xin, Bv, Cv = (xbc[..., :d_inner], xbc[..., d_inner:d_inner + N],
                   xbc[..., d_inner + N:])

    # ragged prompts: pad to a chunk multiple with dt = 0 (decay exp(0·A)=1,
    # update dt·B⊗x = 0) so the padded tail is an exact no-op on the state.
    pad = (-S_orig) % Q
    if pad:
        padw = [(0, 0), (0, pad), (0, 0)]
        xin = jnp.pad(xin, padw)
        Bv = jnp.pad(Bv, padw)
        Cv = jnp.pad(Cv, padw)
        dtv = jnp.pad(dtv, padw)
    S = S_orig + pad
    nC = S // Q

    xh = xin.reshape(B_, nC, Q, H, P_)
    Bc = Bv.reshape(B_, nC, Q, N)          # n_groups=1 → broadcast over heads
    Cc = Cv.reshape(B_, nC, Q, N)
    dtc = dtv.reshape(B_, nC, Q, H)
    A = -jnp.exp(p["A_log"])               # [H], negative

    a = dtc * A                            # log-decay per step [B,nC,Q,H]
    cum = jnp.cumsum(a, axis=2)            # within-chunk cumulative decay
    # intra-chunk: y_i += Σ_{j≤i} (C_i·B_j) exp(cum_i − cum_j) dt_j x_j
    Sij = jnp.einsum("bcin,bcjn->bcij", Cc, Bc).astype(jnp.float32)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [B,nC,i,j,H]
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(decay), 0.0)
    M = Sij[..., None] * L                                   # [B,nC,i,j,H]
    xdt = xh * dtc[..., None].astype(dt_)                    # dt_j x_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(dt_), xdt)

    # chunk summaries: state contribution of chunk c
    w_end = jnp.exp(cum[:, :, -1:, :] - cum)                 # decay j→chunk end
    state_c = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                         w_end.astype(dt_) * dtc.astype(dt_), Bc, xh)
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))                # [B,nC,H]

    # inter-chunk scan: h_c = decay_c · h_{c-1} + state_c
    def scan_body(h, inp):
        st, dec = inp
        h_new = h * dec[:, :, None, None].astype(h.dtype) + st
        return h_new, h
    init = (initial_state["h"].astype(dt_) if initial_state is not None
            else jnp.zeros((B_, H, N, P_), dt_))
    h_last, h_prev = jax.lax.scan(
        scan_body, init,
        (state_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                 # [B,nC,H,N,P]

    # inter-chunk output: C_i · (decay to i) · h_{c-1}
    w_in = jnp.exp(cum)                                      # decay start→i
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", Cc, h_prev,
                         w_in.astype(dt_))
    y = (y_intra + y_inter).reshape(B_, S, H, P_)
    y = y + xin.reshape(B_, S, H, P_) * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B_, S, d_inner)[:, :S_orig] * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(dt_))
    r = current_rules()
    if r is not None and r.mesh is not None:
        out = shard(out, r.batch, None, None)
    if return_state:
        return out, {"conv": conv_state, "h": h_last}
    return out


def init_ssm_state(cfg, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_inner, H, conv_ch = dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        "h": jnp.zeros((batch, H, s.d_state, s.head_dim), dtype),
    }


def apply_ssm_decode(p, x, cfg, state):
    """One-token decode. x [B,1,D] → ([B,1,D], new_state)."""
    s = cfg.ssm
    B_, _, D = x.shape
    d_inner, H, conv_ch = dims(cfg)
    P_, N = s.head_dim, s.d_state
    dt_ = x.dtype

    z, xin, Bv, Cv, dtv = _proj_in(p, x, cfg)
    xbc = jnp.concatenate([xin, Bv, Cv], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state["conv"])
    xin, Bv, Cv = (xbc[..., :d_inner], xbc[..., d_inner:d_inner + N],
                   xbc[..., d_inner + N:])

    xh = xin.reshape(B_, H, P_)
    Bt, Ct, dtt = Bv[:, 0], Cv[:, 0], dtv[:, 0]              # [B,N],[B,N],[B,H]
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dtt * A)                                   # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhnp", dtt.astype(dt_), Bt, xh)
    h = state["h"] * dec[:, :, None, None].astype(dt_) + upd
    y = jnp.einsum("bn,bhnp->bhp", Ct, h)
    y = y + xh * p["D"].astype(dt_)[None, :, None]
    y = y.reshape(B_, 1, d_inner) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(dt_))
    return out, {"conv": conv_state, "h": h}
