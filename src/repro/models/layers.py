"""Transformer layer library: norms, RoPE, GQA attention, gated MLPs.

Pure-function style: ``init_*`` builds param dicts, ``apply`` functions are
stateless. Activations run in bf16 with f32 softmax/norm internals; params
are stored f32 and cast at use (the optimizer keeps f32 masters anyway).
Sharding constraints use the logical rules from repro.parallel.sharding.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import shard, current_rules

# REPRO_ACT_DTYPE=float32 works around an XLA:CPU crash with bf16 inside
# partial-manual shard_map regions (pipeline parallelism tests); TPU is
# unaffected (native bf16).
import os as _os
ACT_DTYPE = (jnp.float32 if _os.environ.get("REPRO_ACT_DTYPE") == "float32"
             else jnp.bfloat16)


def _normal(key, shape, scale):
    return (scale * jax.random.normal(key, shape, jnp.float32))


# -- norms ---------------------------------------------------------------------

def init_norm(d: int, kind: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# -- rotary position embeddings -------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions int32[...,S] → (cos, sin) [..., S, head_dim//2] f32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, hd]; cos/sin broadcastable [..., S, 1, hd//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# -- attention -------------------------------------------------------------------

def init_attention(key, cfg):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = D ** -0.5
    return {
        "wq": _normal(k1, (D, H, hd), s),
        "wk": _normal(k2, (D, KV, hd), s),
        "wv": _normal(k3, (D, KV, hd), s),
        "wo": _normal(k4, (H, hd, D), (H * hd) ** -0.5),
    }


def attention_param_specs(cfg, rules):
    from jax.sharding import PartitionSpec as P
    h, kv = rules.heads, rules.kv_heads
    return {"wq": P(None, h, None), "wk": P(None, kv, None),
            "wv": P(None, kv, None), "wo": P(h, None, None)}


def _qkv(p, x, cfg, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    r = current_rules()
    if r is not None and r.mesh is not None:
        q = shard(q, r.batch, None, r.heads, None)
        k = shard(k, r.batch, None, r.kv_heads, None)
        v = shard(v, r.batch, None, r.kv_heads, None)
    cos, sin = rope_angles(positions, cfg.hd, cfg.rope_theta)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa(q, k, v, *, causal: bool, q_offset=0, kv_len=None, chunk: int = 0):
    """Scaled dot-product attention with GQA; optional flash-style chunking
    over the KV axis (scan with running max/sum) for long sequences.

    q [B,Sq,H,hd], k/v [B,Sk,KV,hd]. ``kv_len`` masks positions ≥ kv_len
    (decode with a partially filled cache). ``q_offset`` is the absolute
    position of q[0] for causal masking.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = hd ** -0.5

    def block_scores(kb, kb_start, Skb):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb) * scale
        s = s.astype(jnp.float32)
        kpos = kb_start + jnp.arange(Skb)
        if causal:
            qpos = q_offset + jnp.arange(Sq)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, -jnp.inf)
        if kv_len is not None:
            s = jnp.where((kpos < kv_len)[None, None, None, None, :], s, -jnp.inf)
        return s

    if chunk and Sk > chunk:
        n_chunks = Sk // chunk
        assert Sk % chunk == 0

        def body(carry, inputs):
            m, l, acc = carry
            kb, vb, ci = inputs
            s = block_scores(kb, ci * chunk, chunk)        # [B,KV,G,Sq,C]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb)
            acc = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l, acc), None

        ks = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
        vs = v.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
        m0 = jnp.full((B, KV, G, Sq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Sq, hd), v.dtype)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (ks, vs, jnp.arange(n_chunks)))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    else:
        s = block_scores(k, 0, Sk)                          # [B,KV,G,Sq,Sk]
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.maximum(m, -1e30)  # rows fully masked (padding) stay finite
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        p = (p / jnp.maximum(l, 1e-30)).astype(v.dtype)
        out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


def apply_attention(p, x, cfg, positions, *, causal=True, chunk=0,
                    cache=None, cache_pos=None, cross_kv=None):
    """Full attention block. ``cache`` = dict(k, v) [B,Smax,KV,hd] for decode
    (updated functionally, returned). ``cross_kv`` = precomputed (k, v) for
    encoder-decoder cross-attention (no rope on cross)."""
    dt = x.dtype
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
        k, v = cross_kv
        out = _sdpa(q, k, v, causal=False, chunk=chunk)
        new_cache = cache
    elif cache is not None:
        q, k_new, v_new = _qkv(p, x, cfg, positions)
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), cache_pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), cache_pos, axis=1)
        r = current_rules()
        if r is not None and r.mesh is not None and r.kv_heads is None:
            # few KV heads (not divisible by the model axis): shard the
            # cache SEQUENCE instead (flash-decoding; GSPMD inserts the
            # partial-softmax psums). Always on for decode; during prefill
            # only when the total cache stack would blow HBM — the per-layer
            # cache-write reshard it costs shows up in the collective term.
            cache_total = (cfg.n_layers * cache["k"].size
                           * cache["k"].dtype.itemsize * 2)
            if x.shape[1] == 1 or cache_total > 8 * 2 ** 30:
                k = shard(k, r.batch, r.kv_seq, None, None)
                v = shard(v, r.batch, r.kv_seq, None, None)
        new_cache = {"k": k, "v": v}
        kv_len = cache_pos + x.shape[1]
        out = _sdpa(q, k, v, causal=True, q_offset=cache_pos, kv_len=kv_len,
                    chunk=chunk)
    else:
        q, k, v = _qkv(p, x, cfg, positions)
        out = _sdpa(q, k, v, causal=causal, chunk=chunk)
        new_cache = None
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    r = current_rules()
    if r is not None and r.mesh is not None:
        y = shard(y, r.batch, None, None)
    return y, new_cache


def init_cross_attention(key, cfg):
    return init_attention(key, cfg)


def cross_kv(p, enc_out, cfg):
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    return k, v


# -- MLP -------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, activation: str):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    p = {"wup": _normal(k1, (d_model, d_ff), s_in),
         "wdown": _normal(k2, (d_ff, d_model), s_out)}
    if activation in ("swiglu", "geglu"):
        p["wgate"] = _normal(k3, (d_model, d_ff), s_in)
    return p


def mlp_param_specs(activation: str, rules):
    from jax.sharding import PartitionSpec as P
    tp = rules.tp
    p = {"wup": P(None, tp), "wdown": P(tp, None)}
    if activation in ("swiglu", "geglu"):
        p["wgate"] = P(None, tp)
    return p


def apply_mlp(p, x, activation: str):
    dt = x.dtype
    up = jnp.einsum("bsd,df->bsf", x, p["wup"].astype(dt))
    r = current_rules()
    if r is not None and r.mesh is not None:
        up = shard(up, r.batch, None, r.tp)
    if activation == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["wgate"].astype(dt))
        h = jax.nn.silu(gate) * up
    elif activation == "geglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["wgate"].astype(dt))
        h = jax.nn.gelu(gate) * up
    else:
        h = jax.nn.gelu(up)
    y = jnp.einsum("bsf,fd->bsd", h, p["wdown"].astype(dt))
    if r is not None and r.mesh is not None:
        y = shard(y, r.batch, None, None)
    return y


# -- embeddings -------------------------------------------------------------------

def init_embedding(key, vocab_padded: int, d_model: int):
    # d^-0.5 keeps tied-head logits O(1) at init (gemma-style tying)
    return {"tok": _normal(key, (vocab_padded, d_model), d_model ** -0.5)}


def apply_embedding(p, tokens):
    return p["tok"].astype(ACT_DTYPE)[tokens]


def apply_lm_head(p_embed, p_head, x, tie: bool):
    dt = x.dtype
    if tie:
        return jnp.einsum("bsd,vd->bsv", x, p_embed["tok"].astype(dt))
    return jnp.einsum("bsd,dv->bsv", x, p_head["w"].astype(dt))
