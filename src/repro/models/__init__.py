from repro.models.model import (init_params, param_specs, forward, loss_fn,
                                prefill, decode_step, init_decode_state,
                                input_specs)
from repro.models import layers, moe, ssm
