"""Mixture-of-Experts layer with capacity-based dispatch (Switch-style) and
optional shared experts (DeepSeekMoE fine-grained recipe).

Dispatch is *per-sequence* (capacity C = ceil(cf · S · k / E)), which keeps
the expert buffers batch-sharded over DP and expert-sharded over EP without
any host-side regrouping: GSPMD turns the scatter/gather across the EP axis
into the dispatch all-to-all pattern. Overflow tokens are dropped (their
residual passes through), and a Switch load-balancing aux loss is returned.

Sharding:
  EP (experts % model == 0):   expert weights P("model", None, None)
  TP fallback (granite, 40e):  expert weights P(None, None, "model")
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard, current_rules
from repro.utils.compat import shard_map
from repro.models.layers import _normal


def capacity(S: int, cfg_moe) -> int:
    import math
    c = math.ceil(cfg_moe.capacity_factor * S * cfg_moe.top_k
                  / cfg_moe.n_experts)
    return max(1, c)


def init_moe(key, d_model: int, m):
    E, F = m.n_experts, m.d_expert
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in, s_out = d_model ** -0.5, F ** -0.5
    p = {
        "router": _normal(k1, (d_model, E), s_in),
        "wup": _normal(k2, (E, d_model, F), s_in),
        "wgate": _normal(k3, (E, d_model, F), s_in),
        "wdown": _normal(k4, (E, F, d_model), s_out),
    }
    if m.n_shared:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(k5, d_model, m.n_shared * F, "swiglu")
    return p


def moe_param_specs(m, rules):
    from jax.sharding import PartitionSpec as P
    if rules.experts:                    # EP
        w = P(rules.experts, None, None)
    else:                                # TP inside experts
        w = P(None, None, rules.expert_tp)
        wd = P(None, rules.expert_tp, None)
    specs = {
        "router": P(None, None),
        "wup": w, "wgate": w,
        "wdown": P(rules.experts, None, None) if rules.experts else
                 P(None, rules.expert_tp, None),
    }
    if m.n_shared:
        from repro.models.layers import mlp_param_specs
        specs["shared"] = mlp_param_specs("swiglu", rules)
    return specs


def apply_moe_shardmap(p, x, m, activation: str = "swiglu"):
    """EP MoE with an explicit shard_map over the model axis (§Perf B).

    Observation: activations are replicated across the EP (model) axis —
    only the batch axes shard them. Each EP rank can therefore build the
    dispatch buffer for ITS OWN expert shard entirely locally; the only
    cross-EP communication needed is the combine-reduction (psum of the
    per-rank partial outputs), the same volume as one dense TP layer.
    GSPMD's scatter/gather partitioning of the jnp formulation instead
    produces full-buffer all-reduces (~6× the collective bytes on the
    8-device smoke config, growing with E·capacity — measured in
    EXPERIMENTS.md §Perf).
    """
    from repro.parallel.sharding import current_rules
    r = current_rules()
    mesh = r.mesh
    E = m.n_experts
    msize = mesh.shape["model"]
    E_loc = E // msize
    B, S, D = x.shape
    C = capacity(S, m)
    dt = x.dtype
    from jax.sharding import PartitionSpec as P

    def local(x_blk, router, wup, wgate, wdown):
        # x_blk [B_loc, S, D] — replicated over "model"; w* [E_loc, ...]
        Bl = x_blk.shape[0]
        logits = jnp.einsum("bsd,de->bse", x_blk.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        onehot_k = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
        f = onehot_k.sum(axis=2).mean(axis=1)
        aux = E * jnp.mean(jnp.sum(f * probs.mean(axis=1), axis=-1))
        aux = jax.lax.pmean(aux, "model")

        flat_choice = onehot_k.reshape(Bl, S * m.top_k, E)
        pos = jnp.cumsum(flat_choice, axis=1) - flat_choice
        pos = jnp.sum(pos * flat_choice, axis=-1).reshape(Bl, S, m.top_k)
        keep = pos < C
        # LOCAL expert shard only: experts [e0, e0+E_loc)
        e0 = jax.lax.axis_index("model") * E_loc
        local_e = expert_idx - e0
        in_shard = (local_e >= 0) & (local_e < E_loc) & keep
        slot = jnp.where(in_shard, local_e * C + pos.astype(jnp.int32),
                         E_loc * C)
        xk = jnp.broadcast_to(x_blk[:, :, None, :],
                              (Bl, S, m.top_k, D)).reshape(Bl, S * m.top_k, D)
        buf = jax.vmap(lambda s_ids, vals: jax.ops.segment_sum(
            vals, s_ids, num_segments=E_loc * C + 1))(
            slot.reshape(Bl, S * m.top_k), xk)
        buf = buf[:, : E_loc * C].reshape(Bl, E_loc, C, D)

        up = jnp.einsum("becd,edf->becf", buf, wup.astype(dt))
        gatep = jnp.einsum("becd,edf->becf", buf, wgate.astype(dt))
        h = (jax.nn.silu(gatep) if activation == "swiglu"
             else jax.nn.gelu(gatep)) * up
        out_buf = jnp.einsum("becf,efd->becd", h, wdown.astype(dt))

        flat = out_buf.reshape(Bl, E_loc * C, D)
        flat = jnp.concatenate([flat, jnp.zeros((Bl, 1, D), dt)], axis=1)
        gathered = jax.vmap(lambda fb, s_ids: fb[s_ids])(
            flat, slot.reshape(Bl, S * m.top_k)).reshape(Bl, S, m.top_k, D)
        w = jnp.where(in_shard, gate_vals, 0.0).astype(dt)
        y_part = jnp.einsum("bskd,bsk->bsd", gathered, w)
        # combine: sum partial outputs across EP ranks (tokens whose expert
        # lives elsewhere contributed zero here)
        return jax.lax.psum(y_part, "model"), aux

    batch_axes = r.batch
    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["wup"], p["wgate"], p["wdown"])

    if "shared" in p:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["shared"], x, "swiglu")
    return y, aux


def apply_moe_a2a(p, x, m, activation: str = "swiglu"):
    """EP MoE via true all-to-all (§Perf B iteration 3, DeepSpeed-MoE
    layout). Requires tokens sharded over the model axis too (strategy
    ``fsdp_dp``): each rank routes its own tokens, sends them to the rank
    owning their expert (one a2a), runs its expert shard, and a reverse a2a
    returns the results — per-device communication is tokens·k·D both ways,
    independent of expert count, vs FSDP's per-layer expert-weight gathers
    or GSPMD's full-buffer all-reduces.
    """
    import math
    from repro.parallel.sharding import current_rules
    r = current_rules()
    mesh = r.mesh
    E, k = m.n_experts, m.top_k
    msize = mesh.shape["model"]
    E_loc = E // msize
    B, S, D = x.shape
    dt = x.dtype
    from jax.sharding import PartitionSpec as P
    # per-destination-rank capacity (each source sends ≤ C_pair rows/peer)
    C_pair = max(1, math.ceil(m.capacity_factor * S * k / msize))
    # per-expert capacity after the exchange (rows from msize peers)
    C_big = max(1, math.ceil(m.capacity_factor * msize * C_pair / E_loc))

    def local(x_blk, router, wup, wgate, wdown):
        Bl = x_blk.shape[0]                     # B/(data·model) sequences
        logits = jnp.einsum("bsd,de->bse", x_blk.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = (gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)).astype(jnp.float32)
        onehot_k = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
        f = onehot_k.sum(axis=2).mean(axis=1)
        aux = E * jnp.mean(jnp.sum(f * probs.mean(axis=1), axis=-1))
        aux = jax.lax.pmean(aux, "model")

        # destination rank + slot within the [dest, C_pair] send buffer
        flat_e = expert_idx.reshape(Bl, S * k)
        dest = flat_e // E_loc                                  # [Bl, S·k]
        dhot = jax.nn.one_hot(dest, msize, dtype=jnp.float32)
        pos = (jnp.cumsum(dhot, axis=1) - dhot)
        pos = jnp.sum(pos * dhot, axis=-1).astype(jnp.int32)    # [Bl, S·k]
        keep = pos < C_pair
        slot = jnp.where(keep, dest * C_pair + pos, msize * C_pair)

        xk = jnp.broadcast_to(x_blk[:, :, None, :], (Bl, S, k, D)) \
            .reshape(Bl, S * k, D)
        send = jax.vmap(lambda s_ids, vals: jax.ops.segment_sum(
            vals, s_ids, num_segments=msize * C_pair + 1))(slot, xk)
        send = send[:, : msize * C_pair]
        # metadata: local expert id at the destination (+1, 0 = invalid)
        meta = jax.vmap(lambda s_ids, vals: jax.ops.segment_sum(
            vals, s_ids, num_segments=msize * C_pair + 1))(
            slot, jnp.where(keep, (flat_e % E_loc) + 1, 0
                            ).astype(jnp.float32)[..., None])
        meta = meta[:, : msize * C_pair, 0]

        payload = jnp.concatenate(
            [send.astype(dt), meta.astype(dt)[..., None]], axis=-1) \
            .reshape(Bl, msize, C_pair, D + 1)
        recv = jax.lax.all_to_all(payload, "model", split_axis=1,
                                  concat_axis=1)
        recv = recv.reshape(Bl, msize, C_pair, D + 1)
        rx = recv[..., :D].reshape(Bl, msize * C_pair, D)
        rmeta = recv[..., D].reshape(Bl, msize * C_pair)
        e_loc = jnp.round(rmeta.astype(jnp.float32)).astype(jnp.int32) - 1
        valid = e_loc >= 0

        # pack into the local expert buffer [E_loc, C_big, D]
        ehot = jax.nn.one_hot(jnp.where(valid, e_loc, E_loc), E_loc + 1,
                              dtype=jnp.float32)[..., :E_loc]
        epos = (jnp.cumsum(ehot, axis=1) - ehot)
        epos = jnp.sum(epos * ehot, axis=-1).astype(jnp.int32)
        ekeep = valid & (epos < C_big)
        eslot = jnp.where(ekeep, e_loc * C_big + epos, E_loc * C_big)
        buf = jax.vmap(lambda s_ids, vals: jax.ops.segment_sum(
            vals, s_ids, num_segments=E_loc * C_big + 1))(eslot, rx)
        buf = buf[:, : E_loc * C_big].reshape(Bl, E_loc, C_big, D)

        up = jnp.einsum("becd,edf->becf", buf, wup.astype(dt))
        gatep = jnp.einsum("becd,edf->becf", buf, wgate.astype(dt))
        h = (jax.nn.silu(gatep) if activation == "swiglu"
             else jax.nn.gelu(gatep)) * up
        out_buf = jnp.einsum("becf,efd->becd", h, wdown.astype(dt))

        # unpack to recv layout, reverse a2a, combine at the source
        flat_out = out_buf.reshape(Bl, E_loc * C_big, D)
        flat_out = jnp.concatenate(
            [flat_out, jnp.zeros((Bl, 1, D), dt)], axis=1)
        back = jax.vmap(lambda fb, s: fb[s])(flat_out, eslot)   # recv order
        back = back.reshape(Bl, msize, C_pair, D)
        ret = jax.lax.all_to_all(back, "model", split_axis=1, concat_axis=1)
        ret = ret.reshape(Bl, msize * C_pair, D)
        ret = jnp.concatenate([ret, jnp.zeros((Bl, 1, D), dt)], axis=1)
        got = jax.vmap(lambda fb, s: fb[s])(ret, slot)          # [Bl,S·k,D]
        got = got.reshape(Bl, S, k, D)
        w = jnp.where(keep.reshape(Bl, S, k), gate_vals, 0.0).astype(dt)
        return jnp.einsum("bskd,bsk->bsd", got, w), aux

    batch_axes = r.batch        # includes "model" under fsdp_dp
    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["wup"], p["wgate"], p["wdown"])
    if "shared" in p:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["shared"], x, "swiglu")
    return y, aux


def apply_moe(p, x, m, activation: str = "swiglu"):
    """x [B,S,D] → ([B,S,D], aux_loss scalar)."""
    B, S, D = x.shape
    E, k = m.n_experts, m.top_k
    C = capacity(S, m)
    dt = x.dtype
    r = current_rules()

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                  # [B,S,E]
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # [B,S,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch LB loss: E · Σ_e f_e · P_e
    onehot_k = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # [B,S,k,E]
    f = onehot_k.sum(axis=2).mean(axis=1)                    # [B,E] token frac
    aux = E * jnp.mean(jnp.sum(f * probs.mean(axis=1), axis=-1))

    # position within expert (per sequence): running count over (S, k)
    flat_choice = onehot_k.reshape(B, S * k, E)
    pos = jnp.cumsum(flat_choice, axis=1) - flat_choice      # [B,S*k,E]
    pos = jnp.sum(pos * flat_choice, axis=-1).reshape(B, S, k)
    keep = pos < C
    slot = expert_idx * C + pos.astype(jnp.int32)            # [B,S,k]
    slot = jnp.where(keep, slot, E * C)                      # overflow bin

    # dispatch: scatter tokens into [B, E·C+1, D]
    xk = jnp.broadcast_to(x[:, :, None, :], (B, S, k, D)).reshape(B, S * k, D)
    buf = jax.vmap(
        lambda s_ids, vals: jax.ops.segment_sum(vals, s_ids,
                                                num_segments=E * C + 1)
    )(slot.reshape(B, S * k), xk)
    buf = buf[:, : E * C].reshape(B, E, C, D)
    if r is not None and r.mesh is not None:
        buf = shard(buf, r.batch, r.experts, None, None)

    # expert FFN (grouped einsum — MXU batched over E)
    up = jnp.einsum("becd,edf->becf", buf, p["wup"].astype(dt))
    gatep = jnp.einsum("becd,edf->becf", buf, p["wgate"].astype(dt))
    h = (jax.nn.silu(gatep) if activation == "swiglu"
         else jax.nn.gelu(gatep)) * up
    out_buf = jnp.einsum("becf,efd->becd", h, p["wdown"].astype(dt))
    if r is not None and r.mesh is not None:
        out_buf = shard(out_buf, r.batch, r.experts, None, None)

    # combine: gather each token's k slots back, weighted by gates
    flat = out_buf.reshape(B, E * C, D)
    flat = jnp.concatenate([flat, jnp.zeros((B, 1, D), dt)], axis=1)
    gathered = jax.vmap(lambda fb, s_ids: fb[s_ids])(flat,
                                                     slot.reshape(B, S * k))
    gathered = gathered.reshape(B, S, k, D)
    w = jnp.where(keep, gate_vals, 0.0).astype(dt)
    y = jnp.einsum("bskd,bsk->bsd", gathered, w)

    if "shared" in p:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["shared"], x, "swiglu")
    if r is not None and r.mesh is not None:
        y = shard(y, r.batch, None, None)
    return y, aux
