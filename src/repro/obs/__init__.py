"""Unified observability layer: span tracing + metrics (DESIGN.md §12).

  * ``obs.clock``   — the ``Clock`` seam (System/Virtual) every
    timestamp in the stack reads through;
  * ``obs.trace``   — structured span tracer exporting Chrome/Perfetto
    trace-event JSON (``--trace out.json`` on the drivers);
  * ``obs.metrics`` — typed counter/gauge/histogram registry exported as
    Prometheus text (``GET /metrics``) and as JSON in ``BENCH_*.json``.

This package sits BELOW core/serve/launch in the import graph (it
imports nothing from them), so any module can instrument itself without
cycles.
"""
from repro.obs.clock import Clock, SystemClock, VirtualClock
from repro.obs.trace import TRACER, Tracer, get_tracer
from repro.obs.metrics import REGISTRY, Registry

__all__ = ["Clock", "SystemClock", "VirtualClock", "Tracer", "TRACER",
           "get_tracer", "Registry", "REGISTRY"]
