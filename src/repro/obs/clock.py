"""The process-wide time-source seam (DESIGN.md §12).

Everything that timestamps — the continuous-batching engine
(serve/engine.py), the span tracer (obs/trace.py), and the wave
scheduler's straggler detector (core/multilevel.py) — reads time ONLY
through a ``Clock``. Production code gets ``SystemClock`` (monotonic);
the simulation rig swaps in ``VirtualClock``, which moves only when the
test advances it. That single seam is what makes a scripted
``VirtualClock`` service run replay to a *byte-identical* trace file:
with no wall-clock reads anywhere on the timestamp path, two runs of the
same trace produce the same floats (tests/test_obs.py).

These classes lived in serve/engine.py until the observability layer
needed them too; serve/engine re-exports them, so existing imports keep
working.
"""
from __future__ import annotations

import time


class Clock:
    """Time source seam: timestamping code never reads the wall clock
    directly."""

    def now(self) -> float:
        raise NotImplementedError


class SystemClock(Clock):
    def now(self) -> float:
        return time.monotonic()


class VirtualClock(Clock):
    """Manually-advanced clock for deterministic simulation: time moves
    only when the test rig says so, so every latency/deadline/backpressure
    behavior — and every trace timestamp — is assertable without timing
    slack."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        assert dt >= 0, dt
        self._t += float(dt)
