"""Structured span tracer with Chrome/Perfetto trace-event export
(DESIGN.md §12).

One process-wide ``Tracer`` collects *spans* (named, nested intervals),
*instant events* (point markers — the engine's scheduling log rides the
same timeline as device dispatch spans), and *counter samples* (queue
depth over time). ``export`` writes the Chrome trace-event JSON that
``chrome://tracing`` and https://ui.perfetto.dev load directly, so a
``--trace out.json`` run of any driver becomes a zoomable timeline in
which host coarsening, placement, device refine dispatches, and engine
waves are visually overlaid — the measurement ROADMAP items 1 and 5
stall on.

Design constraints, in order:

  * **~zero cost when disabled.** Every hook checks one attribute and
    returns a single shared ``nullcontext`` — no allocation, no clock
    read, no lock. The pipeline benchmark asserts the enabled overhead
    too (< 2% warm wall clock, EXPERIMENTS.md §Observability).
  * **Time through the Clock seam only** (obs/clock.py). Under a
    ``VirtualClock`` the same scripted service run replays to a
    byte-identical trace file: timestamps are virtual, the pid is fixed,
    and tids are assigned from thread-NAME first-appearance order rather
    than OS thread ids (tests/test_obs.py).
  * **Thread-aware.** Events record the emitting thread's name, so the
    engine worker thread (named ``engine-worker``) and the caller thread
    render as separate tracks.

Spans must close on the thread that opened them (the usual
``with span(...)`` shape guarantees it); cross-thread intervals are
emitted with explicit times via ``complete``.
"""
from __future__ import annotations

import contextlib
import json
import threading

from repro.obs.clock import Clock, SystemClock

# the shared do-nothing context manager: the disabled-tracer fast path
# returns THIS object every time (identity-asserted in tests/test_obs.py)
_NULL = contextlib.nullcontext()


def _json_safe(v):
    """Clamp span/instant args to JSON-able values (tuples → lists,
    anything exotic → ``str``) so export never throws mid-benchmark."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    return str(v)


class _Span:
    """Context object for one open span; created only when tracing is ON."""

    __slots__ = ("_tr", "_name", "_cat", "_args", "_t0")

    def __init__(self, tr: "Tracer", name: str, cat: str, args: dict):
        self._tr = tr
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = self._tr.clock.now()
        return self

    def __exit__(self, *exc):
        tr = self._tr
        tr._append("X", self._name, self._cat, self._t0,
                   tr.clock.now() - self._t0, self._args)
        return False


class Tracer:
    """Span/instant/counter collector bound to one ``Clock``.

    The module-level ``TRACER`` is the process default (SystemClock,
    disabled); tests and the sim rig construct their own on a
    ``VirtualClock``. All mutation is lock-protected — hooks fire from
    the engine worker thread and the caller thread concurrently.
    """

    def __init__(self, clock: Clock | None = None, *, enabled: bool = False):
        self.clock = clock or SystemClock()
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        # (ph, name, cat, t_seconds, dur_seconds, thread_name, args)
        self._events: list[tuple] = []

    # -- control ---------------------------------------------------------------
    def enable(self, clock: Clock | None = None) -> None:
        if clock is not None:
            self.clock = clock
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        with self._lock:
            self._events.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    # -- hooks (each is a no-op returning shared state when disabled) ----------
    def span(self, name: str, cat: str = "", **args):
        """``with tracer.span("coarsen", level=3): ...`` — a nested
        interval on the calling thread's track."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, cat, args)

    def complete(self, name: str, t0: float, t1: float, cat: str = "",
                 **args) -> None:
        """A finished interval with explicit clock-frame times — for
        spans whose bounds were observed elsewhere (request lifetimes,
        per-lane shares of a fused group dispatch)."""
        if not self.enabled:
            return
        self._append("X", name, cat, float(t0), float(t1) - float(t0), args)

    def instant(self, name: str, ts: float | None = None, cat: str = "",
                **args) -> None:
        if not self.enabled:
            return
        t = self.clock.now() if ts is None else float(ts)
        self._append("i", name, cat, t, None, args)

    def counter(self, name: str, value, ts: float | None = None) -> None:
        """One sample of a time-series counter track (e.g. queue depth)."""
        if not self.enabled:
            return
        t = self.clock.now() if ts is None else float(ts)
        self._append("C", name, "", t, None, {"value": value})

    def _append(self, ph: str, name: str, cat: str, t: float,
                dur: float | None, args: dict) -> None:
        ev = (ph, name, cat, t, dur, threading.current_thread().name,
              {k: _json_safe(v) for k, v in args.items()} if args else None)
        with self._lock:
            self._events.append(ev)

    # -- export ----------------------------------------------------------------
    def to_dict(self) -> dict:
        """Chrome trace-event JSON object. Deterministic by construction:
        ``pid`` is always 1 (never ``os.getpid()``), ``tid`` is the
        first-appearance rank of the thread NAME, timestamps are the
        recorded clock readings in µs rounded to ns."""
        with self._lock:
            events = list(self._events)
        tids: dict[str, int] = {}
        out = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
                "args": {"name": "repro"}}]
        body = []
        for ph, name, cat, t, dur, tname, args in events:
            tid = tids.get(tname)
            if tid is None:
                tid = tids[tname] = len(tids) + 1
                out.append({"ph": "M", "pid": 1, "tid": tid,
                            "name": "thread_name", "args": {"name": tname}})
            ev = {"ph": ph, "pid": 1, "tid": tid, "name": name,
                  "ts": round(t * 1e6, 3)}
            if cat:
                ev["cat"] = cat
            if ph == "X":
                ev["dur"] = round((dur or 0.0) * 1e6, 3)
            if ph == "i":
                ev["s"] = "t"                   # thread-scoped instant
            if args:
                ev["args"] = args
            body.append(ev)
        return {"traceEvents": out + body, "displayTimeUnit": "ms"}

    def json_bytes(self) -> bytes:
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":")).encode("utf-8")

    def export(self, path: str) -> str:
        with open(path, "wb") as f:
            f.write(self.json_bytes())
        return path


# -- the process-default tracer and its module-level hook surface --------------

TRACER = Tracer()


def get_tracer() -> Tracer:
    return TRACER


def span(name: str, cat: str = "", **args):
    return _NULL if not TRACER.enabled else _Span(TRACER, name, cat, args)


def complete(name: str, t0: float, t1: float, cat: str = "", **args) -> None:
    TRACER.complete(name, t0, t1, cat, **args)


def instant(name: str, ts: float | None = None, cat: str = "", **args) -> None:
    TRACER.instant(name, ts, cat, **args)


def counter(name: str, value, ts: float | None = None) -> None:
    TRACER.counter(name, value, ts)


def enable(clock: Clock | None = None) -> None:
    TRACER.enable(clock)


def disable() -> None:
    TRACER.disable()


def reset() -> None:
    TRACER.reset()


def export(path: str) -> str:
    return TRACER.export(path)
