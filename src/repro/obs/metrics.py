"""Typed counter/gauge/histogram registry with Prometheus text export
(DESIGN.md §12).

One process-wide ``REGISTRY`` holds every metric the stack emits:
compile-cache hits/misses/entries (core/bucketing.py), wave composition
and padding occupancy (core/multilevel.py + cached_refine_many), engine
admission/expiry/preemption counts and latency histograms
(serve/engine.py). Exported two ways:

  * Prometheus text exposition (``to_prometheus``) behind ``GET
    /metrics`` on the HTTP front door (launch/service.py) — a scraper
    pointed at a long-running service sees cache hit rate and padding
    occupancy as first-class series;
  * a JSON ``snapshot`` embedded in every ``BENCH_*.json`` and in
    ``EngineCore.stats()``, so benchmark trajectories carry the same
    numbers CI plots.

Families register idempotently (``counter(name, ...)`` returns the
existing family on re-import) and every mutation takes the registry
lock, which is the thread-safety fix for the old ``bucketing.PHASES``
process-global: phase seconds are now a labeled counter
(``gila_phase_seconds_total{phase=...}``) mutated safely from the engine
worker thread and the caller thread concurrently.

Metric names follow Prometheus conventions: ``gila_`` prefix,
``_total`` suffix on counters, base units (seconds, ratios in [0, 1])
in the name or ``unit``.
"""
from __future__ import annotations

import threading


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_str(key: tuple) -> str:
    return ",".join(f'{k}="{v}"' for k, v in key)


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _Family:
    """Base of one named metric family (all label variants of a name)."""

    kind = "untyped"

    def __init__(self, name: str, help: str, unit: str,
                 lock: threading.RLock):
        self.name = name
        self.help = help
        self.unit = unit
        self._lock = lock
        self._values: dict[tuple, float] = {}

    def values(self) -> dict[tuple, float]:
        with self._lock:
            return dict(self._values)

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(_label_key(labels), 0.0)

    def clear(self) -> None:
        with self._lock:
            self._values.clear()

    def _snapshot_values(self) -> dict:
        return {_label_str(k): v for k, v in self.values().items()}

    def snapshot(self) -> dict:
        return {"type": self.kind, "unit": self.unit,
                "values": self._snapshot_values()}


class Counter(_Family):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        assert amount >= 0, f"counter {self.name} decremented by {amount}"
        k = _label_key(labels)
        with self._lock:
            self._values[k] = self._values.get(k, 0.0) + float(amount)


class Gauge(_Family):
    """Settable gauge; ``fn`` makes it a *callback* gauge sampled at
    read/export time (e.g. live compile-cache entry count)."""

    kind = "gauge"

    def __init__(self, name, help, unit, lock, fn=None):
        super().__init__(name, help, unit, lock)
        self.fn = fn

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def values(self) -> dict[tuple, float]:
        if self.fn is not None:
            return {(): float(self.fn())}
        return super().values()

    def value(self, **labels) -> float:
        if self.fn is not None:
            return float(self.fn())
        return super().value(**labels)


class Histogram(_Family):
    """Cumulative-bucket histogram (Prometheus semantics): ``le`` bounds
    are upper-inclusive, ``+Inf`` implicit; per-label-set it tracks
    bucket counts, sum, and count."""

    kind = "histogram"
    DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0,
                       2.5, 5.0, 10.0, 30.0, 60.0)

    def __init__(self, name, help, unit, lock, buckets=None):
        super().__init__(name, help, unit, lock)
        self.buckets = tuple(sorted(buckets or self.DEFAULT_BUCKETS))
        # label key -> [bucket_counts..., count, sum]
        self._values: dict[tuple, list] = {}

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        k = _label_key(labels)
        with self._lock:
            row = self._values.get(k)
            if row is None:
                row = self._values[k] = [0] * len(self.buckets) + [0, 0.0]
            for i, le in enumerate(self.buckets):
                if v <= le:
                    row[i] += 1
            row[-2] += 1
            row[-1] += v

    def stats(self, **labels) -> dict:
        with self._lock:
            row = self._values.get(_label_key(labels))
            if row is None:
                return {"count": 0, "sum": 0.0, "buckets": {}}
            return {"count": row[-2], "sum": row[-1],
                    "buckets": {_fmt(le): row[i]
                                for i, le in enumerate(self.buckets)}}

    def _snapshot_values(self) -> dict:
        with self._lock:
            keys = list(self._values)
        return {_label_str(k): self.stats(**dict(k)) for k in keys}


class Registry:
    """Thread-safe metric registry; see module docstring. Registration is
    idempotent get-or-create, so modules can declare their metrics at
    import time in any order."""

    def __init__(self):
        self._lock = threading.RLock()
        self._families: dict[str, _Family] = {}

    def _register(self, cls, name, help, unit, **kw) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = cls(name, help, unit,
                                                 self._lock, **kw)
            assert isinstance(fam, cls), \
                f"{name} already registered as {fam.kind}"
            return fam

    def counter(self, name: str, help: str = "", unit: str = "") -> Counter:
        return self._register(Counter, name, help, unit)

    def gauge(self, name: str, help: str = "", unit: str = "",
              fn=None) -> Gauge:
        g = self._register(Gauge, name, help, unit, fn=fn)
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets=None) -> Histogram:
        return self._register(Histogram, name, help, unit, buckets=buckets)

    def get(self, name: str) -> _Family | None:
        with self._lock:
            return self._families.get(name)

    def reset(self) -> None:
        """Zero every family's values (registrations and callbacks stay)."""
        with self._lock:
            for fam in self._families.values():
                fam.clear()

    def snapshot(self) -> dict:
        """JSON-able {name: {type, unit, values}} of every family."""
        with self._lock:
            fams = list(self._families.items())
        return {name: fam.snapshot() for name, fam in sorted(fams)}

    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines = []
        with self._lock:
            fams = sorted(self._families.items())
        for name, fam in fams:
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            if isinstance(fam, Histogram):
                with fam._lock:
                    keys = list(fam._values)
                for k in sorted(keys):
                    st = fam.stats(**dict(k))
                    base = _label_str(k)
                    for le in fam.buckets:
                        sep = "," if base else ""
                        lines.append(
                            f'{name}_bucket{{{base}{sep}le="{_fmt(le)}"}}'
                            f' {st["buckets"][_fmt(le)]}')
                    lines.append(
                        f'{name}_bucket{{{base}{"," if base else ""}'
                        f'le="+Inf"}} {st["count"]}')
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{name}_sum{suffix} {_fmt(st['sum'])}")
                    lines.append(f"{name}_count{suffix} {st['count']}")
            else:
                vals = fam.values()
                if not vals and not isinstance(fam, Gauge):
                    lines.append(f"{name} 0")
                for k in sorted(vals):
                    suffix = f"{{{_label_str(k)}}}" if k else ""
                    lines.append(f"{name}{suffix} {_fmt(vals[k])}")
        return "\n".join(lines) + "\n"


REGISTRY = Registry()
