from repro.train.optim import AdamWConfig, init_opt_state, apply_updates, lr_at
from repro.train.train_step import TrainConfig, make_train_step, init_train_state
from repro.train.data import DataConfig, batch_at, extra_inputs
