"""Deterministic synthetic data pipeline (restart-safe, host-shardable).

Batches are a pure function of (seed, step) — no iterator state — so
checkpoint/restart resumes the exact stream by storing only the step, and
every host in a multi-host deployment materializes exactly its own shard
(``host_slice``). Token streams follow a skewed unigram distribution with
short-range repetition structure so the LM loss is learnable (quickstart
demonstrates loss descent).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def _unigram(rng, vocab: int, a: float, size):
    # zipf-ish via inverse CDF over ranks, clipped to vocab
    u = rng.random(size)
    raw = np.minimum(u ** (-1.0 / (a - 1.0)), float(vocab))  # clip pre-cast
    ranks = raw.astype(np.int64) - 1
    perm_seed = 12345
    perm = np.random.default_rng(perm_seed).permutation(vocab)
    return perm[np.clip(ranks, 0, vocab - 1)]


def batch_at(cfg: DataConfig, step: int, *, host_id: int = 0,
             n_hosts: int = 1) -> dict:
    """Return this host's shard of batch ``step`` (tokens, labels)."""
    assert cfg.global_batch % n_hosts == 0
    per_host = cfg.global_batch // n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_id]))
    toks = _unigram(rng, cfg.vocab, cfg.zipf_a,
                    (per_host, cfg.seq_len + 1)).astype(np.int32)
    # inject copy structure: second half of each 64-block repeats the first
    blk = 64
    nblk = (cfg.seq_len + 1) // blk
    view = toks[:, : nblk * blk].reshape(per_host, nblk, blk)
    view[:, :, blk // 2:] = view[:, :, : blk // 2]
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def extra_inputs(cfg_arch, batch_size: int, seq_len: int, seed: int = 0):
    """Frontend-stub inputs (audio frames / vlm patches) for real runs."""
    rng = np.random.default_rng(seed)
    out = {}
    if cfg_arch.enc_layers:
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch_size, seq_len, cfg_arch.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg_arch.modality == "vlm":
        from repro.models.model import VLM_PATCHES
        n = min(VLM_PATCHES, seq_len // 2)
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch_size, n, cfg_arch.d_model)) * 0.02,
            jnp.bfloat16)
    return out
