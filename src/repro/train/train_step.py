"""The jitted training step: loss → grad → (optional compression) → AdamW.

``make_train_step`` builds the step function and the in/out shardings for
the production mesh; on a single CPU device the same function runs without
a mesh. Gradient compression (int8 + error feedback) is a flag — with GSPMD
the DP reduction of bf16 grads is implicit in the grad computation, so the
compression path demonstrates/measures the collective-volume trade and is
exercised end-to-end in tests via the hand-rolled DP reduction.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import loss_fn
from repro.train.optim import AdamWConfig, OptState, init_opt_state, apply_updates
from repro.parallel.collectives import (compress_grads, decompress_grads,
                                        init_error_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optim: AdamWConfig = AdamWConfig()
    remat: str = "none"            # none | full | dots
    compress_grads: bool = False
    aux_weight: float = 0.01


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig):
    """Returns step(params, opt_state, err_state, batch) → (params, opt,
    err, metrics)."""

    def step(params, opt_state, err_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=tcfg.remat,
                              aux_weight=tcfg.aux_weight), has_aux=True
        )(params)
        if tcfg.compress_grads:
            qgrads, err_state = compress_grads(grads, err_state)
            grads = decompress_grads(qgrads)
        params, opt_state, om = apply_updates(tcfg.optim, params, grads,
                                              opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, err_state, metrics

    return step


def init_train_state(cfg: ArchConfig, tcfg: TrainConfig, params):
    opt = init_opt_state(tcfg.optim, params)
    err = init_error_state(params) if tcfg.compress_grads else None
    return opt, err
