"""AdamW with f32 master state, global-norm clipping, and LR schedules.

Pure-pytree implementation (no optax dependency): state = (step, mu, nu,
master). Params may be bf16; the master copy keeps f32 precision and the
cast back to param dtype happens once per step.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    keep_master: bool = True     # f32 master copy (off → update in param dtype)


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict
    master: dict | None


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup → cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def init_opt_state(cfg: AdamWConfig, params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # copy=True: master must not alias the params buffer (both get donated)
    master = (jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                           params)
              if cfg.keep_master else None)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros), master=master)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(cfg: AdamWConfig, params, grads, st: OptState):
    """Returns (new_params, new_state, metrics)."""
    step = st.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    ref = st.master if cfg.keep_master else params

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf, m, v

    out = jax.tree.map(upd, ref, grads, st.mu, st.nu)
    new_master = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(
        lambda pf, p: pf.astype(p.dtype), new_master, params)
    new_state = OptState(step=step, mu=new_mu, nu=new_nu,
                         master=new_master if cfg.keep_master else None)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
