"""Logical-axis sharding rules for the LM zoo (DP/TP/EP/SP + pod).

The mesh is (pod, data, model) — multi-pod — or (data, model). Parameters
and activations carry *logical* axes; `ShardingRules` resolves them to mesh
axes per architecture:

  batch   → ("pod","data")          (DP; pod is an outer DP axis)
  heads   → "model" when n_heads % model_size == 0, else replicated
            (documented per-arch in configs/*.py notes)
  mlp/vocab/ssm-inner → "model"     (Megatron TP)
  experts → "model" when n_experts % model_size == 0 (EP), else expert FFNs
            TP-sharded inside each expert
  kv_seq  → "model" for decode KV caches (flash-decoding style: the softmax
            partial reductions are inserted by GSPMD)

`shard(x, *logical)` applies with_sharding_constraint only when a mesh
context is active, so the same model code runs unsharded on one CPU device.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh | None
    batch: tuple | None            # mesh axes for the batch dim
    tp: str | None                 # "model" or None
    heads: str | None              # q-head sharding
    kv_heads: str | None
    experts: str | None            # EP axis
    expert_tp: str | None          # TP inside experts (granite fallback)
    kv_seq: str | None             # decode cache sequence sharding
    seq: str | None = None         # Megatron-SP: residual seq sharding
    moe_impl: str = "gspmd"        # gspmd | shard_map (§Perf hillclimb B)

    def spec(self, *axes) -> P:
        return P(*axes)


def make_rules(mesh: Mesh | None, cfg=None, *, seq_shard: bool = False,
               strategy: str = "tp", moe_impl: str = "gspmd") -> ShardingRules:
    """strategy "tp" = Megatron TP over the model axis (default);
    "fsdp_dp" = the model axis joins the batch axes (pure DP) and parameters
    are fully sharded (ZeRO-3) — no per-activation TP collectives, only
    per-layer param all-gathers. The right choice is model-size dependent
    (§Perf hillclimb A)."""
    if mesh is None:
        return ShardingRules(None, None, None, None, None, None, None, None)
    model = "model" if "model" in mesh.axis_names else None
    msize = mesh.shape["model"] if model else 1
    if strategy == "fsdp_dp":
        batch = ("data", "model")
        experts = None
        if (cfg is not None and cfg.moe is not None and model
                and moe_impl == "all_to_all"
                and cfg.moe.n_experts % msize == 0):
            experts = model      # EP via a2a rides the model axis
        return ShardingRules(mesh=mesh, batch=batch, tp=None, heads=None,
                             kv_heads=None, experts=experts, expert_tp=None,
                             kv_seq=None, seq=None, moe_impl=moe_impl)
    batch = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    heads = kv_heads = None
    experts = expert_tp = None
    if cfg is not None and model:
        if cfg.n_heads and cfg.n_heads % msize == 0:
            heads = model
            if cfg.n_kv_heads and cfg.n_kv_heads % msize == 0:
                kv_heads = model
        if cfg.moe is not None:
            if cfg.moe.n_experts % msize == 0:
                experts = model
            else:
                expert_tp = model
    return ShardingRules(mesh=mesh, batch=batch, tp=model, heads=heads,
                         kv_heads=kv_heads, experts=experts,
                         expert_tp=expert_tp, kv_seq=model,
                         seq=model if seq_shard else None,
                         moe_impl=moe_impl)


@contextlib.contextmanager
def use_shardings(mesh: Mesh | None, rules: ShardingRules | None):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        if mesh is not None:
            with mesh:   # classic mesh context (NamedShardings carry the mesh anyway)
                yield
        else:
            yield
    finally:
        _STATE.rules = prev


def current_rules() -> ShardingRules | None:
    return getattr(_STATE, "rules", None)


def current_mesh() -> Mesh | None:
    r = current_rules()
    return r.mesh if r else None


def batch_axes() -> tuple | None:
    r = current_rules()
    return r.batch if r else None


def shard(x, *axes):
    """with_sharding_constraint by resolved logical axes; no-op without mesh.

    ``axes`` entries are already-resolved mesh axes (strings/tuples) or None.
    """
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*axes)))


def param_shardings(mesh: Mesh, rules: ShardingRules, param_specs):
    """Map a pytree of PartitionSpecs to NamedShardings."""
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_specs, is_leaf=lambda x: isinstance(x, P))


def zero_spec(spec: P, shape, mesh: Mesh, axes=("pod", "data")) -> P:
    """ZeRO/FSDP: additionally shard the first free, divisible dim over the
    DP axes. Used for optimizer states (ZeRO-1) and, with ``fsdp``, for the
    parameters themselves (GSPMD inserts the per-layer all-gathers)."""
    axes = tuple(a for a in axes if a in mesh.axis_names)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for d, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % dp == 0 and s >= dp:
            entries[d] = axes if len(axes) > 1 else axes[0]
            return P(*entries)
    return spec


def zero_shardings(mesh: Mesh, param_spec_tree, params_struct):
    """NamedShardings with DP-dim sharding added per leaf (ZeRO layout)."""
    def one(spec, ref):
        return NamedSharding(mesh, zero_spec(spec, ref.shape, mesh))
    return jax.tree.map(one, param_spec_tree, params_struct,
                        is_leaf=lambda x: isinstance(x, P))
