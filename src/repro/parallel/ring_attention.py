"""Ring attention (context parallelism) — sequence-sharded exact attention.

For prefill/训练 at 500k-token contexts even flash attention needs the whole
KV on-device; ring attention shards the SEQUENCE over the model axis and
rotates KV blocks around the ring with `ppermute`, folding each arriving
block into a streaming softmax (the same running max/denominator as
kernels/flash_attention). Per device: Sq_loc × Sk_loc work per step, size
steps; communication (Sk_loc·KV·hd·2·2B per step) overlaps the block matmul
on TPU. Causality is enforced with GLOBAL positions, so whole future blocks
contribute nothing (their masked exp underflows to zero numerically — the
schedule stays shape-static).

This is the primitive that would lift the long_500k skip for full-attention
archs at prefill/train time; it is validated against the reference SDPA in
tests/test_distributed.py and exposed for integration.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.utils.compat import shard_map, pvary


def ring_attention(mesh: Mesh, *, axis: str = "model", causal: bool = True,
                   batch_axes=("data",)):
    """Returns f(q, k, v) with q/k/v [B, S, H|KV, hd], S sharded over
    ``axis`` (B over ``batch_axes``); computes exact (GQA) attention."""
    size = mesh.shape[axis]
    perm = [(i, (i + 1) % size) for i in range(size)]

    def local(q, k, v):
        # q [B, Sq_loc, H, hd]; k/v [B, Sk_loc, KV, hd]
        B, Sq, H, hd = q.shape
        Sk, KV = k.shape[1], k.shape[2]
        G = H // KV
        idx = jax.lax.axis_index(axis)
        qg = q.reshape(B, Sq, KV, G, hd)
        scale = hd ** -0.5
        qpos = idx * Sq + jnp.arange(Sq)

        def step(carry, s):
            m, l, acc, kb, vb = carry
            src = jax.lax.rem(idx - s + size, size)   # whose block we hold
            kpos = src * Sk + jnp.arange(Sk)
            sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb) * scale
            sc = sc.astype(jnp.float32)
            if causal:
                sc = jnp.where(qpos[:, None] >= kpos[None, :], sc, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            shift = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(sc - shift[..., None])
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - shift), 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb)
            acc = acc * corr[..., None].astype(acc.dtype) + pv
            # rotate the KV block to the next rank (overlaps compute on TPU)
            kb = jax.lax.ppermute(kb, axis, perm)
            vb = jax.lax.ppermute(vb, axis, perm)
            return (m_new, l, acc, kb, vb), None

        m0 = jnp.full((B, KV, G, Sq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Sq, hd), v.dtype)
        m0, l0, a0 = (pvary(x, (axis,)) for x in (m0, l0, a0))
        (m, l, acc, _, _), _ = jax.lax.scan(
            step, (m0, l0, a0, k, v), jnp.arange(size))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)

    ba = tuple(a for a in batch_axes if a in mesh.axis_names)
    spec = P(ba if ba else None, axis, None, None)
    return shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                         out_specs=spec, check_vma=False)
