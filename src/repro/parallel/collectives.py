"""Distributed-optimization tricks: gradient compression and overlapped
collective matmuls.

* ``compress_grads`` / ``decompress_grads`` — int8 quantization with error
  feedback (EF-SGD style): the quantization residual is carried in a state
  buffer and re-added next step, so compression error is O(1) accumulated
  rather than O(steps). Under GSPMD the all-reduce of the int8 payload moves
  4× fewer bytes across the DP axes (the collective term of the roofline).

* ``ring_collective_matmul`` — all-gather-matmul overlap: instead of
  all-gather(x) → x @ W, the x shards rotate around the TP ring with
  ``ppermute`` while each device multiplies the shard it currently holds —
  compute hides the communication (the classic collective-matmul schedule).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.utils.compat import shard_map, pvary


def quantize_int8(x: jnp.ndarray):
    """Per-tensor symmetric int8 quantization → (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error_state):
    """Quantize each grad leaf with error feedback.

    Returns (quantized pytree of (q, scale), new_error_state). The caller
    all-reduces/averages the dequantized values (GSPMD already reduced the
    true grads across DP; in a hand-rolled DP loop you would psum ``q``)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return (q, s), corrected - deq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    qs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        (q, s), err = one(g, e)
        qs.append((q, s))
        errs.append(err)
    return jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, errs)


def decompress_grads(qgrads):
    return jax.tree.map(lambda qs: dequantize_int8(*qs), qgrads,
                        is_leaf=lambda x: isinstance(x, tuple))


def init_error_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def ring_collective_matmul(mesh: Mesh, axis: str = "model"):
    """All-gather→matmul with compute/comm overlap (collective matmul).

    Computes ``all_gather(x, axis) @ w`` where x [S, K] is ROW-sharded over
    ``axis`` (sequence-parallel residual) and w [K, N] is COLUMN-sharded
    (Megatron column-parallel weight). Instead of materializing the gather,
    the x shards rotate around a ppermute ring; at step s, device d holds
    shard j = (d − s) mod size and fills output row-block j — the transfer
    of the next shard overlaps the current matmul on TPU (async collective
    permute). Output is [S, N/size] (row-complete, column-sharded).
    """
    size = mesh.shape[axis]
    perm = [(i, (i + 1) % size) for i in range(size)]

    def local(x_blk, w_blk):
        # x_blk [S/size, K], w_blk [K, N/size]
        idx = jax.lax.axis_index(axis)
        S_loc = x_blk.shape[0]

        def body(s, carry):
            acc, xs = carry
            j = jax.lax.rem(idx - s + size, size)        # shard id in hand
            part = (xs @ w_blk)[None]                    # [1, S/size, N/size]
            acc = jax.lax.dynamic_update_slice(acc, part, (j, 0, 0))
            xs = jax.lax.ppermute(xs, axis, perm)        # prefetch next shard
            return acc, xs

        acc0 = jnp.zeros((size, S_loc, w_blk.shape[1]), x_blk.dtype)
        # the carry becomes device-varying inside the loop (ppermute);
        # mark the initial zeros accordingly (shard_map vma rules)
        acc0 = pvary(acc0, (axis,))
        acc, _ = jax.lax.fori_loop(0, size, body, (acc0, x_blk))
        return acc.reshape(size * S_loc, w_blk.shape[1])

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axis, None), P(None, axis)),
                     out_specs=P(None, axis))
