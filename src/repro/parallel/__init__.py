from repro.parallel.sharding import (ShardingRules, make_rules, shard,
                                     use_shardings, current_mesh,
                                     param_shardings, batch_axes)
