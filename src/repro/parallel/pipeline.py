"""GPipe-style pipeline parallelism over the "pod" axis.

The layer-group stack (already scanned, params stacked [G, ...]) is split
into `pod`-many stages by sharding the G axis; microbatches stream through
the stages with `ppermute` handoffs. shard_map runs with
``axis_names={"pod"}`` (partial-manual), so TP/DP sharding over
data/model inside each stage is still handled by GSPMD — PP composes with
the rest of the mesh.

Schedule: plain GPipe fill-drain — T = M + S − 1 ticks; at tick t, stage s
computes microbatch (t − s) (bubbles compute garbage whose outputs are
masked out, so their gradient contribution is exactly zero). The whole
loop is a `lax.scan`, hence differentiable: `jax.grad` through it yields
the reverse pipeline automatically.

Cross-pod traffic per step: 2·M·(mb·S·D) activations (fwd + bwd) — versus
pod-DP's full gradient all-reduce; PP also divides the per-pod parameter
residency by the stage count, which is what makes >HBM models fit.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.utils.compat import shard_map, pvary


def pipeline_scan(mesh: Mesh, stage_fn, n_microbatches: int):
    """Build pp(x_mb, stage_params) → y_mb.

    stage_fn(params_local, x) applies THIS stage's layer groups (params
    already sliced to the local stage; inner dims may be TP/DP sharded by
    GSPMD). x_mb: [M, ...] microbatched activations (leading batch dim of
    each microbatch sharded over "data" as usual).
    """
    S_stages = mesh.shape["pod"]
    M = n_microbatches
    fwd_perm = [(s, s + 1) for s in range(S_stages - 1)]

    def pp(x_mb, params_local, stage_arr):
        # stage id arrives as a P("pod")-sharded iota instead of
        # lax.axis_index: inside a partial-manual region the latter lowers
        # to a partition-id HLO that 0.4.x GSPMD refuses to partition.
        stage = stage_arr[0]
        mb_shape = x_mb.shape[1:]

        def tick(prev_out, t):
            # hand the previous tick's output to the next stage
            recv = jax.lax.ppermute(prev_out, "pod", fwd_perm)
            mb_idx = t - stage
            x0 = jax.lax.dynamic_index_in_dim(
                x_mb, jnp.clip(mb_idx, 0, M - 1), axis=0, keepdims=False)
            x_in = jnp.where(stage == 0, x0, recv)
            y = stage_fn(params_local, x_in)
            return y, y                         # stack every tick's output

        y0 = pvary(jnp.zeros(mb_shape, x_mb.dtype), ("pod",))
        _, ys_all = jax.lax.scan(tick, y0, jnp.arange(M + S_stages - 1))
        # microbatch m finishes on the LAST stage at tick m + S − 1:
        # a STATIC slice of the stacked outputs (bubble ticks fall outside)
        out = ys_all[S_stages - 1: S_stages - 1 + M]
        mask = (stage == S_stages - 1).astype(x_mb.dtype)
        return jax.lax.psum(out * mask, "pod")

    sm = shard_map(pp, mesh=mesh,
                   in_specs=(P(), P("pod"), P("pod")),
                   out_specs=P(),
                   axis_names={"pod"}, check_vma=False)
    return lambda x_mb, params_local: sm(
        x_mb, params_local, jnp.arange(S_stages, dtype=jnp.int32))


def pipeline_forward(params, cfg, batch, mesh: Mesh, *,
                     n_microbatches: int = 4, remat: str = "none"):
    """Pipeline-parallel forward → logits (dense homogeneous stacks).

    Embedding/LM-head run replicated across pods (outside the pipeline);
    the scanned layer-group stack is stage-sharded over "pod" on its G axis.
    """
    import dataclasses as _dc
    from repro.models import layers as L
    from repro.models.model import _apply_sublayer, shard_batch
    from repro.parallel.sharding import current_rules, use_shardings
    pat = cfg.layer_pattern()
    assert cfg.moe is None and not cfg.enc_layers, \
        "pipeline_forward targets homogeneous dense stacks"
    tokens = batch["tokens"]
    B, S = tokens.shape
    M = n_microbatches
    assert B % M == 0

    # the pod axis carries STAGES here; inside the partial-manual region we
    # drop explicit sharding constraints entirely (mesh=None rules) — mixing
    # with_sharding_constraint with Manual axes trips an XLA:CPU SPMD bug
    # ("invalid binary instruction opcode copy"); GSPMD still infers the
    # data/model sharding inside from the operand shardings.
    outer = current_rules()
    inner_rules = _dc.replace(outer, mesh=None) if outer else None

    with use_shardings(mesh, inner_rules):
        x = L.apply_embedding(params["embed"], tokens)
        x = shard_batch(x)
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B // M, S))
        chunk = 2048 if S > 4096 else 0

        def stage_fn(gp_local, x):
            def body(x, gp):
                for i, kind in enumerate(pat):
                    x, _, _ = _apply_sublayer(gp[i], x, cfg, kind, positions,
                                              chunk=chunk)
                return x, None
            fn = body
            if remat != "none":
                fn = jax.checkpoint(lambda c, g: body(c, g),
                                    prevent_cse=False)
            y, _ = jax.lax.scan(fn, x, gp_local)
            return y

        x_mb = x.reshape((M, B // M) + x.shape[1:])
        pp = pipeline_scan(mesh, stage_fn, M)
        y_mb = pp(x_mb, params["groups"])
        y = y_mb.reshape((B,) + y_mb.shape[2:])
        y = L.apply_norm(params["final_norm"], y, cfg.norm)
        return L.apply_lm_head(params["embed"], params.get("lm_head"), y,
                               cfg.tie_embeddings)
