"""HTTP front door for the continuous-batching layout engine.

A thin stdlib ``http.server`` layer over
``serve.engine.ContinuousLayoutService`` — the fixinventory-style
multi-tenant scenario: every user's graph laid out on demand by one
always-on engine, requests joining the wave scheduler mid-flight
(DESIGN.md §11).

    PYTHONPATH=src python -m repro.launch.service --port 8080

    POST /layout   {"edges": [[u, v], ...], "n": 123, "priority": 0,
                    "deadline_s": 30.0, "seed": 7, "engine": "stress"}
        → 200 {"rid", "pos": [[x, y], ...], "levels", "latency_s"}
        → 400 malformed graph            (validation at the boundary)
        → 429 admission queue full       (bounded-queue backpressure)
        → 504 deadline exceeded / timeout
    GET  /healthz  → 200 ok
    GET  /stats    → engine counters + compile-cache stats (JSON)
    GET  /metrics  → Prometheus text exposition of the metrics registry
                     (cache hit/miss, padding occupancy, queue depth,
                     latency histograms — DESIGN.md §12)

``--trace out.json`` enables the span tracer for the server's lifetime
and writes a Chrome/Perfetto trace-event timeline on shutdown.

``--smoke`` starts the server on an ephemeral port, POSTs a few graphs
from client threads, asserts the responses, and shuts down (CI-friendly
self-test; tests/test_service.py drives the same path in-process).
"""
from __future__ import annotations

import argparse
import json
import threading
from concurrent.futures import CancelledError
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np


def make_server(svc, host: str = "127.0.0.1", port: int = 0,
                default_timeout_s: float = 300.0) -> ThreadingHTTPServer:
    """Build (but do not start) the HTTP server wrapping ``svc``.

    ``ThreadingHTTPServer`` gives one thread per connection, so a handler
    blocking on its request's Future stalls nobody else — the engine
    worker keeps admitting other requests between waves.
    """
    from repro.serve.engine import DeadlineExceeded, EngineBusy

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *args):       # quiet: CI logs stay readable
            pass

        def _json(self, code: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path == "/healthz":
                self._json(200, {"ok": True})
            elif self.path == "/stats":
                from repro.core import bucketing
                self._json(200, {"engine": svc.stats(),
                                 "compile_cache": bucketing.cache_stats()})
            elif self.path == "/metrics":
                from repro.obs import metrics as obs_metrics
                body = obs_metrics.REGISTRY.to_prometheus().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            else:
                self._json(404, {"error": f"no route {self.path}"})

        def do_POST(self):
            if self.path != "/layout":
                self._json(404, {"error": f"no route {self.path}"})
                return
            try:
                size = int(self.headers.get("Content-Length", "0"))
                body = json.loads(self.rfile.read(size) or b"{}")
                edges = np.asarray(body.get("edges", []), dtype=np.int64)
                n = body["n"]
                timeout = float(body.get("timeout_s", default_timeout_s))
            except (KeyError, ValueError, TypeError, json.JSONDecodeError) as e:
                self._json(400, {"error": f"bad request: {e}"})
                return
            try:
                req = svc.submit(
                    edges, n, priority=int(body.get("priority", 0)),
                    deadline_s=body.get("deadline_s"),
                    seed=body.get("seed"),
                    engine=body.get("engine"))
            except ValueError as e:
                self._json(400, {"error": str(e)})
                return
            except EngineBusy as e:
                self._json(429, {"error": str(e)})
                return
            try:
                pos, stats = req.result(timeout)
            except DeadlineExceeded as e:
                self._json(504, {"error": str(e), "rid": req.rid})
                return
            except CancelledError:
                self._json(409, {"error": "request cancelled",
                                 "rid": req.rid})
                return
            except TimeoutError:
                svc.cancel(req)
                self._json(504, {"error": f"no result in {timeout}s",
                                 "rid": req.rid})
                return
            self._json(200, {"rid": req.rid,
                             "pos": np.asarray(pos, np.float32).tolist(),
                             "levels": stats.levels,
                             "latency_s": round(req.latency or 0.0, 6)})

    return ThreadingHTTPServer((host, port), Handler)


def smoke() -> None:
    """Self-test: serve three graphs over HTTP, assert parity + stats."""
    import urllib.request

    from repro.core import LayoutConfig, multigila_layout
    from repro.graphs import generators as G
    from repro.serve.engine import ContinuousLayoutService

    cfg = LayoutConfig(seed=0)
    svc = ContinuousLayoutService(cfg, max_lanes=8)
    httpd = make_server(svc)
    host, port = httpd.server_address
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    try:
        graphs = [G.delaunay(90, 7 + i) for i in range(3)]
        for i, (e, n) in enumerate(graphs):
            payload = json.dumps({"edges": e.tolist(), "n": int(n),
                                  "seed": 7 + i}).encode()
            with urllib.request.urlopen(
                    f"http://{host}:{port}/layout", data=payload,
                    timeout=600) as resp:
                out = json.loads(resp.read())
            import dataclasses
            ref, _ = multigila_layout(
                e, n, dataclasses.replace(cfg, seed=7 + i))
            got = np.asarray(out["pos"], np.float32)
            assert got.shape == (n, 2), got.shape
            assert np.array_equal(got, np.asarray(ref, np.float32)), \
                "HTTP result diverged from the dedicated driver"
            print(f"[service] graph {i}: n={n} levels={out['levels']} "
                  f"latency={out['latency_s']}s", flush=True)
        with urllib.request.urlopen(f"http://{host}:{port}/stats",
                                    timeout=60) as resp:
            stats = json.loads(resp.read())
        assert stats["engine"]["completed"] == 3, stats["engine"]["completed"]
        with urllib.request.urlopen(f"http://{host}:{port}/metrics",
                                    timeout=60) as resp:
            prom = resp.read().decode()
        assert "gila_compile_cache_hits_total" in prom, prom[:400]
        assert "gila_wave_padding_occupancy_vertices" in prom, prom[:400]
        eng = {k: v for k, v in stats["engine"].items() if k != "metrics"}
        print(f"[service] smoke OK: {eng}", flush=True)
    finally:
        httpd.shutdown()
        svc.close()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--max-lanes", type=int, default=32,
                    help="concurrent component lanes the engine runs")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="admission queue bound (backpressure above it)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="record a Chrome/Perfetto trace for the server's "
                         "lifetime; written on shutdown")
    ap.add_argument("--smoke", action="store_true",
                    help="serve 3 graphs over HTTP on an ephemeral port, "
                         "assert parity, exit")
    args = ap.parse_args(argv)
    if args.smoke:
        smoke()
        return

    from repro.core import LayoutConfig
    from repro.obs import trace as obs_trace
    from repro.serve.engine import ContinuousLayoutService

    if args.trace:
        obs_trace.enable()
    svc = ContinuousLayoutService(LayoutConfig(seed=args.seed),
                                  max_queue=args.max_queue,
                                  max_lanes=args.max_lanes)
    httpd = make_server(svc, host=args.host, port=args.port)
    print(f"[service] continuous-batching layout engine on "
          f"http://{args.host}:{httpd.server_address[1]} "
          f"(max_lanes={args.max_lanes}, max_queue={args.max_queue})",
          flush=True)
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        httpd.shutdown()
        svc.close()
        if args.trace:
            obs_trace.export(args.trace)
            print(f"[service] wrote trace to {args.trace}", flush=True)


if __name__ == "__main__":
    main()
