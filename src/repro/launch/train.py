"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 200 \
        --seq 256 --batch 8 --ckpt /tmp/run1 --resume auto

Features exercised even on one CPU device (and identical on a real mesh):
  * checkpoint/restart: async sharded checkpoints, atomic, digest-validated;
    ``--resume auto`` picks the newest valid one (corrupt dirs are skipped);
  * deterministic stateless data: restart resumes the exact batch stream;
  * straggler monitor: per-step EWMA, slow steps logged with rank id;
  * elastic restore: params saved on mesh A reshard onto mesh B
    (``--model-parallel`` may differ across restarts);
  * optional int8 gradient compression with error feedback.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import init_params, input_specs
from repro.train import (TrainConfig, AdamWConfig, make_train_step,
                         init_train_state, DataConfig, batch_at, extra_inputs)
from repro.ckpt import CheckpointManager
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import make_rules, use_shardings, param_shardings
from repro.models.model import param_specs
from repro.utils.timing import StepTimer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", type=str, default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", type=str, default="none",
                    choices=["none", "auto"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(args.model_parallel) if jax.device_count() > 1 else None
    rules = make_rules(mesh, cfg)

    tcfg = TrainConfig(
        optim=AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5)),
        remat=args.remat, compress_grads=args.compress_grads)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)

    with use_shardings(mesh, rules):
        params = init_params(cfg, jax.random.PRNGKey(0))
        if mesh is not None:
            shardings = param_shardings(mesh, rules, param_specs(cfg, rules))
            params = jax.tree.map(
                lambda p, s: jax.device_put(p, s), params, shardings)
        else:
            shardings = None
        opt_state, err_state = init_train_state(cfg, tcfg, params)
        step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

        start = 0
        mgr = CheckpointManager(args.ckpt) if args.ckpt else None
        if mgr and args.resume == "auto":
            found, tree = mgr.restore_latest(
                {"params": params, "opt": opt_state},
                {"params": shardings, "opt": None} if shardings else None)
            if found is not None:
                params, opt_state = tree["params"], tree["opt"]
                start = found
                print(f"[resume] restored step {found} from {args.ckpt}")

        timer = StepTimer()
        extras = extra_inputs(cfg, args.batch, args.seq // 2
                              if cfg.enc_layers else args.seq)
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = batch_at(dcfg, step)
            if cfg.enc_layers or cfg.modality == "vlm":
                if cfg.enc_layers:  # encoder-decoder splits the budget
                    batch = {"tokens": batch["tokens"][:, : args.seq // 2],
                             "labels": batch["labels"][:, : args.seq // 2]}
                batch.update(extras)
            params, opt_state, err_state, metrics = step_fn(
                params, opt_state, err_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f}")
            dt = time.perf_counter() - t0
            if timer.record(dt):
                print(f"[straggler] rank 0 step {step} took {dt:.2f}s "
                      f"(ewma {timer.ewma:.2f}s)")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save_async(step + 1, {"params": params, "opt": opt_state})
        if mgr:
            mgr.save_async(args.steps, {"params": params, "opt": opt_state})
            mgr.wait()
            mgr.close()
        return float(metrics["loss"])


if __name__ == "__main__":
    main()
