import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import: they give this process
512 placeholder CPU devices so `make_production_mesh` can build the real
16×16 (single-pod) and 2×16×16 (two-pod) meshes; `.lower().compile()` then
proves the sharding config is coherent (no sharding mismatch, no OOM at
compile, all collectives supported) without touching real hardware.

    PYTHONPATH=src python -m repro.launch.dryrun --suite lm --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --suite layout
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --cell train_4k

Per cell it records memory_analysis (bytes/device — proves it fits),
cost_analysis, and the parsed roofline terms (launch/roofline.py) into
results/dryrun/<mesh>/<arch>__<cell>.json.
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs, cells_for, SHAPES
from repro.configs.base import ArchConfig, ShapeCell
from repro.launch.mesh import make_production_mesh, PEAK_FLOPS_BF16
from repro.launch import roofline as RL
from repro.models import model as M
from repro.models.model import param_specs, input_specs
from repro.parallel.sharding import make_rules, use_shardings, param_shardings
from repro.train.optim import AdamWConfig, init_opt_state, apply_updates
from repro.utils.tree import tree_bytes, tree_cast

HBM_PER_CHIP = 16 * 1024 ** 3       # v5e: 16 GiB


# -- sharding helpers ---------------------------------------------------------

def _batch_spec(rules, B: int):
    dp = 1
    for a in rules.batch:
        dp *= rules.mesh.shape[a]
    return rules.batch if B % dp == 0 else None


def decode_state_specs(cfg: ArchConfig, rules, B: int):
    """PartitionSpec tree matching init_decode_state's structure."""
    bs = _batch_spec(rules, B)
    pat = cfg.layer_pattern()

    def kv_spec():
        if rules.kv_heads is not None:
            s = P(None, bs, None, rules.kv_heads, None)
        else:  # flash-decoding: shard the cache sequence
            s = P(None, bs, rules.kv_seq, None, None)
        return {"kv": {"k": s, "v": s}}

    def ssm_spec():
        d_inner = cfg.ssm.expand * cfg.d_model
        H = d_inner // cfg.ssm.head_dim
        ch = d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
        msize = rules.mesh.shape["model"]
        conv = P(None, bs, None, "model" if ch % msize == 0 else None)
        h = P(None, bs, "model" if H % msize == 0 else None, None, None)
        return {"ssm": {"conv": conv, "h": h}}

    group = [kv_spec() if k == "attn" else ssm_spec() for k in pat]
    specs = {"groups": group}
    if cfg.moe is not None and cfg.moe.first_dense_ff:
        # prefix states lack the leading group axis
        def drop_lead(s):
            return P(*s[1:])
        specs["prefix"] = [jax.tree.map(
            drop_lead, group[0], is_leaf=lambda x: isinstance(x, P))]
    return specs


def _shardings_for(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# -- cell lowering -------------------------------------------------------------

@dataclasses.dataclass
class CellOpts:
    remat: str = "dots"
    seq_shard: bool = False
    params_dtype: str = "bfloat16"
    zero_opt: bool = True      # ZeRO-1: optimizer states sharded over DP
    fsdp: bool = False         # ZeRO-3: params themselves sharded over DP
    accum: int = 1             # gradient-accumulation microbatches
    strategy: str = "tp"       # tp | fsdp_dp (hillclimb A)
    moe_impl: str = "gspmd"    # gspmd | shard_map (hillclimb B)


def lower_cell(cfg: ArchConfig, cell: ShapeCell, mesh, opts: CellOpts):
    from repro.parallel.sharding import zero_shardings
    rules = make_rules(mesh, cfg, seq_shard=opts.seq_shard,
                       strategy=opts.strategy, moe_impl=opts.moe_impl)
    n_dev = mesh.devices.size
    pdtype = jnp.bfloat16 if opts.params_dtype == "bfloat16" else jnp.float32
    pspec_tree = param_specs(cfg, rules)
    params_struct = jax.eval_shape(
        lambda: tree_cast(M.init_params(cfg, jax.random.PRNGKey(0)), pdtype))
    if opts.strategy == "fsdp_dp":
        # ZeRO-3 over every axis not already used by the leaf's base spec
        # (a2a-MoE expert weights stay EP-sharded on "model")
        import repro.parallel.sharding as SH

        def one(spec, ref):
            used = set()
            for e in spec:
                if e is None:
                    continue
                used.update(e if isinstance(e, tuple) else (e,))
            free = tuple(a for a in mesh.axis_names if a not in used)
            return NamedSharding(
                mesh, SH.zero_spec(spec, ref.shape, mesh, axes=free))
        pshard = jax.tree.map(one, pspec_tree, params_struct,
                              is_leaf=lambda x: isinstance(x, P))
    elif opts.fsdp:
        pshard = zero_shardings(mesh, pspec_tree, params_struct)
    else:
        pshard = _shardings_for(mesh, pspec_tree)
    B = cell.global_batch
    bs = _batch_spec(rules, B)

    with use_shardings(mesh, rules):
        if cell.kind == "train":
            ocfg = AdamWConfig()
            opt_struct = jax.eval_shape(partial(init_opt_state, ocfg),
                                        params_struct)
            zshard = (zero_shardings(mesh, pspec_tree, params_struct)
                      if opts.zero_opt else pshard)
            oshard = type(opt_struct)(
                step=NamedSharding(mesh, P()),
                mu=zshard, nu=zshard, master=zshard)

            def step(params, opt, batch):
                from repro.models import loss_fn
                if opts.accum > 1:
                    # gradient accumulation: scan over microbatches
                    micro = jax.tree.map(
                        lambda x: x.reshape((opts.accum,
                                             x.shape[0] // opts.accum)
                                            + x.shape[1:]), batch)

                    def acc_body(carry, mb):
                        g_acc, l_acc = carry
                        (l, _), g = jax.value_and_grad(
                            lambda p: loss_fn(p, cfg, mb, remat=opts.remat),
                            has_aux=True)(params)
                        return (jax.tree.map(jnp.add, g_acc, g),
                                l_acc + l), None

                    g0 = jax.tree.map(jnp.zeros_like, params)
                    (grads, loss), _ = jax.lax.scan(
                        acc_body, (g0, jnp.zeros((), jnp.float32)), micro)
                    grads = jax.tree.map(lambda g: g / opts.accum, grads)
                    loss = loss / opts.accum
                else:
                    (loss, parts), grads = jax.value_and_grad(
                        lambda p: loss_fn(p, cfg, batch, remat=opts.remat),
                        has_aux=True)(params)
                params, opt, om = apply_updates(ocfg, params, grads, opt)
                return params, opt, loss

            batch = input_specs(cfg, cell)
            bshard = {k: NamedSharding(mesh, P(bs, *([None] * (len(v.shape) - 1))))
                      for k, v in batch.items()}
            jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_struct, opt_struct, batch)

        elif cell.kind == "prefill":
            def step(params, batch):
                logits, state, _ = M.prefill(params, cfg, batch,
                                             cache_len=_dec_len(cfg, cell),
                                             chunks=opts.accum)
                return logits, state
            batch = input_specs(cfg, cell)
            bshard = {k: NamedSharding(mesh, P(bs, *([None] * (len(v.shape) - 1))))
                      for k, v in batch.items()}
            jitted = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_struct, batch)

        else:  # decode
            cache_len = _dec_len(cfg, cell)
            state_struct = jax.eval_shape(
                partial(M.init_decode_state, cfg, B, cache_len))
            sshard = _shardings_for(mesh, decode_state_specs(cfg, rules, B))
            spec = input_specs(cfg, cell)
            tok_shard = NamedSharding(mesh, P(bs, None))
            enc = None
            if cfg.enc_layers:
                enc = spec["enc_out"]

            def step(params, token, state, pos, enc_out=None):
                return M.decode_step(params, cfg, token, state, pos,
                                     enc_out=enc_out)
            in_sh = [pshard, tok_shard, sshard, NamedSharding(mesh, P())]
            args = [params_struct, spec["token"], state_struct, spec["pos"]]
            if enc is not None:
                in_sh.append(NamedSharding(mesh, P(bs, None, None)))
                args.append(enc)
            jitted = jax.jit(step, in_shardings=tuple(in_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(*args)

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    return compiled, compile_s, rules


def _dec_len(cfg: ArchConfig, cell: ShapeCell) -> int:
    return cell.seq_len // 2 if cfg.enc_layers else cell.seq_len


def model_flops_per_device(cfg: ArchConfig, cell: ShapeCell, n_dev: int):
    N = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * _dec_len(cfg, cell)
        total = 6.0 * N * tokens
    elif cell.kind == "prefill":
        total = 2.0 * N * cell.global_batch * _dec_len(cfg, cell)
    else:
        total = 2.0 * N * cell.global_batch
    return total / n_dev


def analyze(compiled, cfg, cell, mesh, compile_s, opts):
    """Merge parsed-HLO costs with the analytic TPU model (launch/analytic).

    FLOPs + collective bytes: parsed from the SPMD HLO (dtype-exact).
    HBM bytes + resident memory: analytic model — XLA:CPU emulates bf16 in
    f32 (hoisting whole-stack converts), inflating the parsed values; those
    are kept as the `cpu_upper_bound` cross-check.
    """
    from repro.launch.analytic import analytic_cell
    n_dev = int(mesh.devices.size)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    ma = compiled.memory_analysis()
    ca = RL.normalize_cost_analysis(compiled.cost_analysis())
    text = compiled.as_text()
    cost = RL.analyze_text(text, world=n_dev)
    mf = model_flops_per_device(cfg, cell, n_dev)
    an = analytic_cell(cfg, cell, mesh_shape,
                       remat=(opts.remat != "none"),
                       zero_opt=opts.zero_opt, fsdp=opts.fsdp,
                       seq_shard=opts.seq_shard, accum=opts.accum,
                       strategy=opts.strategy)
    peak_bytes = int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)

    from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW
    compute_s = cost.flops / PEAK_FLOPS_BF16
    memory_s = an["bytes"] / HBM_BW
    coll_s = cost.coll_bytes / ICI_BW
    total = max(compute_s, memory_s, coll_s)
    bottleneck = {compute_s: "compute", memory_s: "memory",
                  coll_s: "collective"}[total]
    # roofline fraction: useful work over achievable peak. Train/prefill are
    # FLOP-normalized (MFU-like: 6·N·D / peak / step-time); decode is
    # bandwidth-normalized (its analytic bytes = params+state read once,
    # the information-theoretic floor for one token).
    if cell.kind == "decode":
        frac = memory_s / total if total > 0 else 0.0
    else:
        frac = (mf / PEAK_FLOPS_BF16 / total) if total > 0 else 0.0
    terms = {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "bottleneck": bottleneck,
        "flops": cost.flops, "bytes_analytic": an["bytes"],
        "bytes_cpu_hlo": cost.bytes, "coll_bytes": cost.coll_bytes,
        "model_flops": mf,
        "useful_ratio": mf / cost.flops if cost.flops else 0.0,
        "roofline_frac": frac,
    }
    rec = {
        "arch": cfg.name, "cell": cell.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "opts": dataclasses.asdict(opts),
        "compile_s": round(compile_s, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "peak_bytes_cpu_hlo": peak_bytes,
            "peak_bytes_analytic": int(an["peak"]),
            "fits_hbm": bool(an["peak"] < HBM_PER_CHIP),
        },
        "cost_analysis": {"flops_scan_once": float(ca.get("flops", 0.0)),
                          "bytes_scan_once": float(ca.get("bytes accessed", 0.0))},
        "roofline": terms,
        "collectives": RL.summarize_collectives(cost),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    return rec


# -- layout-engine dry-run rows ---------------------------------------------------

def lower_layout(mesh, n_pad: int, m_pad: int, cap: int, mode: str,
                 grid_dim: int = 0, cell_cap: int = 0):
    from repro.core.distributed import layout_train_step, layout_step_specs
    step, shardings = layout_train_step(mesh, n_pad, m_pad, cap, mode=mode,
                                        grid_dim=grid_dim, cell_cap=cell_cap)
    specs = layout_step_specs(n_pad, m_pad, cap, mode=mode)
    in_sh = (shardings["pos"], shardings["w"], shardings["nbr_idx"],
             shardings["edge"], shardings["edge"], shardings["edge"],
             shardings["edge"], shardings["scalar"], shardings["scalar"])
    jitted = jax.jit(step, in_shardings=in_sh)
    lowered = jitted.lower(specs["pos"], specs["w"], specs["nbr_idx"],
                           specs["src"], specs["dst_local"], specs["emask"],
                           specs["ewt"], specs["params"], specs["temp"])
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, time.time() - t0


def lower_layout_halo(mesh, n_pad: int, m_pad: int, cap: int, halo: int,
                      mode: str = "neighbor", grid_dim: int = 0,
                      cell_cap: int = 0):
    from repro.core.distributed import (layout_train_step_halo,
                                        layout_halo_specs)
    step, sh = layout_train_step_halo(mesh, n_pad, m_pad, cap, halo,
                                      mode=mode, grid_dim=grid_dim,
                                      cell_cap=cell_cap)
    specs = layout_halo_specs(mesh, n_pad, m_pad, cap, halo, mode=mode)
    in_sh = (sh["pos"], sh["w"], sh["nbr_idx"], sh["send"], sh["edge"],
             sh["edge"], sh["edge"], sh["edge"], sh["scalar"], sh["scalar"])
    jitted = jax.jit(step, in_shardings=in_sh)
    lowered = jitted.lower(specs["pos"], specs["w"], specs["nbr_local"],
                           specs["send_idx"], specs["src_local"],
                           specs["dst_local"], specs["emask"], specs["ewt"],
                           specs["params"], specs["temp"])
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, time.time() - t0


def run_layout_suite(meshes, outdir):
    from repro.configs.multigila import BIG_GRAPH_DRYRUN
    from repro.kernels.grid_force.ops import choose_grid
    results = []
    for mesh_name, mesh in meshes:
        for gname, spec in BIG_GRAPH_DRYRUN.items():
            for mode in ("neighbor", "exact", "halo", "grid", "grid_halo"):
                if mode == "exact" and spec["n_pad"] > (1 << 16):
                    continue  # exact N-body only on coarse levels
                if mode in ("halo", "grid", "grid_halo") \
                        and spec["n_pad"] <= (1 << 16):
                    continue  # halo/grid target the fine levels
                tag = f"layout_{gname}_{mode}"
                try:
                    vsize = int(np.prod(
                        [mesh.shape[a] for a in mesh.axis_names
                         if a != "model"]))
                    G, cc = choose_grid(
                        spec["n_pad"],
                        multiple_of=vsize if mode == "grid_halo" else 1)
                    if mode in ("halo", "grid_halo"):
                        halo = max(spec["n_pad"] // vsize // 8, 128)
                        compiled, cs = lower_layout_halo(
                            mesh, spec["n_pad"], spec["m_pad"], spec["cap"],
                            halo,
                            mode="grid" if mode == "grid_halo" else "neighbor",
                            grid_dim=G, cell_cap=cc)
                    else:
                        compiled, cs = lower_layout(
                            mesh, spec["n_pad"], spec["m_pad"], spec["cap"],
                            mode, grid_dim=G, cell_cap=cc)
                    ma = compiled.memory_analysis()
                    cost = RL.analyze_text(compiled.as_text(),
                                           world=int(mesh.devices.size))
                    terms = RL.roofline_terms(cost)
                    rec = {"arch": tag, "cell": "layout_step",
                           "mesh": "x".join(str(s) for s in mesh.devices.shape),
                           "compile_s": round(cs, 2),
                           "memory": {"argument_bytes": int(ma.argument_size_in_bytes),
                                      "temp_bytes": int(ma.temp_size_in_bytes),
                                      "peak_bytes": int(ma.argument_size_in_bytes
                                                        + ma.temp_size_in_bytes),
                                      "fits_hbm": bool(
                                          ma.argument_size_in_bytes
                                          + ma.temp_size_in_bytes < HBM_PER_CHIP)},
                           "roofline": terms,
                           "collectives": RL.summarize_collectives(cost)}
                    _save(outdir, mesh_name, tag, "layout_step", rec)
                    results.append((f"{tag} × {mesh_name}", "OK",
                                    terms["bottleneck"], True))
                    print(f"[layout] {tag} {mesh_name}: OK "
                          f"({terms['bottleneck']}-bound, {cs:.1f}s)")
                except Exception as e:
                    results.append((f"{tag} × {mesh_name}", "FAIL",
                                    str(e)[:100], False))
                    print(f"[layout] {tag} {mesh_name}: FAIL {e}")
                    traceback.print_exc()
    return results


def run_pp_suite(outdir):
    """Pipeline-parallel proof on the 2-pod mesh: gemma-2b forward+grad with
    2 stages over the pod axis × TP16 × DP16 inside each stage.

    f32 activations (REPRO_ACT_DTYPE): XLA:CPU crashes on bf16 inside
    partial-manual shard_map regions; TPU-native bf16 is unaffected.
    """
    os.environ["REPRO_ACT_DTYPE"] = "float32"
    import importlib
    import repro.models.layers as RL_layers
    importlib.reload(RL_layers)
    from repro.parallel.pipeline import pipeline_forward
    mesh = make_production_mesh(multi_pod=True)
    cfg = get_config("gemma-2b")
    rules = make_rules(mesh, cfg)
    params_struct = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
             "labels": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    pspec = param_specs(cfg, rules)
    # stage-shard the scanned group axis over "pod"
    pspec["groups"] = jax.tree.map(
        lambda s: P("pod", *s[1:]), pspec["groups"],
        is_leaf=lambda x: isinstance(x, P))
    pshard = _shardings_for(mesh, pspec)

    def step(params, batch):
        def loss(p):
            lg = pipeline_forward(p, cfg, batch, mesh, n_microbatches=8)
            return jnp.mean(lg.astype(jnp.float32) ** 2)
        return jax.grad(loss)(params)

    with use_shardings(mesh, rules):
        t0 = time.time()
        compiled = jax.jit(step, in_shardings=(pshard, None)).lower(
            params_struct, batch).compile()
        cs = time.time() - t0
    cost = RL.analyze_text(compiled.as_text(), world=512)
    ma = compiled.memory_analysis()
    rec = {"arch": "gemma-2b-pp2", "cell": "train_fwd_bwd",
           "mesh": "2x16x16", "compile_s": round(cs, 2),
           "roofline": RL.roofline_terms(cost),
           "memory": {"temp_bytes": int(ma.temp_size_in_bytes)},
           "collectives": RL.summarize_collectives(cost)}
    _save(outdir, "pods2x16x16", "gemma-2b-pp2", "train_fwd_bwd", rec)
    print(f"[pp] gemma-2b 2-stage pipeline × TP16 × DP16: OK "
          f"(compile {cs:.0f}s, coll {cost.coll_bytes/1e9:.1f} GB/dev)")
    out = [("gemma-2b-pp2 × 2x16x16", "OK", "pipeline", True)]

    # ring attention (context parallelism) at 32k context on the pod mesh
    from repro.parallel.ring_attention import ring_attention
    mesh1 = make_production_mesh(multi_pod=False)
    B, S, H, KV, hd = 32, 32768, 16, 8, 128
    fn = ring_attention(mesh1, causal=True)
    spec_q = jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32)
    spec_kv = jax.ShapeDtypeStruct((B, S, KV, hd), jnp.float32)
    t0 = time.time()
    comp = jax.jit(fn).lower(spec_q, spec_kv, spec_kv).compile()
    cs = time.time() - t0
    cost = RL.analyze_text(comp.as_text(), world=256)
    rec = {"arch": "ring-attention-32k", "cell": "prefill_attn_layer",
           "mesh": "16x16", "compile_s": round(cs, 2),
           "roofline": RL.roofline_terms(cost),
           "collectives": RL.summarize_collectives(cost)}
    _save(outdir, "pod16x16", "ring-attention-32k", "prefill_attn_layer", rec)
    print(f"[ring] 32k-context ring attention layer: OK (compile {cs:.0f}s, "
          f"coll {cost.coll_bytes/1e9:.1f} GB/dev)")
    out.append(("ring-attention-32k × 16x16", "OK", "context-parallel", True))
    return out


def _save(outdir, mesh_name, arch, cell, rec):
    d = os.path.join(outdir, mesh_name)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{arch}__{cell}.json"), "w") as f:
        json.dump(rec, f, indent=1)


# -- main -------------------------------------------------------------------------

def cell_opts_for(cfg: ArchConfig, cell: ShapeCell,
                  mesh_shape: dict | None = None) -> CellOpts:
    """Baseline options with memory-driven escalation: if the analytic
    resident set exceeds HBM, enable (in order) sequence-parallel residuals,
    FSDP, then gradient accumulation — the same search a production config
    pass would run. Every escalation is recorded in the cell JSON."""
    from repro.launch.analytic import analytic_cell
    mesh_shape = mesh_shape or {"data": 16, "model": 16}
    opts = CellOpts(remat="full" if cell.kind == "train" else "none",
                    seq_shard=False,
                    fsdp=(cell.kind == "train"
                          and cfg.param_count() * 2 / 16 > 4 * 2 ** 30))

    def peak(o):
        return analytic_cell(cfg, cell, mesh_shape,
                             remat=(o.remat != "none"), zero_opt=o.zero_opt,
                             fsdp=o.fsdp, seq_shard=o.seq_shard,
                             accum=o.accum)["peak"]

    if cell.kind == "decode":
        return opts
    if cell.kind == "prefill":   # escalate via chunked prefill
        for escalation in (dict(accum=2), dict(accum=4)):
            if peak(opts) < HBM_PER_CHIP * 0.95:
                break
            opts = dataclasses.replace(opts, **escalation)
        return opts
    for escalation in (dict(seq_shard=True), dict(fsdp=True),
                       dict(accum=2), dict(accum=4), dict(accum=8)):
        if peak(opts) < HBM_PER_CHIP * 0.95:
            break
        opts = dataclasses.replace(opts, **escalation)
    return opts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="lm",
                    choices=["lm", "layout", "pp", "all"])
    ap.add_argument("--arch", default="")
    ap.add_argument("--cell", default="")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--seq-shard", default="auto",
                    choices=["auto", "on", "off"])
    ap.add_argument("--remat", default="")
    ap.add_argument("--strategy", default="", choices=["", "tp", "fsdp_dp"])
    ap.add_argument("--moe-impl", default="",
                    choices=["", "gspmd", "shard_map", "all_to_all"])
    args = ap.parse_args(argv)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pods2x16x16", make_production_mesh(multi_pod=True)))

    summary = []
    if args.suite in ("layout", "all"):
        summary += run_layout_suite(meshes, args.out)
    if args.suite == "pp":
        summary += run_pp_suite(args.out)

    if args.suite in ("lm", "all"):
        archs = [args.arch] if args.arch else list_archs()
        for name in archs:
            cfg = get_config(name)
            cells = ([SHAPES[args.cell]] if args.cell else cells_for(cfg))
            for cell in cells:
                opts = cell_opts_for(cfg, cell)  # escalation vs 16 GiB HBM
                if args.seq_shard != "auto":
                    opts = dataclasses.replace(
                        opts, seq_shard=args.seq_shard == "on")
                if args.remat:
                    opts = dataclasses.replace(opts, remat=args.remat)
                if args.strategy:
                    opts = dataclasses.replace(opts, strategy=args.strategy)
                if args.moe_impl:
                    opts = dataclasses.replace(opts, moe_impl=args.moe_impl)
                for mesh_name, mesh in meshes:
                    tag = f"{name} × {cell.name} × {mesh_name}"
                    try:
                        t0 = time.time()
                        compiled, cs, rules = lower_cell(cfg, cell, mesh, opts)
                        rec = analyze(compiled, cfg, cell, mesh, cs, opts)
                        _save(args.out, mesh_name, name, cell.name, rec)
                        r = rec["roofline"]
                        fits = rec["memory"]["fits_hbm"]
                        print(f"[OK]   {tag}: {r['bottleneck']}-bound "
                              f"frac={r['roofline_frac']:.2f} "
                              f"peak={rec['memory']['peak_bytes_analytic']/2**30:.1f}GiB "
                              f"fits={fits} compile={cs:.0f}s "
                              f"total={time.time()-t0:.0f}s", flush=True)
                        summary.append((tag, "OK", r["bottleneck"], fits))
                        del compiled
                    except Exception as e:
                        print(f"[FAIL] {tag}: {e}", flush=True)
                        traceback.print_exc()
                        summary.append((tag, "FAIL", str(e)[:100], False))

    n_ok = sum(1 for s in summary if s[1] == "OK")
    print(f"\n=== dry-run summary: {n_ok}/{len(summary)} OK ===")
    for s in summary:
        if s[1] != "OK":
            print("  FAILED:", s[0], s[2])
    return 0 if n_ok == len(summary) else 1


if __name__ == "__main__":
    raise SystemExit(main())
