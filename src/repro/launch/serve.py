"""Layout serving CLI — build a quadtree tile pyramid from a layout run,
benchmark batched viewport queries against it, or smoke-test the stack.

    # build: layout a graph, derive the pyramid, persist it
    PYTHONPATH=src python -m repro.launch.serve --build \
        --graph delaunay --args 100000 --out results/serve/delaunay100k

    # bench: closed-loop load generator, p50/p99 latency + sustained QPS
    PYTHONPATH=src python -m repro.launch.serve --bench \
        --out results/serve/delaunay100k --batches 1,16,64

    # smoke (CI): tiny end-to-end build → save → load → batched queries
    PYTHONPATH=src python -m repro.launch.serve --smoke

Bench results land in the benchmark JSON format under --json
(default results/serve/bench.json); EXPERIMENTS.md §Serving records the
observed numbers.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core import multigila_layout, LayoutConfig
from repro.graphs import generators
from repro.graphs.io import load_edgelist
from repro.serve import (build_pyramid, save_pyramid, load_pyramid,
                         QueryEngine, MicroBatcher)
from repro.serve.query import random_viewports


def _load_graph(args):
    if args.edgelist:
        edges, n = load_edgelist(args.edgelist)
        print(f"edgelist {args.edgelist}: n={n} m={len(edges)}")
    else:
        edges, n, gargs = generators.from_cli(args.graph, args.args)
        print(f"graph {args.graph}{gargs}: n={n} m={len(edges)}")
    return edges, n


def build(args) -> str:
    edges, n = _load_graph(args)
    cfg = LayoutConfig(engine=args.engine, seed=args.seed,
                       coarsest_iters=args.coarsest_iters,
                       finest_iters=args.finest_iters)
    t0 = time.perf_counter()
    pos, stats, exp = multigila_layout(edges, n, cfg, export=True)
    t_layout = time.perf_counter() - t0
    print(f"layout: levels={stats.levels} time={t_layout:.1f}s")
    t0 = time.perf_counter()
    pyr = build_pyramid(exp, tile_cap=args.tile_cap, edge_cap=args.edge_cap,
                        max_zoom=args.max_zoom)
    save_pyramid(args.out, pyr)
    t_build = time.perf_counter() - t0
    shards = len(os.listdir(args.out)) - 1   # minus manifest.json
    for b, band in enumerate(pyr.bands):
        occ = band.tile_count.sum() / max((band.tile_count > 0).sum(), 1)
        print(f"  band {b}: zoom {band.zoom} ({band.tiles_per_axis}^2 tiles) "
              f"n={band.n} m={band.m} mean-occ={occ:.1f} "
              f"overfull={(band.tile_total > band.tile_count).sum()}")
    print(f"pyramid: {shards} tile shards, built+saved in {t_build:.1f}s "
          f"→ {args.out}")
    return args.out


def bench(args) -> list[dict]:
    pyr = load_pyramid(args.out)
    eng = QueryEngine(pyr)
    zoom_max = max(b.zoom for b in pyr.bands)
    batches = [int(b) for b in args.batches.split(",")]
    eng.warmup(tuple(QueryEngine._bucket(b) for b in batches))
    rows = []
    for B in batches:
        boxes, zs = random_viewports(pyr.lo, pyr.hi, zoom_max,
                                     max(args.reqs, B), seed=args.seed)
        n_batches = len(boxes) // B
        lat = []
        t_start = time.perf_counter()
        for i in range(n_batches):
            t0 = time.perf_counter()
            eng.query(boxes[i * B:(i + 1) * B], zs[i * B:(i + 1) * B])
            lat.append(time.perf_counter() - t0)
        total = time.perf_counter() - t_start
        # closed loop: every request in a batch observes its batch's latency
        per_req = np.repeat(lat, B)
        row = {"batch": B, "requests": n_batches * B,
               "qps": n_batches * B / total,
               "p50_ms": float(np.percentile(per_req, 50) * 1e3),
               "p99_ms": float(np.percentile(per_req, 99) * 1e3)}
        rows.append(row)
        print(f"  B={B:3d}: {row['qps']:9.1f} qps   "
              f"p50 {row['p50_ms']:7.2f} ms   p99 {row['p99_ms']:7.2f} ms")
    if rows and len(rows) > 1:
        print(f"  batched speedup B={rows[-1]['batch']} vs B={rows[0]['batch']}: "
              f"{rows[-1]['qps'] / rows[0]['qps']:.1f}× qps")
    os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
    rec = {"pyramid": args.out,
           "bands": [{"zoom": b.zoom, "n": b.n, "m": b.m} for b in pyr.bands],
           "tile_cap": pyr.tile_cap, "edge_cap": pyr.edge_cap,
           "reqs": args.reqs, "rows": rows}
    with open(args.json, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"wrote {args.json}")
    return rows


def smoke(args) -> None:
    """CI end-to-end: tiny build → save → load → 16 batched queries."""
    with tempfile.TemporaryDirectory() as d:
        args.out = os.path.join(d, "pyr")
        args.graph, args.args, args.edgelist = "gnp", [2000, 4.0], ""
        build(args)
        pyr = load_pyramid(args.out, validate=True)
        eng = QueryEngine(pyr)
        mb = MicroBatcher(eng, max_batch=16, window_s=0.01)
        zoom_max = max(b.zoom for b in pyr.bands)
        boxes, zs = random_viewports(pyr.lo, pyr.hi, zoom_max, 16,
                                     seed=args.seed)
        futs = [mb.submit(boxes[i], int(zs[i])) for i in range(16)]
        results = [f.result(timeout=60) for f in futs]
        mb.close()
        n_nonempty = sum(len(r["vid"]) > 0 for r in results)
        assert n_nonempty >= 12, f"only {n_nonempty}/16 queries returned data"
        assert any(len(r["eid"]) > 0 for r in results), "no edges served"
        print(f"serve smoke OK: {n_nonempty}/16 non-empty, "
              f"{mb.batches} device batch(es) for {mb.requests} requests")


def main(argv=None):
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--build", action="store_true")
    mode.add_argument("--bench", action="store_true")
    mode.add_argument("--smoke", action="store_true")
    ap.add_argument("--graph", default="gnp",
                    help="generator name from repro.graphs.generators")
    ap.add_argument("--args", nargs="*", type=float, default=[2000, 4.0])
    ap.add_argument("--edgelist", default="",
                    help="edge-list/.mtx file instead of a generator")
    ap.add_argument("--engine", default="multigila")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/serve/pyramid")
    ap.add_argument("--tile-cap", type=int, default=64)
    ap.add_argument("--edge-cap", type=int, default=96)
    ap.add_argument("--max-zoom", type=int, default=8)
    ap.add_argument("--coarsest-iters", type=int, default=300)
    ap.add_argument("--finest-iters", type=int, default=50)
    ap.add_argument("--batches", default="1,16,64")
    ap.add_argument("--reqs", type=int, default=512,
                    help="closed-loop requests per batch size")
    ap.add_argument("--json", default="results/serve/bench.json")
    args = ap.parse_args(argv)

    if args.build:
        return build(args)
    if args.bench:
        return bench(args)
    return smoke(args)


if __name__ == "__main__":
    main()
