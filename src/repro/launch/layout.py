"""End-to-end Multi-GiLA driver (the paper's pipeline).

    PYTHONPATH=src python -m repro.launch.layout --graph grid --args 40 40 \
        --engine multigila --svg /tmp/grid.svg

Runs pruning → coarsening → placement/refinement → reinsertion, reports the
paper's quality metrics (CRE, NELD) + timing, optionally writes an SVG.

``--many B`` instead lays out B seed-varied requests of the graph through
the batched multi-graph driver (``multigila_layout_many``) — one vmapped
device program per level wave — and reports graphs/sec;
``--many-compare`` additionally runs the sequential single-graph driver
over the same requests and checks per-graph bit-identity (DESIGN.md §9,
benchmarks/many_bench.py for the measured suite).

``--trace out.json`` records a Chrome/Perfetto span timeline of the run
(coarsen/place/refine per level — per lane under ``--many``; open in
https://ui.perfetto.dev, DESIGN.md §12).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.graphs import generators
from repro.graphs.metrics import quality_report
from repro.graphs.graph import build_graph
from repro.graphs.io import save_svg
from repro.core import multigila_layout, multigila_layout_many, LayoutConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="grid",
                    help="generator name from repro.graphs.generators")
    ap.add_argument("--args", nargs="*", type=float, default=[20, 20])
    ap.add_argument("--engine", default="multigila",
                    choices=["multigila", "multigila_dist", "centralized",
                             "flat", "gila", "stress"],
                    help="refinement engine (gila | stress); the driver "
                         "names stay accepted for back-compat and select "
                         "--driver instead (LayoutConfig shim)")
    ap.add_argument("--driver", default=None,
                    choices=["multigila", "multigila_dist", "centralized",
                             "flat"],
                    help="hierarchy driver (default multigila)")
    ap.add_argument("--mesh", default="",
                    help="multigila_dist mesh as DATAxMODEL, e.g. 4x2 "
                         "(default: one mesh over all local devices)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--svg", default="")
    ap.add_argument("--no-cre", action="store_true")
    ap.add_argument("--many", type=int, default=0, metavar="B",
                    help="lay out B seed-varied requests through the "
                         "batched multi-graph driver")
    ap.add_argument("--many-compare", action="store_true",
                    help="with --many: also run the sequential driver and "
                         "check per-graph bit-identity")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="write a Chrome/Perfetto trace of the run")
    args = ap.parse_args(argv)

    if args.trace:
        from repro.obs import trace as obs_trace
        obs_trace.enable()

    edges, n, gargs = generators.from_cli(args.graph, args.args)
    print(f"graph {args.graph}{gargs}: n={n} m={len(edges)}")

    mesh_shape = (tuple(int(s) for s in args.mesh.split("x"))
                  if args.mesh else None)
    cfg = LayoutConfig(engine=args.engine, seed=args.seed,
                       mesh_shape=mesh_shape)
    if args.driver is not None:
        cfg = dataclasses.replace(cfg, driver=args.driver)

    if args.many > 0:
        B = args.many
        seeds = [args.seed + i for i in range(B)]
        reqs = [(edges, n)] * B
        t0 = time.perf_counter()
        outs = multigila_layout_many(reqs, cfg, seeds=seeds)
        dt = time.perf_counter() - t0
        print(f"batched: {B} layouts in {dt:.2f}s = {B / dt:.2f} graphs/s "
              f"(levels={outs[0][1].levels})")
        if args.many_compare:
            t0 = time.perf_counter()
            seq = [multigila_layout(e, nn,
                                    dataclasses.replace(cfg, seed=s))
                   for (e, nn), s in zip(reqs, seeds)]
            ds = time.perf_counter() - t0
            same = all(np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
                       for a, b in zip(seq, outs))
            print(f"sequential: {ds:.2f}s = {B / ds:.2f} graphs/s → "
                  f"batched speedup {ds / dt:.2f}x, bit-identical={same}")
        pos, stats = outs[0]
    else:
        t0 = time.perf_counter()
        pos, stats = multigila_layout(edges, n, cfg)
        dt = time.perf_counter() - t0
        print(f"levels={stats.levels} sizes={stats.level_sizes} time={dt:.2f}s")

    g = build_graph(edges, n)
    rep = quality_report(g, np.pad(pos, ((0, g.n_pad - n), (0, 0))),
                         max_cre_edges=0 if args.no_cre else 40000)
    print(f"CRE={rep['cre']:.3f} NELD={rep['neld']:.3f} "
          f"stress={rep['stress']:.4f}")
    if args.svg:
        save_svg(args.svg, pos, edges)
        print(f"wrote {args.svg}")
    if args.trace:
        from repro.obs import trace as obs_trace
        obs_trace.export(args.trace)
        print(f"wrote trace to {args.trace} "
              f"({len(obs_trace.get_tracer())} events)")
    return rep


if __name__ == "__main__":
    main()
