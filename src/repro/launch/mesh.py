"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state; dryrun.py sets XLA_FLAGS for 512 placeholder devices BEFORE
importing jax and then calls this.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (CPU tests / local runs)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link direction
