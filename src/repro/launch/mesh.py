"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state; dryrun.py sets XLA_FLAGS for 512 placeholder devices BEFORE
importing jax and then calls this.
"""
from __future__ import annotations

import numpy as np

import jax


def make_compat_mesh(shape, axes):
    """Version-portable mesh constructor.

    `jax.sharding.AxisType` and `jax.make_mesh(axis_types=...)` only exist on
    newer JAX; on 0.4.x every mesh axis is implicitly Auto, so plain
    `jax.make_mesh` (or `Mesh` on even older versions) is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    devices = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (CPU tests / local runs)."""
    n = jax.device_count()
    assert n % model == 0
    return make_compat_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link direction
