"""Analytic per-cell cost model (TPU-native bytes/memory).

XLA:CPU has no native bf16: the compiled HLO upcasts bf16 operands to f32
(and hoists whole-stack converts out of loops), inflating both
memory_analysis and byte-traffic counts by up to ~2-3× versus what the same
program costs on a TPU. The FLOP and collective counts parsed from HLO are
dtype-exact and unaffected; bytes and peak memory are therefore modeled
analytically here (and the parsed values are reported as the CPU upper
bound). Constants are deliberately simple and stated inline — this is the
napkin-math layer of the roofline, cross-checked against the parsed values
in tests.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeCell

BF16 = 2
F32 = 4


def _shards(cfg: ArchConfig, mesh_shape: dict) -> tuple[int, int]:
    """(dp, tp) shard counts."""
    tp = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    return dp, tp


def _param_bytes_dev(cfg: ArchConfig, tp: int) -> float:
    """bf16 param bytes per device. Attention params replicate when heads
    don't divide tp (configs/*.py notes)."""
    P = cfg.param_count()
    if cfg.n_heads and cfg.n_heads % tp != 0:
        attn = cfg.n_layers * (cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads)
                               * cfg.hd + cfg.n_heads * cfg.hd * cfg.d_model)
        return ((P - attn) / tp + attn) * BF16
    return P / tp * BF16


def analytic_cell(cfg: ArchConfig, cell: ShapeCell, mesh_shape: dict,
                  *, remat: bool = True, zero_opt: bool = True,
                  fsdp: bool = False, seq_shard: bool = False,
                  accum: int = 1, strategy: str = "tp") -> dict:
    """→ dict(bytes=HBM traffic/device/step, peak=resident bytes/device)."""
    dp, tp = _shards(cfg, mesh_shape)
    if strategy == "fsdp_dp":
        dp, tp, fsdp = dp * tp, 1, True
    B = cell.global_batch
    S = cell.seq_len // 2 if cfg.enc_layers else cell.seq_len
    B_loc = max(B // dp, 1)
    D, L = cfg.d_model, cfg.n_layers
    Vloc = cfg.vocab_padded // tp
    pdev = _param_bytes_dev(cfg, tp)
    n_attn = sum(1 for i in range(L)
                 if cfg.layer_pattern()[i % len(cfg.layer_pattern())] == "attn")
    H_loc = max(cfg.n_heads // tp, 1) if cfg.n_heads else 0

    if cell.kind == "train":
        tok_loc = B_loc * S
        if fsdp:
            pdev = pdev / dp
        # params: fwd read + remat re-read + dgrad + wgrad passes, once per
        # accumulation microbatch (FSDP re-materializes per layer each pass)
        param_traffic = (4 if remat else 3) * _param_bytes_dev(cfg, tp) * accum
        # optimizer: read grad+mu+nu+master, write mu+nu+master+param
        opt_shards = tp * (dp if zero_opt else 1)
        opt_traffic = 8 * (cfg.param_count() / opt_shards) * F32
        # activations: ~c tensor r/w per layer of the residual-sized stream
        # (qkv/o/mlp in+out, norms, residual adds; MoE dispatch doubles it)
        c = 30 if cfg.moe is not None else 20
        act = L * tok_loc * D * BF16 * c
        # attention score traffic (flash-chunked: scores never hit HBM when
        # S ≤ chunk; above that, ~2 r/w of the running blocks)
        attn_scores = n_attn * B_loc * H_loc * S * min(S, 2048) * BF16 * 2
        logits = 3 * tok_loc * Vloc * F32 * 2            # fwd+bwd, lse etc.
        traffic = param_traffic + opt_traffic + act + attn_scores + logits
        # resident: params + opt(3×f32, ZeRO over DP) + grads + residual
        # stack (seq-sharded under SP) + logits workspace
        tok_mb = tok_loc / accum          # per-microbatch activation terms
        stack = (L * tok_mb * D * BF16 if remat
                 else 3 * L * tok_mb * D * BF16)
        if seq_shard:
            stack /= tp
        # with accumulation the grad accumulator is always resident
        grads = cfg.param_count() / tp / (dp if fsdp else 1) * BF16 \
            * (2 if accum > 1 else 1)
        peak = (pdev + 3 * cfg.param_count() / opt_shards * F32
                + grads + stack + 2 * tok_mb * Vloc * F32
                + 6 * tok_mb * D * BF16)
    elif cell.kind == "prefill":
        tok_loc = B_loc * S
        c = 18 if cfg.moe is not None else 12
        act = L * tok_loc * D * BF16 * c
        attn_scores = n_attn * B_loc * H_loc * S * min(S, 2048) * BF16 * 2
        kv = n_attn * B_loc * S * cfg.n_kv_heads * cfg.hd * BF16 * 2
        # cache resident set: sharded over kv-heads when divisible, else
        # seq-sharded once the stack exceeds 8 GiB (models/layers.py rule)
        if cfg.n_kv_heads and cfg.n_kv_heads % tp == 0:
            kv_res = kv / tp
        elif kv > 8 * 2 ** 30:
            kv_res = kv / tp
        else:
            kv_res = kv
        traffic = pdev * accum + act + attn_scores + kv
        # chunked prefill (accum chunks) divides the activation live-set
        tok_mb = tok_loc / accum
        peak = pdev + kv_res + 8 * tok_mb * D * BF16 + tok_mb * Vloc * BF16
    else:  # decode: one token — read all params + the KV/SSM state
        kv_dev = n_attn * B * S * cfg.n_kv_heads * cfg.hd * BF16 * 2 / (
            dp * tp if B % dp == 0 else tp)
        ssm_dev = 0.0
        if cfg.ssm is not None:
            di = cfg.ssm.expand * D
            Hs = di // cfg.ssm.head_dim
            n_ssm = L - n_attn
            ssm_dev = (n_ssm * B * Hs * cfg.ssm.d_state * cfg.ssm.head_dim
                       * BF16 / max(dp if B % dp == 0 else 1, 1) / 1)
            ssm_dev /= tp if Hs % tp == 0 else 1
        traffic = pdev + kv_dev + 2 * ssm_dev
        peak = pdev + kv_dev + ssm_dev + B_loc * Vloc * F32
    return {"bytes": float(traffic), "peak": float(peak)}
