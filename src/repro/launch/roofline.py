"""Roofline-term extraction from compiled SPMD HLO (DESIGN.md §5).

`compiled.cost_analysis()` on XLA:CPU is per-device and counts while-loop
bodies ONCE. This module re-derives per-device FLOPs / HBM bytes /
collective bytes by walking the optimized HLO call graph and multiplying
while bodies by their trip counts (taken from the `known_trip_count`
backend config XLA attaches to every counted loop — scans over layers,
attention KV chunks, SSD chunk scans are all covered, nested included).

Accounting rules (mirrors what cost_analysis fuses):
  * FLOPs: dots = 2·|out|·K (K from contracting dims); elementwise math =
    |out|; reduces = |operand|. Fusion bodies contribute FLOPs once per
    call; fusion-internal traffic contributes no bytes.
  * bytes: operands+result of every top-level instruction (fusion calls
    count at the call boundary) — an HBM-traffic proxy at fusion
    granularity.
  * collectives: ring-model bytes/device — all-gather/reduce-scatter
    (g−1)/g·size, all-reduce 2(g−1)/g·size, all-to-all (g−1)/g·size,
    collective-permute size — with g parsed from replica_groups.

Self-check: with trip counts forced to 1 the FLOPs agree with
cost_analysis() (validated in tests/test_roofline.py).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
    "remainder", "atan2", "expm1", "log-plus-one", "cbrt", "erf",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"^(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(ROOT\s+)?%([\w.\-]+)\s+=\s+(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")


def normalize_cost_analysis(ca) -> dict:
    """`Compiled.cost_analysis()` → flat dict, across JAX versions.

    JAX 0.4.x returns a one-element list of per-device dicts; newer JAX
    returns the dict directly; some backends return None.
    """
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def _parse_shape(type_str):
    """'f32[64,128]{1,0}' → (dtype, shape) | None for tuples/tokens."""
    m = _SHAPE_RE.match(type_str)
    if not m:
        return None
    dt, dims = m.group(1), m.group(2)
    if dt not in DTYPE_BYTES:
        return None
    shape = tuple(int(d) for d in dims.split(",")) if dims else ()
    return dt, shape


def _nbytes(sh):
    if sh is None:
        return 0
    dt, shape = sh
    return DTYPE_BYTES[dt] * int(np.prod(shape)) if shape else DTYPE_BYTES[dt]


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    shape: tuple | None
    operands: list
    rest: str


def parse_module(text: str):
    """→ (computations: name → [Instr], entry_name, shapes: name → shape)."""
    computations: dict[str, list[Instr]] = {}
    shapes: dict[str, tuple | None] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and line.rstrip().endswith("{"):
            cur = mc.group(2)
            computations[cur] = []
            if mc.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if not (cur and mi):
            continue
        name, body = mi.group(2), mi.group(3)
        sh = _parse_shape(body)
        # tuple results: leave shape None (elements resolved via gte)
        # opcode = first word after the type
        rest = body
        # strip the result type
        depth = 0
        i = 0
        if body.startswith("("):
            for i, ch in enumerate(body):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            rest = body[i + 1:].strip()
        else:
            sp = body.find(" ")
            rest = body[sp + 1:].strip() if sp > 0 else ""
        mop = re.match(r"([\w\-]+)\(", rest)
        opcode = mop.group(1) if mop else rest.split("(")[0].strip()
        operands = re.findall(r"%([\w.\-]+)", rest.split("),", 1)[0]
        ) if "(" in rest else []
        shapes[name] = sh
        computations[cur].append(Instr(name, opcode, sh, operands, rest))
    return computations, entry, shapes


def _group_size(rest: str, world: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return world


def _dot_flops(instr: Instr, shapes) -> float:
    out = instr.shape
    if out is None:
        return 0.0
    lhs_sh = shapes.get(instr.operands[0]) if instr.operands else None
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    k = 1
    if lhs_sh and m and m.group(1):
        for d in m.group(1).split(","):
            k *= lhs_sh[1][int(d)]
    return 2.0 * float(np.prod(out[1]) if out[1] else 1) * k


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: list = dataclasses.field(default_factory=list)

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        self.coll_detail += o.coll_detail
        return self

    def scaled(self, k: float):
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    [(n, b * k, g, int(mult * k)) for (n, b, g, mult)
                     in self.coll_detail])


def analyze_text(text: str, world: int = 1, *, force_trip_one: bool = False):
    """Parse optimized HLO → per-device Cost with loop multipliers applied."""
    comps, entry, shapes = parse_module(text)
    memo: dict[tuple, Cost] = {}

    def comp_cost(cname: str, in_fusion: bool) -> Cost:
        key = (cname, in_fusion)
        if key in memo:
            return memo[key]
        total = Cost()
        for ins in comps.get(cname, []):
            op = ins.opcode
            if op in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "partition-id", "replica-id"):
                continue
            rb = _nbytes(ins.shape)
            ob = sum(_nbytes(shapes.get(o)) for o in ins.operands)
            if op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if m:
                    sub = comp_cost(m.group(1), True)
                    total += Cost(flops=sub.flops,
                                  coll_bytes=sub.coll_bytes,
                                  coll_detail=sub.coll_detail)
                if not in_fusion:
                    total += Cost(bytes=rb + ob)
                continue
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                mt = re.search(r'known_trip_count\D*(\d+)', ins.rest)
                trip = 1 if force_trip_one else (
                    int(mt.group(1)) if mt else 1)
                if mb:
                    total += comp_cost(mb.group(1), in_fusion).scaled(trip)
                if mc:
                    total += comp_cost(mc.group(1), in_fusion).scaled(trip)
                continue
            if op in ("call", "conditional"):
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                     ins.rest):
                    total += comp_cost(m.group(1), in_fusion)
                if not in_fusion:
                    total += Cost(bytes=rb + ob)
                continue
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                g = _group_size(ins.rest, world)
                size = max(rb, ob)
                if base == "all-reduce":
                    moved = 2.0 * (g - 1) / g * size
                elif base == "collective-permute":
                    moved = float(rb)
                else:
                    moved = (g - 1) / g * size
                # XLA:CPU emulates bf16 in f32, so activation/grad
                # collectives appear at 2× their TPU width; on TPU they
                # stay bf16. Halve f32 collective payloads (the only
                # intended f32 collectives are tiny loss-psum scalars).
                if ins.shape is not None and ins.shape[0] == "f32":
                    moved *= 0.5
                total += Cost(coll_bytes=moved,
                              coll_detail=[(base, moved, g, 1)])
                if not in_fusion:
                    total += Cost(bytes=rb + ob)
                continue
            if op.endswith("-done"):
                continue
            if op in ("dynamic-slice", "gather"):
                # reads only the sliced window, not the full operand
                rb_eff = 2 * rb
                if not in_fusion:
                    total += Cost(bytes=rb_eff)
                continue
            if op in ("dynamic-update-slice", "scatter"):
                upd = (_nbytes(shapes.get(ins.operands[1]))
                       if len(ins.operands) > 1 else rb)
                if not in_fusion:
                    total += Cost(bytes=2 * upd)
                continue
            fl = 0.0
            if op == "dot":
                fl = _dot_flops(ins, shapes)
            elif op in ELEMENTWISE:
                fl = float(np.prod(ins.shape[1])) if ins.shape and ins.shape[1] else 1.0
            elif op in ("reduce", "reduce-window"):
                fl = sum(float(np.prod(shapes[o][1]))
                         for o in ins.operands[:1]
                         if shapes.get(o) and shapes[o][1])
            elif op == "convolution":
                fl = 2.0 * _nbytes(ins.shape) / DTYPE_BYTES[ins.shape[0]]
            if in_fusion:
                total += Cost(flops=fl)
            else:
                total += Cost(flops=fl, bytes=rb + ob)
        memo[key] = total
        return total

    return comp_cost(entry, False)


# -- roofline terms ----------------------------------------------------------------

from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW


def roofline_terms(cost: Cost, *, model_flops_per_device: float = 0.0):
    compute_s = cost.flops / PEAK_FLOPS_BF16
    memory_s = cost.bytes / HBM_BW
    coll_s = cost.coll_bytes / ICI_BW
    dom = max((compute_s, "compute"), (memory_s, "memory"),
              (coll_s, "collective"))
    total = max(compute_s, memory_s, coll_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "bottleneck": dom[1],
        "flops": cost.flops,
        "bytes": cost.bytes,
        "coll_bytes": cost.coll_bytes,
        "model_flops": model_flops_per_device,
        "useful_ratio": (model_flops_per_device / cost.flops
                         if cost.flops else 0.0),
        "roofline_frac": (model_flops_per_device / PEAK_FLOPS_BF16 / total
                          if total > 0 else 0.0),
    }


def summarize_collectives(cost: Cost, top: int = 6):
    agg = defaultdict(float)
    for (name, b, g, mult) in cost.coll_detail:
        agg[(name, g)] += b
    rows = sorted(agg.items(), key=lambda kv: -kv[1])[:top]
    return [{"op": k[0], "group": k[1], "bytes": v} for k, v in rows]
