"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/.

    PYTHONPATH=src python -m repro.launch.report [--out results/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_all(root="results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(root, "*", "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.1f}"


def _flags(rec):
    o = rec.get("opts", {})
    out = []
    if o.get("seq_shard"):
        out.append("SP")
    if o.get("fsdp"):
        out.append("FSDP")
    if o.get("zero_opt"):
        out.append("Z1")
    if o.get("accum", 1) > 1:
        out.append(f"acc{o['accum']}")
    if o.get("remat") not in (None, "none"):
        out.append("rm")
    return "+".join(out) or "-"


def roofline_table(recs, mesh: str) -> str:
    rows = [r for r in recs if r["mesh"] == mesh and "roofline" in r]
    rows.sort(key=lambda r: (r["arch"], r["cell"]))
    out = ["| arch | cell | flags | compute s | memory s | collective s | "
           "bound | MODEL_FLOPs/HLO | roofline frac | peak GiB | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        t = r["roofline"]
        mem = r.get("memory", {})
        peak = mem.get("peak_bytes_analytic", mem.get("peak_bytes", 0))
        out.append(
            f"| {r['arch']} | {r['cell']} | {_flags(r)} "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | {t['bottleneck']} "
            f"| {t.get('useful_ratio', 0):.2f} | {t['roofline_frac']:.3f} "
            f"| {fmt_bytes(peak)} | {'Y' if mem.get('fits_hbm') else 'N'} |")
    return "\n".join(out)


def dryrun_summary(recs) -> str:
    out = ["| arch | cell | mesh | compile s | HLO flops/dev | "
           "coll GB/dev | top collective |", "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["cell"], r["mesh"])):
        t = r.get("roofline", {})
        cols = r.get("collectives", [])
        top = (f"{cols[0]['op']}(g={cols[0]['group']}) "
               f"{cols[0]['bytes']/1e9:.0f}GB" if cols else "-")
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {r.get('compile_s', 0):.0f} | {t.get('flops', 0):.2e} "
            f"| {t.get('coll_bytes', 0)/1e9:.1f} | {top} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args(argv)
    recs = load_all(args.root)
    parts = ["## Roofline — single pod 16×16 (256 chips)\n",
             roofline_table(recs, "16x16"),
             "\n\n## Roofline — two pods 2×16×16 (512 chips)\n",
             roofline_table(recs, "2x16x16"),
             "\n\n## Dry-run detail\n", dryrun_summary(recs)]
    txt = "\n".join(parts)
    with open(args.out, "w") as f:
        f.write(txt)
    print(f"wrote {args.out} ({len(recs)} cells)")
    return txt


if __name__ == "__main__":
    main()
