"""Padding-independent per-vertex randomness.

``jax.random.uniform(key, (n_pad,))`` derives the value at index i from the
*buffer shape*: the counter space of the threefry stream is carved up by the
total element count, so re-padding a graph to a different bucket changes the
random draw of every valid vertex. That would make the pow2 shape-bucketing
of the multilevel driver (core/bucketing.py) behavior-CHANGING instead of
behavior-preserving.

The helpers here derive per-vertex streams by ``fold_in``-ing the vertex
index into the key, so the value at index i depends only on (key, i). A
graph padded to 512 and the same graph padded to 1024 draw identical values
for every real vertex — the basis of the bucketed-vs-exact-shape parity
guarantee (tests/test_bucketing.py).

All functions are trace-compatible (used inside jitted supersteps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fold_in_keys(key: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """One PRNG key per id: keys[i] = fold_in(key, ids[i])."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)


def uniform_per_vertex(key: jnp.ndarray, ids: jnp.ndarray,
                       minval: float = 0.0, maxval: float = 1.0
                       ) -> jnp.ndarray:
    """float32[len(ids)] uniforms; element i depends only on (key, ids[i])."""
    ks = fold_in_keys(key, ids)
    return jax.vmap(
        lambda k: jax.random.uniform(k, (), minval=minval, maxval=maxval))(ks)


def uniform2_per_vertex(key: jnp.ndarray, ids: jnp.ndarray,
                        minval: float = 0.0, maxval: float = 1.0
                        ) -> jnp.ndarray:
    """float32[len(ids), 2] uniforms, per-vertex streams (for positions)."""
    ks = fold_in_keys(key, ids)
    return jax.vmap(
        lambda k: jax.random.uniform(k, (2,), minval=minval, maxval=maxval))(ks)
