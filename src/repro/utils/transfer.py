"""Explicit device↔host transfer boundaries.

The hot path of the multilevel driver is a loop of cached jitted steps
whose operands already live on device; an *implicit* transfer inside that
loop (a numpy array silently staged per call, a Python scalar re-uploaded
per iteration, a stray ``float(x)`` sync) is a performance bug that CPU
testing never surfaces. Tier-1 hot-path tests therefore run under
``no_implicit_transfers()`` (= ``jax.transfer_guard("disallow")``), which
turns any implicit transfer into an error — and every INTENTIONAL staging
or egress region in the drivers is marked with ``io_boundary()`` so the
reader (and the guard) can tell deliberate I/O from an accident.

Rule of thumb: ``io_boundary()`` belongs at the edges of a driver — graph
ingest, per-level argument staging, final position egress — never inside
the per-iteration loop body. tools/gilalint's R3 rule covers the traced
side of the same invariant (no host syncs inside step functions).
"""
from __future__ import annotations

import jax


def io_boundary():
    """Context marking an intentional host↔device staging/egress region.

    Inside, transfers behave as normal (``transfer_guard("allow")``), even
    when an enclosing scope — e.g. the tier-1 test harness — disallows
    implicit transfers.
    """
    return jax.transfer_guard("allow")


def no_implicit_transfers():
    """Context under which any implicit device↔host transfer raises.

    Explicit transfers (``jax.device_put``, ``jax.device_get``) stay
    allowed, as do regions wrapped in ``io_boundary()``.
    """
    return jax.transfer_guard("disallow")
