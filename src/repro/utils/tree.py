"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of elements across all leaves."""
    return int(sum(np.prod(x.shape) if hasattr(x, "shape") else 1
                   for x in jax.tree.leaves(tree)))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (shape/dtype based, no materialization)."""
    tot = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            tot += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return tot


def tree_cast(tree, dtype):
    """Cast all floating-point leaves to ``dtype``."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, tree)
