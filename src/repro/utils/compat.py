"""JAX version-portability shims.

The codebase targets current JAX (public ``jax.shard_map`` with vma
tracking, ``jax.lax.pvary``, ``jax.sharding.AxisType``) but must also run
on the 0.4.x line installed in CI containers, where shard_map still lives
in ``jax.experimental`` with the older ``check_rep``/``auto`` surface and
pvary does not exist (replication is untracked, so it is the identity).

Mesh construction has its own shim (`repro.launch.mesh.make_compat_mesh`);
everything else version-dependent funnels through here.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names=None):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)

    pvary = jax.lax.pvary

else:
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names=None):
        # axis_names (partial-manual) would map onto 0.4.x's `auto`
        # complement, but the 0.4.x SPMD partitioner hard-crashes on manual
        # subgroups ("Check failed: IsManualSubgroup"), so we run fully
        # manual instead: axes absent from the specs are replicated in the
        # region — numerically identical, forgoing only in-region GSPMD.
        del axis_names
        return _shard_map_04(f, mesh, in_specs, out_specs,
                             check_rep=check_vma)

    def pvary(x, axis_names):
        del axis_names  # 0.4.x does not track varying-ness
        return x
