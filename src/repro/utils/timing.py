"""Wall-clock timing helpers (used by benchmarks and the train driver)."""
from __future__ import annotations

import time


class Timer:
    """Context-manager timer; ``with Timer() as t: ...; t.seconds``."""

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self._t0
        return False


class StepTimer:
    """EWMA step timer with straggler flagging.

    Used by the train/layout drivers: each rank (in a real deployment, each
    host) records its per-step wall time; a step slower than
    ``threshold × ewma`` is flagged as a straggler event. On this single-host
    container the monitor exercises the same code path with one rank.
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 2.5):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma = None
        self.straggler_events = 0
        self.steps = 0

    def record(self, seconds: float) -> bool:
        """Record one step; returns True if this step is a straggler."""
        self.steps += 1
        if self.ewma is None:
            self.ewma = seconds
            return False
        is_straggler = seconds > self.threshold * self.ewma
        if is_straggler:
            self.straggler_events += 1
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return is_straggler
