"""Batched LOD viewport queries — the serving hot path (DESIGN.md §6).

One jitted device program answers B viewports at once (the BatchLayout
move applied to query time): per request, select the zoom band, enumerate
the covered quadtree tiles (row-major, a static ``max_tiles`` budget),
gather the tiles' dense vertex/edge tables, and mask. Every band is
evaluated for the whole batch and the per-request winner is selected with
``where`` — bands are few (hierarchy depth) and the per-band work is a
handful of gathers, so uniform compute beats host-side re-batching by
band.

Everything after band selection is gathers and comparisons — no float
arithmetic touches the stored coordinates — so the batched results are
bit-identical to the unpadded NumPy reference resolver
(``reference_resolve``), which tests/test_serve.py asserts for every
request in a batch.

Zoom semantics: a request's ``zoom`` z asks for quadtree tiles of zoom z;
the resolver serves it from the coarsest band whose tile grid is at least
that fine (``band_for_zoom``), i.e. coarse summaries for zoomed-out
viewports, full detail only when the viewport is small.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.serve.tiles import TilePyramid, tile_coords

MAX_TILES = 16  # static per-request tile-cover budget (row-major truncation;
# the result's "covered" field carries the true wx·wy so clients can tell)


def band_for_zoom(zooms: np.ndarray, z) -> np.ndarray:
    """Coarsest band whose zoom ≥ z (band 0 if z exceeds the finest)."""
    zs = np.asarray(zooms)
    z = np.asarray(z)
    return np.clip(np.sum(zs[None, ...] >= z[..., None], axis=-1) - 1,
                   0, len(zs) - 1).astype(np.int32)


def _cover(boxes, lo, hi, zoom: int, max_tiles: int):
    """Row-major tile cover of each viewport, truncated to ``max_tiles``.

    boxes f32[B, 4] → (tid i32[B, K], tvalid bool[B, K], covered i32[B] =
    the untruncated wx·wy); the valid tiles are a prefix (k < wx·wy).
    Tile math is the shared ``tile_coords`` (bit-identical to binning).
    """
    G = 1 << zoom
    t0 = tile_coords(boxes[:, 0:2], lo, hi, zoom, xp=jnp)
    t1 = tile_coords(boxes[:, 2:4], lo, hi, zoom, xp=jnp)
    w = jnp.maximum(t1 - t0 + 1, 1)                     # [B, 2] (≥1 even for
    # an inverted box, keeping the k % w enumeration well-defined)
    k = jnp.arange(max_tiles, dtype=jnp.int32)[None, :]  # [1, K]
    kx = k % w[:, 0:1]
    ky = k // w[:, 0:1]
    tvalid = ky < w[:, 1:2]
    tid = jnp.where(tvalid, (t0[:, 1:2] + ky) * G + (t0[:, 0:1] + kx), 0)
    return tid, tvalid, w[:, 0] * w[:, 1]


def _query_band(band_arrays, zoom: int, lo, hi, boxes, max_tiles: int):
    """Resolve ALL requests against one band's dense tables."""
    tid, tvalid, covered = _cover(boxes, lo, hi, zoom, max_tiles)
    B = boxes.shape[0]

    vt = band_arrays["tile_vid"][tid]                    # [B, K, cap]
    vmask = (vt >= 0) & tvalid[:, :, None]
    rep = jnp.where(vmask, band_arrays["tile_rep"][tid], -1)
    vpos = jnp.where(vmask[..., None], band_arrays["tile_pos"][tid], 0.0)
    vmass = jnp.where(vmask, band_arrays["tile_mass"][tid], 0.0)
    vid = jnp.where(vmask, vt, -1)
    inside = (vmask
              & (vpos[..., 0] >= boxes[:, None, None, 0])
              & (vpos[..., 1] >= boxes[:, None, None, 1])
              & (vpos[..., 0] <= boxes[:, None, None, 2])
              & (vpos[..., 1] <= boxes[:, None, None, 3]))

    et = band_arrays["tile_eid"][tid]                    # [B, K, ecap]
    emask = (et >= 0) & tvalid[:, :, None]
    eid = jnp.where(emask, et, -1)
    epos = jnp.where(emask[..., None], band_arrays["tile_epos"][tid], 0.0)

    flat = lambda a: a.reshape((B, -1) + a.shape[3:])
    return {"vid": flat(vid), "rep": flat(rep), "vpos": flat(vpos),
            "vmass": flat(vmass), "vmask": flat(vmask),
            "inside": flat(inside), "eid": flat(eid), "epos": flat(epos),
            "emask": flat(emask),
            "tiles": jnp.where(tvalid, tid, -1),
            "covered": covered}


@functools.partial(jax.jit, static_argnames=("zooms", "max_tiles"))
def _query_batch(bands, zooms: tuple, lo, hi, boxes, req_zoom,
                 max_tiles: int = MAX_TILES):
    """boxes f32[B, 4], req_zoom i32[B] → per-request padded slices.

    ``bands`` is a tuple of dense per-band array dicts (uniform caps);
    ``zooms`` the static per-band quadtree zooms.
    """
    zs = jnp.asarray(zooms, jnp.int32)
    sel = jnp.clip(jnp.sum(zs[None, :] >= req_zoom[:, None], axis=1) - 1,
                   0, len(zooms) - 1)
    out = None
    for b, band in enumerate(bands):
        res = _query_band(band, zooms[b], lo, hi, boxes, max_tiles)
        if out is None:
            out = res
        else:
            pick = sel == b
            out = {k: jnp.where(pick.reshape((-1,) + (1,) * (v.ndim - 1)),
                                v, out[k])
                   for k, v in res.items()}
    out["band"] = sel.astype(jnp.int32)
    return out


class QueryEngine:
    """Device-resident pyramid + jitted batched resolver.

    Batch sizes are padded to power-of-two buckets so the number of
    compiled programs stays logarithmic in the largest batch.
    """

    def __init__(self, pyramid: TilePyramid, max_tiles: int = MAX_TILES):
        self.zooms = tuple(int(b.zoom) for b in pyramid.bands)
        self.lo = jnp.asarray(pyramid.lo, jnp.float32)
        self.hi = jnp.asarray(pyramid.hi, jnp.float32)
        self.max_tiles = max_tiles
        self.bands = tuple(
            {"tile_vid": jnp.asarray(b.tile_vid),
             "tile_rep": jnp.asarray(b.tile_rep),
             "tile_pos": jnp.asarray(b.tile_pos),
             "tile_mass": jnp.asarray(b.tile_mass),
             "tile_eid": jnp.asarray(b.tile_eid),
             "tile_epos": jnp.asarray(b.tile_epos)}
            for b in pyramid.bands)

    @staticmethod
    def _bucket(b: int) -> int:
        return 1 << max(b - 1, 0).bit_length()

    def query(self, boxes: np.ndarray, req_zoom: np.ndarray) -> dict:
        """Resolve B viewports; returns host arrays trimmed to B rows."""
        boxes = np.asarray(boxes, np.float32).reshape(-1, 4)
        req_zoom = np.asarray(req_zoom, np.int32).reshape(-1)
        B = boxes.shape[0]
        Bp = self._bucket(B)
        if Bp != B:
            boxes = np.concatenate(
                [boxes, np.zeros((Bp - B, 4), np.float32)], axis=0)
            req_zoom = np.concatenate(
                [req_zoom, np.zeros(Bp - B, np.int32)])
        out = _query_batch(self.bands, self.zooms, self.lo, self.hi,
                           jnp.asarray(boxes), jnp.asarray(req_zoom),
                           self.max_tiles)
        return {k: np.asarray(v)[:B] for k, v in out.items()}

    def warmup(self, batch_sizes=(1, 16, 64)) -> None:
        for B in batch_sizes:
            self.query(np.zeros((B, 4), np.float32), np.zeros(B, np.int32))


def trim_result(out: dict, i: int) -> dict:
    """Drop padding from request i of a batched result → unpadded arrays
    (the reference resolver's format)."""
    vm = out["vmask"][i]
    em = out["emask"][i]
    return {"band": int(out["band"][i]),
            "covered": int(out["covered"][i]),
            "vid": out["vid"][i][vm], "rep": out["rep"][i][vm],
            "vpos": out["vpos"][i][vm], "vmass": out["vmass"][i][vm],
            "inside": out["inside"][i][vm],
            "eid": out["eid"][i][em], "epos": out["epos"][i][em],
            "tiles": out["tiles"][i][out["tiles"][i] >= 0]}


def random_viewports(lo, hi, zoom_max: int, count: int, seed: int = 0
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Uniform load-generator workload: ``count`` (box, zoom) requests.

    Zooms are uniform over [0, zoom_max]; a zoom-z box spans 1/2^z of the
    pyramid extent at a uniform position — the mix a map-style client
    panning and zooming over the drawing produces.
    """
    rng = np.random.default_rng(seed)
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    z = rng.integers(0, zoom_max + 1, count).astype(np.int32)
    ext = hi - lo
    w = ext[None, :] / (2.0 ** z)[:, None].astype(np.float32)
    c = lo[None, :] + (rng.random((count, 2)).astype(np.float32)
                       * np.maximum(ext[None, :] - w, 0.0))
    return np.concatenate([c, c + w], axis=1).astype(np.float32), z


def reference_resolve(pyr: TilePyramid, box, zoom: int,
                      max_tiles: int = MAX_TILES) -> dict:
    """Unpadded single-request NumPy resolver — the parity oracle.

    Mirrors the batched path operation for operation (same f32 tile math,
    same row-major truncation, same slot order) so results are
    bit-identical, not just approximately equal.
    """
    zs = np.asarray([b.zoom for b in pyr.bands])
    sel = int(band_for_zoom(zs, np.asarray([zoom]))[0])
    band = pyr.bands[sel]
    G = 1 << band.zoom
    box = np.asarray(box, np.float32).reshape(4)
    lo = np.asarray(pyr.lo, np.float32)
    hi = np.asarray(pyr.hi, np.float32)
    t0 = tile_coords(box[0:2], lo, hi, band.zoom)
    t1 = tile_coords(box[2:4], lo, hi, band.zoom)
    wx, wy = max(int(t1[0] - t0[0] + 1), 1), max(int(t1[1] - t0[1] + 1), 1)
    tids = []
    for k in range(max_tiles):
        kx, ky = k % wx, k // wx
        if ky >= wy:
            break
        tids.append(int((int(t0[1]) + ky) * G + (int(t0[0]) + kx)))

    vids, reps, vposs, vmasss, eids, eposs = [], [], [], [], [], []
    for t in tids:
        vm = band.tile_vid[t] >= 0
        vids.append(band.tile_vid[t][vm])
        reps.append(band.tile_rep[t][vm])
        vposs.append(band.tile_pos[t][vm])
        vmasss.append(band.tile_mass[t][vm])
        em = band.tile_eid[t] >= 0
        eids.append(band.tile_eid[t][em])
        eposs.append(band.tile_epos[t][em])
    cat = lambda xs, w: (np.concatenate(xs) if xs
                         else np.zeros((0,) + w, np.float32))
    vpos = cat(vposs, (2,))
    inside = ((vpos[:, 0] >= box[0]) & (vpos[:, 1] >= box[1])
              & (vpos[:, 0] <= box[2]) & (vpos[:, 1] <= box[3]))
    return {"band": sel,
            "covered": wx * wy,
            "vid": cat(vids, ()).astype(np.int32),
            "rep": cat(reps, ()).astype(np.int32),
            "vpos": vpos, "vmass": cat(vmasss, ()),
            "inside": inside,
            "eid": cat(eids, ()).astype(np.int32),
            "epos": cat(eposs, (4,)),
            "tiles": np.asarray(tids, np.int32)}
