"""Quadtree tile pyramid over the multilevel hierarchy (DESIGN.md §6).

The solar-merger hierarchy is a semantic level-of-detail pyramid: level
ℓ+1 is a faithful summary of level ℓ (systems collapse into suns). This
module turns a finished layout's ``HierarchyExport`` into the serving
artifact: every hierarchy level becomes a *zoom band*; within a band,
vertices and edges are binned into the 2^z × 2^z spatial tiles of a
quadtree whose box is shared by ALL bands, so tile (z, tx, ty) addresses
the same region at every zoom.

Coarse-band positions are mass-weighted centroids of the members' FINAL
positions (not the interim coarse drawings, which fine refinement walks
away from), so zooming out never disagrees with the fine drawing.

Binning reuses ``grid_force.bin_vertices`` with a fixed ``box``: vertices
are presented in descending aggregate-mass order, so each tile's
fixed-capacity bucket is a top-k by the mass of the solar system the
vertex represents — an overfull tile keeps its heaviest (most
representative) vertices instead of truncating arbitrarily, and records
the uncapped total so clients can tell.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax.numpy as jnp

from repro.kernels.grid_force import bin_vertices, grid_cell_size
from repro.core.multilevel import HierarchyExport

_EPS = 1e-12


@dataclasses.dataclass
class TileBand:
    """One zoom band: dense per-tile tables over T = 4^zoom tiles.

    Sentinels: vertex/edge slots beyond the per-tile count hold id -1 and
    zero positions. ``tile_total`` is the uncapped vertex count (>''count''
    iff the tile overflowed and kept only its top-k by mass).
    """
    zoom: int
    level: int               # hierarchy level this band serves
    n: int                   # vertices in this band
    m: int                   # edges in this band
    tile_vid: np.ndarray     # int32[T, cap] — band-local vertex id
    tile_rep: np.ndarray     # int32[T, cap] — level-0 representative id
    tile_pos: np.ndarray     # float32[T, cap, 2]
    tile_mass: np.ndarray    # float32[T, cap] — aggregate (subtree) mass
    tile_count: np.ndarray   # int32[T]
    tile_total: np.ndarray   # int32[T]
    tile_eid: np.ndarray     # int32[T, ecap] — band-local edge id
    tile_epos: np.ndarray    # float32[T, ecap, 4] — (x1, y1, x2, y2)
    tile_ecount: np.ndarray  # int32[T]

    @property
    def tiles_per_axis(self) -> int:
        return 1 << self.zoom


@dataclasses.dataclass
class TilePyramid:
    lo: np.ndarray           # float32[2] — shared quadtree box
    hi: np.ndarray           # float32[2]
    tile_cap: int
    edge_cap: int
    bands: list              # list[TileBand], bands[0] = finest


def band_positions(exp: HierarchyExport
                   ) -> tuple[list[np.ndarray], list[np.ndarray]]:
    """(positions, aggregate masses) per band, finest first.

    Aggregate mass of a coarse vertex = number of level-0 vertices it
    represents; positions are mass-weighted member centroids, bottom-up.
    """
    pos = [np.asarray(exp.pos, np.float32)]
    mass = [np.ones(exp.levels[0].n, np.float32)]
    for b, lvl in enumerate(exp.levels[:-1]):
        nn = exp.levels[b + 1].n
        m = np.zeros(nn, np.float32)
        s = np.zeros((nn, 2), np.float32)
        np.add.at(m, lvl.parent, mass[-1])
        np.add.at(s, lvl.parent, mass[-1][:, None] * pos[-1])
        pos.append((s / np.maximum(m, _EPS)[:, None]).astype(np.float32))
        mass.append(m)
    return pos, mass


def zoom_for(n: int, tile_cap: int, max_zoom: int) -> int:
    """Smallest zoom whose mean tile occupancy is ≤ tile_cap/2."""
    occ = max(tile_cap // 2, 1)
    z = 0 if n <= occ else math.ceil(math.log(n / occ, 4))
    return int(np.clip(z, 0, max_zoom))


def tile_coords(pos, lo, hi, zoom: int, xp=np):
    """int32[..., 2] (tx, ty) — the same f32 ops as ``bin_vertices``
    (the cell size comes from the shared ``grid_cell_size``), with ``xp``
    numpy (build/reference) or jax.numpy (the batched query path)."""
    G = 1 << zoom
    cell = grid_cell_size(lo, hi, G, xp)
    t = xp.floor((pos - lo) / cell)
    return xp.clip(t, 0, G - 1).astype(xp.int32)


def _bin_band(pos, agg_mass, rep, edges, lo, hi, zoom: int, level: int,
              tile_cap: int, edge_cap: int) -> TileBand:
    n, m = len(pos), len(edges)
    G = 1 << zoom
    T = G * G

    # -- vertices: mass-priority order through bin_vertices ------------------
    order = np.argsort(-agg_mass, kind="stable")
    cid_o, bucket, _ = bin_vertices(
        jnp.asarray(pos[order], jnp.float32), jnp.ones(n, bool), G, tile_cap,
        box=(jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32)))
    bucket = np.asarray(bucket)[:T]                       # [T, cap], sentinel n
    cell_of = np.empty(n, np.int32)
    cell_of[order] = np.asarray(cid_o)
    valid = bucket < n
    vid = np.where(valid, order[np.minimum(bucket, n - 1)], -1).astype(np.int32)
    tile_count = valid.sum(axis=1).astype(np.int32)
    tile_total = np.bincount(cell_of, minlength=T).astype(np.int32)
    safe = np.maximum(vid, 0)
    tile_pos = np.where(valid[:, :, None], pos[safe], 0.0).astype(np.float32)
    tile_mass = np.where(valid, agg_mass[safe], 0.0).astype(np.float32)
    tile_rep = np.where(valid, rep[safe].astype(np.int32), -1).astype(np.int32)

    # -- edges: each edge lands in the tile(s) of its endpoints --------------
    if m:
        tc = tile_coords(pos, lo, hi, zoom)
        tid = tc[:, 1].astype(np.int64) * G + tc[:, 0]
        tu, tv = tid[edges[:, 0]], tid[edges[:, 1]]
        eids = np.arange(m, dtype=np.int64)
        prio = agg_mass[edges[:, 0]] + agg_mass[edges[:, 1]]
        etile = np.concatenate([tu, tv[tu != tv]])
        eeid = np.concatenate([eids, eids[tu != tv]])
        eprio = np.concatenate([prio, prio[tu != tv]])
        # per-tile top-k by endpoint mass, ties broken by edge id
        srt = np.lexsort((eeid, -eprio, etile))
        etile, eeid = etile[srt], eeid[srt]
        starts = np.searchsorted(etile, etile, side="left")
        rank = np.arange(len(etile)) - starts
        keep = rank < edge_cap
        tile_eid = np.full((T, edge_cap), -1, np.int32)
        tile_eid[etile[keep], rank[keep]] = eeid[keep]
        tile_ecount = np.bincount(etile[keep], minlength=T).astype(np.int32)
        epos = np.concatenate([pos[edges[:, 0]], pos[edges[:, 1]]],
                              axis=1).astype(np.float32)   # [m, 4]
        esafe = np.maximum(tile_eid, 0)
        tile_epos = np.where((tile_eid >= 0)[:, :, None], epos[esafe], 0.0)
        tile_epos = tile_epos.astype(np.float32)
    else:
        tile_eid = np.full((T, edge_cap), -1, np.int32)
        tile_ecount = np.zeros(T, np.int32)
        tile_epos = np.zeros((T, edge_cap, 4), np.float32)

    return TileBand(zoom=zoom, level=level, n=n, m=m, tile_vid=vid,
                    tile_rep=tile_rep,
                    tile_pos=tile_pos, tile_mass=tile_mass,
                    tile_count=tile_count, tile_total=tile_total,
                    tile_eid=tile_eid, tile_epos=tile_epos,
                    tile_ecount=tile_ecount)


def build_pyramid(exp: HierarchyExport, *, tile_cap: int = 64,
                  edge_cap: int = 96, max_zoom: int = 8) -> TilePyramid:
    """Build the quadtree tile pyramid from a layout's hierarchy export."""
    pos, mass = band_positions(exp)
    lo = pos[0].min(axis=0).astype(np.float32)
    hi = pos[0].max(axis=0).astype(np.float32)
    bands = []
    prev_zoom = max_zoom
    for b, lvl in enumerate(exp.levels):
        zoom = min(zoom_for(lvl.n, tile_cap, max_zoom), prev_zoom)
        prev_zoom = zoom
        band = _bin_band(pos[b], mass[b], lvl.rep,
                         np.asarray(lvl.edges, np.int64).reshape(-1, 2),
                         lo, hi, zoom, b, tile_cap, edge_cap)
        if bands and bands[-1].zoom == zoom:
            # two levels mapping to the same zoom: keep only the coarser —
            # band selection ("coarsest band with zoom ≥ z") could never
            # pick the finer one, it would just be stored and gathered
            bands[-1] = band
        else:
            bands.append(band)
    return TilePyramid(lo=lo, hi=hi, tile_cap=tile_cap, edge_cap=edge_cap,
                       bands=bands)
