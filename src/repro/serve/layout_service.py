"""Layout-as-a-service front door: micro-batched multi-graph layout.

The multi-tenant serving scenario the ROADMAP aims at: many users submit
(small) graphs concurrently and each expects a finished drawing back. One
``LayoutService`` owns a deadline-window collector (the ``_BatcherCore``
machinery of serve/batcher.py) whose batches are evaluated by
``core.multilevel.multigila_layout_many`` — so every window of concurrent
requests shares ONE batched device program per level wave, and a warm
process compiles nothing (core/bucketing.py). Per-request results are
bit-identical to a dedicated single-graph ``multigila_layout`` call.

    svc = LayoutService(LayoutConfig(seed=0))
    futs = [svc.submit(edges_i, n_i) for ...]     # concurrent callers
    pos, stats = futs[0].result()
    svc.close()

The default window (10 ms) is wider than the viewport-query batcher's:
a layout costs 10⁴–10⁶× a tile lookup, so waiting a beat longer to fill
the batch is always worth it.
"""
from __future__ import annotations

from concurrent.futures import Future

from repro.serve.batcher import _BatcherCore


class LayoutService(_BatcherCore):
    """Deadline-window coalescing of layout requests into batched drivers."""

    def __init__(self, cfg=None, *, max_batch: int = 16,
                 window_s: float = 0.010):
        from repro.core import LayoutConfig
        self.cfg = cfg or LayoutConfig()
        super().__init__(max_batch=max_batch, window_s=window_s)

    def submit(self, edges, n: int) -> Future:
        """Enqueue one graph; resolves to ``(pos[n, 2], LayoutStats)``.

        Validates — and defensively copies — the request HERE, not in the
        batch (serve/engine.py:validate_graph): requests coalesce into
        shared driver calls, so one malformed graph would otherwise fail
        (or, with negative ids wrapping, silently corrupt) every request
        in its window, and a caller mutating its edge array after submit
        would corrupt the shared batch.
        """
        from repro.serve.engine import validate_graph
        e, n = validate_graph(edges, n)
        return self._submit_payload((e, n))

    def layout(self, edges, n: int, timeout: float | None = None):
        """Blocking convenience wrapper around ``submit``."""
        return self.submit(edges, n).result(timeout)

    def _execute(self, payloads: list) -> list:
        from repro.core import multigila_layout_many
        return multigila_layout_many(payloads, self.cfg)
