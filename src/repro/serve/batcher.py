"""Micro-batching front doors (DESIGN.md §6, §9).

Concurrent callers submit single requests; a collector thread coalesces
everything that arrives within a deadline window (or up to ``max_batch``)
into ONE batched device program. Under load the window fills and
per-request cost amortizes toward the batched throughput; an idle request
pays at most the window.

``_BatcherCore`` owns the engine-agnostic machinery (queue, deadline
window, future lifecycle, shutdown races); subclasses supply ``_execute``
— the batched evaluation. Two front doors ride on it:

  * ``MicroBatcher`` — viewport queries against a ``QueryEngine`` (the
    same batched-prefill structure as ``examples/serve_decode.py``,
    applied to query serving);
  * ``serve/layout_service.py:LayoutService`` — whole-graph layout
    requests, coalesced into ``multigila_layout_many`` batches.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.serve.query import QueryEngine, trim_result


class _BatcherCore:
    """Deadline-window request coalescing (engine-agnostic core)."""

    def __init__(self, *, max_batch: int = 64, window_s: float = 0.002):
        self.max_batch = max_batch
        self.window_s = window_s
        self.batches = 0
        self.requests = 0
        self._q: queue.Queue = queue.Queue()
        self._closed = False
        # orders every put against close(): nothing can slip into the queue
        # after the shutdown sentinel, so no future is left unresolved
        self._lock = threading.Lock()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    # -- subclass contract ---------------------------------------------------
    def _execute(self, payloads: list) -> list:
        """Evaluate one batch; returns one result per payload, in order."""
        raise NotImplementedError

    def _submit_payload(self, payload) -> Future:
        """Enqueue one payload; resolves to ``_execute``'s per-item result."""
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise RuntimeError("batcher is closed")
            self._q.put((payload, fut))
        return fut

    # -- collector loop ------------------------------------------------------
    def _collect(self) -> list | None:
        """Block for the first request, then drain until deadline/max."""
        item = self._q.get()
        if item is None:
            return None
        batch = [item]
        deadline = time.monotonic() + self.window_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                nxt = self._q.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is None:
                self._q.put(None)   # re-arm shutdown for the outer loop
                break
            batch.append(nxt)
        return batch

    def _run(self):
        while True:
            batch = self._collect()
            if batch is None:
                break
            # claim each future; a caller may have cancelled while queued
            # (timeout wrappers) — completing a cancelled future would raise
            # InvalidStateError and kill this thread
            batch = [item for item in batch
                     if item[1].set_running_or_notify_cancel()]
            if not batch:
                continue
            self.batches += 1
            self.requests += len(batch)
            try:
                results = self._execute([p for p, _ in batch])
            except Exception as e:
                for _, fut in batch:
                    fut.set_exception(e)
                continue
            for (_, fut), res in zip(batch, results):
                fut.set_result(res)
        self._drain()

    def _drain(self):
        """Cancel whatever is still queued once nobody will serve it
        (requests racing close() must not block their callers forever)."""
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                return
            if item is not None:
                item[1].cancel()

    def close(self):
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._q.put(None)   # under the lock: nothing enqueues after it
        self._worker.join(timeout=30)
        self._drain()   # anything the worker left when the sentinel hit


class MicroBatcher(_BatcherCore):
    """Deadline-window viewport-query coalescing in front of a QueryEngine."""

    def __init__(self, engine: QueryEngine, *, max_batch: int = 64,
                 window_s: float = 0.002, trim: bool = True):
        self.engine = engine
        self.trim = trim
        super().__init__(max_batch=max_batch, window_s=window_s)

    def submit(self, box, zoom: int) -> Future:
        """Enqueue one viewport; resolves to the (trimmed) query result."""
        return self._submit_payload(
            (np.asarray(box, np.float32).reshape(4), int(zoom)))

    def _execute(self, payloads: list) -> list:
        boxes = np.stack([b for b, _ in payloads])
        zooms = np.asarray([z for _, z in payloads], np.int32)
        out = self.engine.query(boxes, zooms)
        if self.trim:
            return [trim_result(out, i) for i in range(len(payloads))]
        return [{k: v[i] for k, v in out.items()}
                for i in range(len(payloads))]
