"""Tile-pyramid persistence + LRU tile cache (DESIGN.md §6).

On disk a pyramid is a directory of npz shards — one per non-empty tile,
keyed by ``(band, tx, ty)`` — plus a ``manifest.json`` recording the
quadtree box, band metadata (zoom, n, m, shard list), tile capacities and
a content digest. Writes go through the ckpt layer's atomic primitives
(``repro.ckpt.save_npz``; tmp-dir → fsync → rename for the directory), so
a killed builder never leaves a pyramid a reader would pick up.

``TileStore`` is the read side: per-tile access with an LRU cache (the
serving hot set — viewports hammer a small fraction of tiles), and
``band_dense`` to assemble the dense per-band tables the batched query
engine (serve/query.py) wants on device.
"""
from __future__ import annotations

import json
import os
import shutil
from collections import OrderedDict

import numpy as np

from repro.ckpt import save_npz, load_npz, array_digest
from repro.serve.tiles import TileBand, TilePyramid

MANIFEST = "manifest.json"

# tile-shard array keys ↔ TileBand per-tile rows
_V_KEYS = ("vid", "rep", "pos", "mass")
_E_KEYS = ("eid", "epos")


def _shard_name(band: int, tx: int, ty: int) -> str:
    return f"band{band}_x{tx}_y{ty}.npz"


def _tile_arrays(band: TileBand, t: int) -> dict[str, np.ndarray]:
    return {"vid": band.tile_vid[t], "rep": band.tile_rep[t],
            "pos": band.tile_pos[t], "mass": band.tile_mass[t],
            "eid": band.tile_eid[t], "epos": band.tile_epos[t],
            "count": band.tile_count[t:t + 1],
            "total": band.tile_total[t:t + 1],
            "ecount": band.tile_ecount[t:t + 1]}


def save_pyramid(path: str, pyr: TilePyramid) -> str:
    """Atomically persist a pyramid directory; returns the final path."""
    tmp = path.rstrip("/") + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    digest_arrays: dict[str, np.ndarray] = {}
    bands_meta = []
    for b, band in enumerate(pyr.bands):
        G = band.tiles_per_axis
        nonempty = np.nonzero((band.tile_count > 0)
                              | (band.tile_ecount > 0))[0]
        tiles = []
        for t in nonempty:
            tx, ty = int(t % G), int(t // G)
            arrs = _tile_arrays(band, int(t))
            save_npz(os.path.join(tmp, _shard_name(b, tx, ty)), arrs)
            for k, a in arrs.items():
                digest_arrays[f"{b}/{tx}/{ty}/{k}"] = np.asarray(a)
            tiles.append([tx, ty])
        bands_meta.append({"zoom": band.zoom, "level": band.level,
                           "n": band.n, "m": band.m, "tiles": tiles})
    manifest = {"bbox": [float(x) for x in np.concatenate([pyr.lo, pyr.hi])],
                "tile_cap": pyr.tile_cap, "edge_cap": pyr.edge_cap,
                "levels": len(pyr.bands), "bands": bands_meta,
                "digest": array_digest(digest_arrays)}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    # displace any existing pyramid aside-first: ``path`` only ever holds a
    # complete pyramid, and a crash between the renames leaves the previous
    # one intact at ``.old`` instead of rmtree'd into nothing
    old = path.rstrip("/") + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(path):
        os.rename(path, old)
    os.rename(tmp, path)
    shutil.rmtree(old, ignore_errors=True)
    return path


class TileStore:
    """Read side of a persisted pyramid: manifest + LRU-cached tile shards."""

    def __init__(self, path: str, cache_tiles: int = 4096):
        self.path = path
        with open(os.path.join(path, MANIFEST)) as f:
            self.manifest = json.load(f)
        bbox = np.asarray(self.manifest["bbox"], np.float32)
        self.lo, self.hi = bbox[:2], bbox[2:]
        self.tile_cap = int(self.manifest["tile_cap"])
        self.edge_cap = int(self.manifest["edge_cap"])
        self.levels = int(self.manifest["levels"])
        self._present = [set(map(tuple, bm["tiles"]))
                         for bm in self.manifest["bands"]]
        self.cache_tiles = cache_tiles
        self._cache: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def band_meta(self, band: int) -> dict:
        return self.manifest["bands"][band]

    def _empty_tile(self) -> dict[str, np.ndarray]:
        cap, ecap = self.tile_cap, self.edge_cap
        return {"vid": np.full(cap, -1, np.int32),
                "rep": np.full(cap, -1, np.int32),
                "pos": np.zeros((cap, 2), np.float32),
                "mass": np.zeros(cap, np.float32),
                "eid": np.full(ecap, -1, np.int32),
                "epos": np.zeros((ecap, 4), np.float32),
                "count": np.zeros(1, np.int32),
                "total": np.zeros(1, np.int32),
                "ecount": np.zeros(1, np.int32)}

    def tile(self, band: int, tx: int, ty: int) -> dict[str, np.ndarray]:
        key = (band, tx, ty)
        if key in self._cache:
            self.hits += 1
            self._cache.move_to_end(key)
            return self._cache[key]
        self.misses += 1
        if (tx, ty) in self._present[band]:
            arrs = load_npz(os.path.join(self.path, _shard_name(band, tx, ty)))
        else:
            arrs = self._empty_tile()
        self._cache[key] = arrs
        while len(self._cache) > self.cache_tiles:
            self._cache.popitem(last=False)
        return arrs

    def band_dense(self, band: int) -> TileBand:
        """Assemble the dense per-band tables (empty tiles → sentinels)."""
        bm = self.band_meta(band)
        G = 1 << bm["zoom"]
        T = G * G
        cap, ecap = self.tile_cap, self.edge_cap
        out = TileBand(
            zoom=bm["zoom"], level=bm["level"], n=bm["n"], m=bm["m"],
            tile_vid=np.full((T, cap), -1, np.int32),
            tile_rep=np.full((T, cap), -1, np.int32),
            tile_pos=np.zeros((T, cap, 2), np.float32),
            tile_mass=np.zeros((T, cap), np.float32),
            tile_count=np.zeros(T, np.int32),
            tile_total=np.zeros(T, np.int32),
            tile_eid=np.full((T, ecap), -1, np.int32),
            tile_epos=np.zeros((T, ecap, 4), np.float32),
            tile_ecount=np.zeros(T, np.int32))
        for (tx, ty) in sorted(self._present[band]):
            t = ty * G + tx
            a = self.tile(band, tx, ty)
            out.tile_vid[t] = a["vid"]
            out.tile_rep[t] = a["rep"]
            out.tile_pos[t] = a["pos"]
            out.tile_mass[t] = a["mass"]
            out.tile_count[t] = a["count"][0]
            out.tile_total[t] = a["total"][0]
            out.tile_eid[t] = a["eid"]
            out.tile_epos[t] = a["epos"]
            out.tile_ecount[t] = a["ecount"][0]
        return out

    def verify(self) -> bool:
        """Recompute the shard digest and compare against the manifest."""
        digest_arrays: dict[str, np.ndarray] = {}
        for b, present in enumerate(self._present):
            for (tx, ty) in present:
                arrs = load_npz(
                    os.path.join(self.path, _shard_name(b, tx, ty)))
                for k, a in arrs.items():
                    digest_arrays[f"{b}/{tx}/{ty}/{k}"] = a
        return array_digest(digest_arrays) == self.manifest["digest"]


def load_pyramid(path: str, *, validate: bool = False) -> TilePyramid:
    """Round-trip read: reassemble the full dense TilePyramid."""
    store = TileStore(path, cache_tiles=0)
    if validate and not store.verify():
        raise IOError(f"tile pyramid {path} failed digest validation")
    bands = [store.band_dense(b) for b in range(store.levels)]
    return TilePyramid(lo=store.lo, hi=store.hi, tile_cap=store.tile_cap,
                       edge_cap=store.edge_cap, bands=bands)
