"""Continuous-batching layout engine (DESIGN.md §11).

``LayoutService`` (serve/layout_service.py) coalesces requests into fixed
deadline-window waves: a batch forms, runs to completion, and everything
that arrived meanwhile waits for the next batch. This module replaces that
with the mechanism LLM serving uses — *continuous batching*: a persistent
engine owns the ``core.multilevel.WaveScheduler`` and admits new requests
into the lane set *between* level waves, so a late request rides the very
next wave alongside requests that are already mid-hierarchy. Lane buckets
are pow2 with a floor (graphs/packing.py) and capped (``lanes_cap`` in
``bucketing.refine_level_many``), so a warm engine compiles nothing for a
mid-flight join, and lanes are arithmetically independent, so every
result stays bit-identical to a dedicated ``multigila_layout`` call.

Three layers, separated so the scheduler is testable without wall clock:

  * ``EngineCore`` — a single-driver state machine: bounded admission
    queue (backpressure → ``EngineBusy``), per-request priorities and
    deadlines honored by the wave picker, cancellation that frees lanes,
    and a deterministic scheduling log. It reads time ONLY through its
    ``Clock``, so the same scripted trace replays to the same log.
  * the simulation rig — ``VirtualClock`` + ``SimEvent`` traces
    (``poisson_trace`` for seeded Poisson arrivals) + ``run_sim``, which
    drives an ``EngineCore`` through a trace charging a wave cost model to
    the virtual clock; ``null_dispatch`` stubs out device work entirely.
  * ``ContinuousLayoutService`` — the always-on threaded front door: a
    worker thread ticks the core under the system clock; ``submit``
    returns a Future-backed ``LayoutRequest`` handle.

Deadlines, cancellations, and admissions take effect at wave boundaries
(a wave in flight is never interrupted). Larger ``priority`` values are
more urgent; ties break by submission order.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from concurrent.futures import Future

import numpy as np

from repro.core.multilevel import LayoutConfig, WaveScheduler
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
# the Clock seam moved to obs/clock.py (the tracer shares it); re-exported
# here because engine callers import it from this module
from repro.obs.clock import Clock, SystemClock, VirtualClock


class EngineBusy(RuntimeError):
    """Backpressure: the admission queue is full — resubmit later."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before its layout finished."""


def validate_graph(edges, n: int) -> tuple[np.ndarray, int]:
    """Validate one layout request at the service boundary and return a
    defensively COPIED edge array.

    The copy is load-bearing: ``np.asarray`` aliases same-dtype input, so
    without it a caller mutating its ``edges`` array after submit would
    corrupt the shared batch mid-flight (regression-tested in
    tests/test_service.py). Validation happens here, not in the batch:
    requests coalesce into shared driver calls, and one malformed graph
    must not fail (or silently corrupt) every request in its wave.
    """
    e = np.array(edges, dtype=np.int64, copy=True).reshape(-1, 2)
    n = int(n)
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if e.size and (e.min() < 0 or e.max() >= n):
        raise ValueError(
            f"edge endpoints must lie in [0, {n}), got [{e.min()}, {e.max()}]")
    return e, n


# -- engine metrics (DESIGN.md §12) -------------------------------------------

ENGINE_REQUESTS = obs_metrics.REGISTRY.counter(
    "gila_engine_requests_total",
    "Engine request transitions, labeled by event "
    "(submitted/rejected/admitted/completed/expired/cancelled)")
QUEUE_DEPTH = obs_metrics.REGISTRY.gauge(
    "gila_engine_queue_depth", "Admission-queue depth (last observed)")
QUEUE_DEPTH_HWM = obs_metrics.REGISTRY.gauge(
    "gila_engine_queue_depth_hwm",
    "Admission-queue high-water mark since engine start")
REQUEST_LATENCY = obs_metrics.REGISTRY.histogram(
    "gila_request_latency_seconds",
    "End-to-end submit-to-complete latency of finished requests",
    "seconds")


# -- requests ------------------------------------------------------------------

@dataclasses.dataclass
class LayoutRequest:
    """Handle for one submitted graph; ``future`` resolves to
    ``(pos[n, 2], LayoutStats)``. Status walk: queued → running → done,
    with expired / cancelled / rejected exits."""
    rid: int
    edges: np.ndarray
    n: int
    seed: int | None
    engine: str | None              # refinement engine override (None = cfg's)
    priority: int
    deadline: float | None          # absolute, in the engine clock's frame
    t_submit: float
    future: Future
    status: str = "queued"
    job: object = None              # core.multilevel.GraphJob once admitted
    t_done: float | None = None

    def result(self, timeout: float | None = None):
        return self.future.result(timeout)

    @property
    def latency(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit


class EngineCore:
    """Continuous-batching state machine over a ``WaveScheduler``.

    Single-driver: exactly one thread (the owner) may call ``tick``;
    ``submit``/``cancel``/``stats`` are safe from any thread (they touch
    only lock-protected queue state, never the scheduler). Each ``tick``
    runs one engine cycle at the current clock reading:

      1. finalize cancellations requested while the last wave ran;
      2. expire queued and running requests whose deadline has passed
         (the lane is freed; siblings are untouched);
      3. admit the most urgent queued requests while lane capacity
         remains — this is the mid-flight join;
      4. dispatch ONE wave, lanes ordered by urgency and truncated to
         ``wave_lanes`` (lanes past the cap are preempted until capacity
         frees — that is how priorities/deadlines shape device time);
      5. harvest finished jobs and resolve their futures.

    Every transition appends to ``log`` — tuples of
    ``(t, kind, rid, details)`` — which is bit-stable across reruns of the
    same (config, trace) under a ``VirtualClock``.
    """

    def __init__(self, cfg: LayoutConfig | None = None, *,
                 clock: Clock | None = None, max_queue: int = 64,
                 max_lanes: int = 32, wave_lanes: int | None = None,
                 dispatch=None, tracer: "obs_trace.Tracer | None" = None):
        assert max_lanes >= 1 and max_queue >= 1
        self.clock = clock or SystemClock()
        # engine clock and tracer are handed to the scheduler so wave
        # spans, straggler timing, and the scheduling-log instants all
        # share ONE time frame (virtual under sim → replayable traces)
        self.tracer = tracer if tracer is not None else obs_trace.get_tracer()
        self.max_queue = int(max_queue)
        self.max_lanes = int(max_lanes)
        self.wave_lanes = int(wave_lanes or max_lanes)
        self.sched = WaveScheduler(cfg, lanes_cap=self.wave_lanes,
                                   dispatch=dispatch, tracer=self.tracer,
                                   clock=self.clock)
        self._lock = threading.Lock()
        self._queue: list[LayoutRequest] = []
        self._running: list[LayoutRequest] = []
        self._req_of_job: dict = {}
        self._next_rid = 0
        self._queue_hwm = 0
        self.log: list[tuple] = []
        self.counters = dict(submitted=0, rejected=0, admitted=0,
                             completed=0, expired=0, cancelled=0, waves=0)

    # -- client surface (any thread) ------------------------------------------
    def submit(self, edges, n: int, *, priority: int = 0,
               deadline_s: float | None = None,
               seed: int | None = None,
               engine: str | None = None) -> LayoutRequest:
        """Enqueue one graph; raises ``EngineBusy`` when the admission
        queue is full (bounded-queue backpressure). ``deadline_s`` is
        relative to now; expiry resolves the future with
        ``DeadlineExceeded``. ``engine`` overrides the refinement engine
        for this request (waves mix engines freely — grouping is by
        (engine, shape bucket), DESIGN.md §14)."""
        e, n = validate_graph(edges, n)
        if engine is not None:
            # boundary validation: an unknown id must bounce here (HTTP
            # 400), not poison the engine worker mid-wave. Deferred import
            # mirrors the registry's own lazy stress import.
            from repro.core.engine import get_engine
            get_engine(engine)
            engine = str(engine)
        t = self.clock.now()
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            if len(self._queue) >= self.max_queue:
                self._count("rejected")
                self._log("reject", t, rid, queue=len(self._queue))
                raise EngineBusy(
                    f"admission queue full ({self.max_queue} pending)")
            req = LayoutRequest(
                rid=rid, edges=e, n=n,
                seed=None if seed is None else int(seed),
                engine=engine,
                priority=int(priority),
                deadline=None if deadline_s is None else t + float(deadline_s),
                t_submit=t, future=Future())
            self._queue.append(req)
            self._count("submitted")
            self._queue_hwm = max(self._queue_hwm, len(self._queue))
            self._log("submit", t, rid, priority=req.priority,
                      deadline=None if req.deadline is None
                      else round(req.deadline, 9))
            self._sample_queue_depth(t)
        return req

    def cancel(self, req: LayoutRequest) -> bool:
        """Cancel a request. Queued: removed immediately. Running: its
        lanes are freed at the next wave boundary, without perturbing any
        sibling lane's result. Returns False if already finished."""
        with self._lock:
            t = self.clock.now()
            if req.status == "queued":
                self._queue.remove(req)
                self._log("cancel", t, req.rid, where="queued")
                self._finish(req, "cancelled", t)
                return True
            if req.status == "running":
                req.status = "cancelling"
                self._log("cancel", t, req.rid, where="running")
                return True
            return False

    def stats(self) -> dict:
        """Engine counters + a metrics-registry snapshot, taken atomically
        under the engine lock (no transition can interleave between the
        counter reads and the snapshot)."""
        with self._lock:
            d = dict(self.counters)
            d.update(queued=len(self._queue), running=len(self._running),
                     lanes_live=self.sched.lanes_live(),
                     max_lanes=self.max_lanes, max_queue=self.max_queue,
                     queue_depth_hwm=self._queue_hwm,
                     straggler_waves=self.sched.straggler_waves,
                     metrics=obs_metrics.REGISTRY.snapshot())
        return d

    @property
    def busy(self) -> bool:
        return bool(self._queue or self._running)

    def pending_deadlines(self) -> list[float]:
        with self._lock:
            return [r.deadline for r in self._queue + self._running
                    if r.deadline is not None]

    # -- engine cycle (owner thread only) --------------------------------------
    def tick(self) -> dict:
        """One engine cycle; returns what happened (see class docstring)."""
        t = self.clock.now()
        out = dict(admitted=0, completed=0, expired=0, cancelled=0,
                   wave=None)
        admits: list[LayoutRequest] = []
        with self._lock:
            for req in [r for r in self._running if r.status == "cancelling"]:
                self.sched.remove(req.job)
                self._running.remove(req)
                self._req_of_job.pop(req.job, None)
                self._finish(req, "cancelled", t)
                out["cancelled"] += 1
            for req in [r for r in self._queue
                        if r.deadline is not None and r.deadline <= t]:
                self._queue.remove(req)
                self._log("expire", t, req.rid, where="queued")
                self._finish(req, "expired", t)
                out["expired"] += 1
            for req in [r for r in self._running
                        if r.deadline is not None and r.deadline <= t]:
                self.sched.remove(req.job)
                self._running.remove(req)
                self._req_of_job.pop(req.job, None)
                self._log("expire", t, req.rid, where="running")
                self._finish(req, "expired", t)
                out["expired"] += 1
            free = self.max_lanes - self.sched.lanes_live()
            while self._queue and free > 0:
                req = min(self._queue, key=self._urgency)
                self._queue.remove(req)
                admits.append(req)
                free -= 1       # ≥ 1 lane per graph; extra components may
                                # briefly overshoot the cap by design

        # job construction = host-side coarsening; deliberately outside the
        # lock so concurrent submits never block on it
        for req in admits:
            job = self.sched.admit(req.edges, req.n, seed=req.seed,
                                   engine=req.engine)
            with self._lock:
                req.job = job
                req.status = "running"
                self._running.append(req)
                self._req_of_job[job] = req
                self._count("admitted")
                self._log("admit", t, req.rid, lanes=len(job.tasks))
                self._sample_queue_depth(t)
            out["admitted"] += 1

        if self.sched.active:
            summary = self.sched.step(
                order=lambda j: self._urgency(self._req_of_job[j]),
                max_lanes=self.wave_lanes)
            if summary["lanes"]:
                with self._lock:
                    self.counters["waves"] += 1
                    self._log("wave", t, -1, lanes=summary["lanes"],
                              groups=tuple(summary["groups"]))
                out["wave"] = summary

        td = self.clock.now()
        with self._lock:
            for req in [r for r in self._running
                        if r.status == "running" and r.job.done]:
                self._running.remove(req)
                self._req_of_job.pop(req.job, None)
                result = req.job.result()
                self._log("complete", td, req.rid,
                          latency=round(td - req.t_submit, 9))
                self._finish(req, "done", td, result=result)
                out["completed"] += 1
        return out

    def run_until_idle(self, max_ticks: int = 1_000_000) -> None:
        for _ in range(max_ticks):
            if not self.busy:
                return
            self.tick()
        raise RuntimeError("engine failed to drain")

    # -- internals -------------------------------------------------------------
    @staticmethod
    def _urgency(req: LayoutRequest) -> tuple:
        """Wave-picker/admission sort key: priority first (larger = more
        urgent), then earliest deadline, then submission order."""
        return (-req.priority,
                math.inf if req.deadline is None else req.deadline, req.rid)

    def _finish(self, req: LayoutRequest, status: str, t: float,
                result=None) -> None:
        # caller holds self._lock
        req.status = status
        req.t_done = t
        if status == "done":
            self._count("completed")
            REQUEST_LATENCY.observe(t - req.t_submit)
            # request-lifetime span on the shared timeline (explicit
            # engine-clock bounds, so it is sim-replayable)
            self.tracer.complete("request", req.t_submit, t, cat="engine",
                                 rid=req.rid)
            if req.future.set_running_or_notify_cancel():
                req.future.set_result(result)
        elif status == "expired":
            self._count("expired")
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(DeadlineExceeded(
                    f"request {req.rid} missed its deadline"))
        elif status == "cancelled":
            self._count("cancelled")
            req.future.cancel()
        else:                                   # pragma: no cover
            raise AssertionError(status)

    def _count(self, event: str) -> None:
        self.counters[event] += 1
        ENGINE_REQUESTS.inc(event=event)

    def _sample_queue_depth(self, t: float) -> None:
        # caller holds self._lock
        QUEUE_DEPTH.set(len(self._queue))
        QUEUE_DEPTH_HWM.set(self._queue_hwm)
        self.tracer.counter("engine.queue_depth", len(self._queue), ts=t)

    def _log(self, kind: str, t: float, rid: int, **detail) -> None:
        self.log.append((round(float(t), 9), kind, int(rid),
                         tuple(sorted(detail.items()))))
        # mirror the scheduling log onto the trace timeline as instants
        self.tracer.instant("engine." + kind, ts=t, cat="engine", rid=rid,
                            **detail)


# -- the deterministic simulation rig ------------------------------------------

@dataclasses.dataclass
class SimEvent:
    """One scripted event of a simulation trace: a ``submit`` carries a
    graph (and per-request knobs); a ``cancel`` targets the ``ref``-th
    event of the trace (which must be a submit)."""
    t: float
    kind: str = "submit"            # "submit" | "cancel"
    edges: object = None
    n: int = 0
    seed: int | None = None
    priority: int = 0
    deadline_s: float | None = None
    ref: int = -1


def poisson_trace(rate_hz: float, count: int, make_graph, *, seed: int = 0,
                  priorities=(0,), deadline_s: float | None = None,
                  t0: float = 0.0) -> list[SimEvent]:
    """Seeded Poisson arrival script: exponential inter-arrival gaps at
    ``rate_hz``; ``make_graph(i, rng) -> (edges, n)`` supplies the graphs
    and ``priorities`` is sampled uniformly per request. Same seed ⇒ the
    identical trace, which is what makes the service benchmark's smoke
    mode wall-clock-stable."""
    rng = np.random.RandomState(seed)
    t = float(t0)
    out = []
    for i in range(count):
        t += float(rng.exponential(1.0 / rate_hz))
        edges, n = make_graph(i, rng)
        out.append(SimEvent(t=t, edges=edges, n=n, seed=i,
                            priority=int(priorities[
                                int(rng.randint(len(priorities)))]),
                            deadline_s=deadline_s))
    return out


def null_dispatch(reqs: list) -> list:
    """Simulation executor: every lane's positions pass through unchanged
    — no device work at all, scheduling behavior only."""
    return [r.pos0 for r in reqs]


# default wave cost model for simulations: every shape-bucket GROUP in a
# wave pays a fixed dispatch cost, and lanes within a group ride nearly
# free — the strongly-sublinear regime BENCH_many.json measures (16 lanes
# ≈ 1.6× one lane). Charging per group rather than per wave makes
# mid-flight fragmentation — lanes spread across many levels — cost what
# it costs for real. Sims need the SHAPE of this model to be realistic,
# not the absolute numbers.
WAVE_COST_BASE_S = 0.030
WAVE_COST_PER_LANE_S = 0.0006


def default_wave_cost(wave: dict) -> float:
    groups = wave.get("groups") or [(None, wave["lanes"])]
    return sum(WAVE_COST_BASE_S + WAVE_COST_PER_LANE_S * cnt
               for _, cnt in groups)


def run_sim(core: EngineCore, events: list[SimEvent], *, wave_cost=None,
            max_waves: int = 1_000_000) -> list:
    """Drive an ``EngineCore`` (on a ``VirtualClock``) through a scripted
    arrival trace: events are delivered at their virtual times, each
    dispatched wave advances the clock by ``wave_cost(wave)``, and idle
    gaps jump straight to the next arrival or deadline. Returns one
    ``LayoutRequest`` handle per trace event (None for cancels and for
    submits rejected by backpressure). Deterministic: the same (core
    config, trace, cost model) replays to a bit-identical ``core.log``."""
    clock = core.clock
    if not isinstance(clock, VirtualClock):
        raise TypeError("run_sim requires an EngineCore on a VirtualClock")
    cost = wave_cost or default_wave_cost
    order = sorted(range(len(events)), key=lambda k: (events[k].t, k))
    handles: list = [None] * len(events)
    i = waves = stall = 0
    while True:
        while i < len(order) and events[order[i]].t <= clock.now() + 1e-12:
            k = order[i]
            ev = events[k]
            i += 1
            if ev.kind == "submit":
                try:
                    handles[k] = core.submit(
                        ev.edges, ev.n, priority=ev.priority,
                        deadline_s=ev.deadline_s, seed=ev.seed)
                except EngineBusy:
                    handles[k] = None
            else:
                assert ev.kind == "cancel", ev.kind
                if handles[ev.ref] is not None:
                    core.cancel(handles[ev.ref])
        if not core.busy and i >= len(order):
            return handles
        out = core.tick()
        if out["wave"]:
            stall = 0
            waves += 1
            if waves > max_waves:
                raise RuntimeError("simulation exceeded max_waves")
            clock.advance(cost(out["wave"]))
        elif any(out[k] for k in ("admitted", "completed", "expired",
                                  "cancelled")):
            stall = 0
        else:
            nxt = [events[order[i]].t] if i < len(order) else []
            nxt += core.pending_deadlines()
            future_ts = [x for x in nxt if x > clock.now() + 1e-12]
            if future_ts:
                stall = 0
                clock.advance(min(future_ts) - clock.now())
            else:
                stall += 1
                if stall > 3:
                    raise RuntimeError("simulation stalled with no events, "
                                       "no deadlines, and no progress")
                clock.advance(1e-6)


# -- the always-on threaded front door -----------------------------------------

class ContinuousLayoutService:
    """Always-on continuous-batching layout service (system clock).

    A worker thread owns the ``EngineCore`` and ticks it while work is
    pending; ``submit`` is thread-safe, validates/copies at the boundary,
    and returns a Future-backed ``LayoutRequest``. Unlike
    ``LayoutService``'s fixed windows, a request submitted while other
    layouts are mid-hierarchy joins their very next wave.

        svc = ContinuousLayoutService(LayoutConfig(seed=0))
        req = svc.submit(edges, n, priority=1, deadline_s=30.0)
        pos, stats = req.result()
        svc.cancel(other_req)           # frees its lanes, siblings unharmed
        svc.close()                     # drains pending work first
    """

    def __init__(self, cfg: LayoutConfig | None = None, *,
                 max_queue: int = 256, max_lanes: int = 32,
                 wave_lanes: int | None = None, poll_s: float = 0.002):
        self.core = EngineCore(cfg, max_queue=max_queue, max_lanes=max_lanes,
                               wave_lanes=wave_lanes)
        self._poll_s = poll_s
        self._wake = threading.Event()
        self._lifecycle = threading.Lock()
        self._closed = False
        # named so the tracer renders the engine's track stably (tids are
        # assigned from thread names, obs/trace.py)
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="engine-worker")
        self._worker.start()

    def submit(self, edges, n: int, *, priority: int = 0,
               deadline_s: float | None = None,
               seed: int | None = None,
               engine: str | None = None) -> LayoutRequest:
        with self._lifecycle:
            if self._closed:
                raise RuntimeError("service is closed")
            req = self.core.submit(edges, n, priority=priority,
                                   deadline_s=deadline_s, seed=seed,
                                   engine=engine)
        self._wake.set()
        return req

    def cancel(self, req: LayoutRequest) -> bool:
        ok = self.core.cancel(req)
        self._wake.set()
        return ok

    def layout(self, edges, n: int, timeout: float | None = None, **kw):
        """Blocking convenience wrapper around ``submit``."""
        return self.submit(edges, n, **kw).result(timeout)

    def stats(self) -> dict:
        return self.core.stats()

    def _run(self):
        while True:
            if self.core.busy:
                self.core.tick()
                continue
            if self._closed:
                return
            # idle: sleep until woken by submit/cancel/close (short poll so
            # an expiring queued deadline is still noticed promptly)
            self._wake.wait(self._poll_s)
            self._wake.clear()

    def close(self) -> None:
        """Stop accepting work, drain what is pending, stop the worker."""
        with self._lifecycle:
            if self._closed:
                return
            self._closed = True
        self._wake.set()
        self._worker.join(timeout=120)
