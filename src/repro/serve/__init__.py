# Layout serving subsystem: a finished multilevel layout becomes a
# queryable quadtree tile pyramid (tiles.py), persisted as npz shards
# (store.py), served by a jitted batched viewport resolver (query.py)
# behind a micro-batching front door (batcher.py). Whole-graph layout
# requests get their own micro-batched front door (layout_service.py),
# evaluated by the batched multi-graph driver. DESIGN.md §6, §9.
from repro.serve.tiles import TileBand, TilePyramid, build_pyramid
from repro.serve.store import (TileStore, save_pyramid, load_pyramid,
                               MANIFEST)
from repro.serve.query import (QueryEngine, reference_resolve, trim_result,
                               band_for_zoom, MAX_TILES)
from repro.serve.batcher import MicroBatcher
from repro.serve.layout_service import LayoutService
from repro.serve.engine import (ContinuousLayoutService, EngineCore,
                                EngineBusy, DeadlineExceeded, LayoutRequest,
                                Clock, SystemClock, VirtualClock, SimEvent,
                                poisson_trace, run_sim, null_dispatch,
                                validate_graph)
